"""Retrieval-augmented serving: PageANN as a first-class serving feature.

A small LM embeds each request (mean-pooled hidden state), the PageANN
index retrieves the nearest passages' ids, and the retrieved context tokens
are prepended before greedy decoding — the kNN-augmented serving loop the
paper's index accelerates.

  PYTHONPATH=src python examples/serve_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import MemoryMode, PageANNConfig, PageANNIndex, SearchParams
from repro.launch.serve import generate
from repro.models import transformer as tf
from repro.serve import BatchingEngine
from repro.train.step import init_train_state


def embed(params, arch, tokens):
    """Mean-pooled final hidden state as the retrieval embedding."""
    batch = {
        "tokens": tokens,
        "positions": jnp.broadcast_to(
            jnp.arange(tokens.shape[1])[None], tokens.shape
        ).astype(jnp.int32),
    }
    logits, _ = tf.forward_train(params, batch, arch)
    # use the (padded-vocab-masked) logits' pre-unembed proxy: mean logits
    # projected back is overkill for a demo — pool the embedding table rows
    emb = params["embed"][tokens].mean(axis=1)
    return emb


def main():
    arch = get_arch("granite-3-2b", smoke=True)
    state = init_train_state(arch, jax.random.PRNGKey(0))

    # corpus: 2000 synthetic passages; the index key is the passage's
    # mean token embedding (same space as query embeddings)
    rng = np.random.default_rng(0)
    corpus_tokens = rng.integers(0, arch.vocab_size, (2000, 16), np.int32)
    corpus_emb = np.asarray(
        embed(state.params, arch, jnp.asarray(corpus_tokens)), np.float32
    )

    cfg = PageANNConfig(
        dim=corpus_emb.shape[1], graph_degree=16, build_beam=32,
        pq_subspaces=8, lsh_sample=512, lsh_entries=8,
        beam_width=48, memory_mode=MemoryMode.HYBRID,
    )
    print("building PageANN index over corpus embeddings …")
    index = PageANNIndex.build(corpus_emb, cfg)

    # requests arrive one at a time; the batching engine collects them into
    # one fixed-shape dispatch and demuxes results per request. Requests
    # may carry their own runtime knobs: the last one asks for a wider
    # beam, forming its own (k-bin, params) dispatch group.
    engine = BatchingEngine.from_index(index, k=3, batch_size=4)
    requests = jnp.asarray(rng.integers(0, arch.vocab_size, (4, 8), np.int32))
    q_emb = np.asarray(embed(state.params, arch, requests), np.float32)
    wide = SearchParams(k=3, beam_width=64, lsh_entries=12)
    futures = [
        engine.submit(q, params=wide if i == len(q_emb) - 1 else None)
        for i, q in enumerate(q_emb)
    ]
    engine.flush()
    rows = [f.result() for f in futures]
    ids = np.stack([r.result.ids for r in rows])
    ios = np.stack([r.result.ios for r in rows])
    print(f"retrieved ids per request:\n{ids}")
    print(f"mean page reads/request: {ios.mean():.1f}")
    m = engine.metrics()
    print(f"engine: {m.requests} requests in {m.batches} batch(es), "
          f"p50 latency {m.latency_ms_p50:.1f} ms")

    # prepend the top passage to each request and decode
    top = np.where(ids[:, 0] >= 0, ids[:, 0], 0)
    context = jnp.asarray(corpus_tokens[top])
    prompts = jnp.concatenate([context, requests], axis=1)
    out = generate(state.params, arch, prompts, gen=8)
    print(f"generated continuation tokens:\n{np.asarray(out)}")


if __name__ == "__main__":
    main()
