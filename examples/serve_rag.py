"""Retrieval-augmented serving: PageANN as a first-class serving feature.

A small LM embeds each request (mean-pooled hidden state), a
multi-collection :class:`repro.serve.VectorService` retrieves the nearest
passages' ids from the collection the request names, and the retrieved
context tokens are prepended before greedy decoding — the kNN-augmented
serving loop the paper's index accelerates, served database-style: a
"passages" corpus and a "notes" corpus live behind ONE service (one
batching core, one compile cache), and each request routes by collection
name.

  PYTHONPATH=src python examples/serve_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import MemoryMode, PageANNConfig, SearchParams
from repro.launch.serve import generate
from repro.models import transformer as tf
from repro.serve import VectorService
from repro.train.step import init_train_state


def embed(params, arch, tokens):
    """Mean-pooled final hidden state as the retrieval embedding."""
    batch = {
        "tokens": tokens,
        "positions": jnp.broadcast_to(
            jnp.arange(tokens.shape[1])[None], tokens.shape
        ).astype(jnp.int32),
    }
    logits, _ = tf.forward_train(params, batch, arch)
    # use the (padded-vocab-masked) logits' pre-unembed proxy: mean logits
    # projected back is overkill for a demo — pool the embedding table rows
    emb = params["embed"][tokens].mean(axis=1)
    return emb


def main():
    arch = get_arch("granite-3-2b", smoke=True)
    state = init_train_state(arch, jax.random.PRNGKey(0))

    # two corpora: 2000 synthetic passages plus a smaller "notes" corpus —
    # the index key is each document's mean token embedding (same space as
    # query embeddings)
    rng = np.random.default_rng(0)
    corpora = {}
    for name, rows in (("passages", 2000), ("notes", 600)):
        tokens = rng.integers(0, arch.vocab_size, (rows, 16), np.int32)
        corpora[name] = (
            tokens,
            np.asarray(embed(state.params, arch, jnp.asarray(tokens)),
                       np.float32),
        )

    dim = corpora["passages"][1].shape[1]
    cfg = PageANNConfig(
        dim=dim, graph_degree=16, build_beam=32,
        pq_subspaces=8, lsh_sample=512, lsh_entries=8,
        beam_width=48, memory_mode=MemoryMode.HYBRID,
    )

    # requests arrive one at a time, each naming its collection; the one
    # shared service collects them into per-(collection, k-bin, params)
    # fixed-shape dispatches and demuxes results per request. The last
    # request also carries its own runtime knobs (a wider beam), forming
    # its own dispatch group.
    with VectorService(batch_size=4) as svc:
        for name, (_, emb_rows) in corpora.items():
            print(f"building PageANN collection {name!r} "
                  f"({len(emb_rows)} docs) …")
            svc.create_collection(name, cfg, emb_rows, k=3)

        requests = jnp.asarray(
            rng.integers(0, arch.vocab_size, (4, 8), np.int32)
        )
        q_emb = np.asarray(embed(state.params, arch, requests), np.float32)
        # route: even requests search the passages, odd ones the notes
        route = ["passages", "notes", "passages", "notes"]
        wide = SearchParams(k=3, beam_width=64, lsh_entries=12)
        futures = [
            svc.submit(route[i], q,
                       params=wide if i == len(q_emb) - 1 else None)
            for i, q in enumerate(q_emb)
        ]
        svc.flush()
        rows = [f.result() for f in futures]
        ids = np.stack([r.result.ids for r in rows])
        ios = np.stack([r.result.ios for r in rows])
        for i, (coll, r) in enumerate(zip(route, rows)):
            print(f"request {i} -> :{coll} -> ids {np.asarray(r.result.ids)}")
        print(f"mean page reads/request: {ios.mean():.1f}")
        m = svc.metrics()
        print(f"service: {m.requests} requests over {m.collections} "
              f"collections in {m.batches} batch(es), "
              f"p50 latency {m.latency_ms_p50:.1f} ms, compile cache "
              f"{m.compile_hits} hits / {m.compile_misses} misses")

    # prepend each request's top document (from ITS collection) and decode
    top = np.where(ids[:, 0] >= 0, ids[:, 0], 0)
    context = jnp.asarray(
        np.stack([corpora[coll][0][t] for coll, t in zip(route, top)])
    )
    prompts = jnp.concatenate([context, requests], axis=1)
    out = generate(state.params, arch, prompts, gen=8)
    print(f"generated continuation tokens:\n{np.asarray(out)}")


if __name__ == "__main__":
    main()
