"""Retrieval-augmented serving: PageANN as a first-class serving feature.

A small LM embeds each request (mean-pooled hidden state), the PageANN
index retrieves the nearest passages' ids, and the retrieved context tokens
are prepended before greedy decoding — the kNN-augmented serving loop the
paper's index accelerates.

  PYTHONPATH=src python examples/serve_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import MemoryMode, PageANNConfig, PageANNIndex
from repro.launch.serve import generate
from repro.models import transformer as tf
from repro.train.step import init_train_state


def embed(params, arch, tokens):
    """Mean-pooled final hidden state as the retrieval embedding."""
    batch = {
        "tokens": tokens,
        "positions": jnp.broadcast_to(
            jnp.arange(tokens.shape[1])[None], tokens.shape
        ).astype(jnp.int32),
    }
    logits, _ = tf.forward_train(params, batch, arch)
    # use the (padded-vocab-masked) logits' pre-unembed proxy: mean logits
    # projected back is overkill for a demo — pool the embedding table rows
    emb = params["embed"][tokens].mean(axis=1)
    return emb


def main():
    arch = get_arch("granite-3-2b", smoke=True)
    state = init_train_state(arch, jax.random.PRNGKey(0))

    # corpus: 2000 synthetic passages; the index key is the passage's
    # mean token embedding (same space as query embeddings)
    rng = np.random.default_rng(0)
    corpus_tokens = rng.integers(0, arch.vocab_size, (2000, 16), np.int32)
    corpus_emb = np.asarray(
        embed(state.params, arch, jnp.asarray(corpus_tokens)), np.float32
    )

    cfg = PageANNConfig(
        dim=corpus_emb.shape[1], graph_degree=16, build_beam=32,
        pq_subspaces=8, lsh_sample=512, lsh_entries=8,
        beam_width=48, memory_mode=MemoryMode.HYBRID,
    )
    print("building PageANN index over corpus embeddings …")
    index = PageANNIndex.build(corpus_emb, cfg)

    # requests
    requests = jnp.asarray(rng.integers(0, arch.vocab_size, (4, 8), np.int32))
    q_emb = np.asarray(embed(state.params, arch, requests), np.float32)
    res = index.search(q_emb, k=3)
    print(f"retrieved ids per request:\n{res.ids}")
    print(f"mean page reads/request: {res.ios.mean():.1f}")

    # prepend the top passage to each request and decode
    top = np.where(res.ids[:, 0] >= 0, res.ids[:, 0], 0)
    context = jnp.asarray(corpus_tokens[top])
    prompts = jnp.concatenate([context, requests], axis=1)
    out = generate(state.params, arch, prompts, gen=8)
    print(f"generated continuation tokens:\n{np.asarray(out)}")


if __name__ == "__main__":
    main()
