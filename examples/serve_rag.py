"""Retrieval-augmented serving: PageANN as a first-class serving feature.

A small LM embeds each request (mean-pooled hidden state), a
:class:`repro.serve.VectorService` retrieves the nearest passages' ids,
and the retrieved context tokens are prepended before greedy decoding —
the kNN-augmented serving loop the paper's index accelerates.

This demo serves ONE shared document collection to several agents, each
seeing only its own tag-namespaced slice: every document carries an
``agent`` tag ("support", "research", or "shared"), and each agent's
retrievals run with ``filter=Tag("agent").isin(<name>, "shared")`` — the
predicate is enforced *inside* the page scan, so there is one index, one
page file, one compile cache, and N isolated views. A
:class:`repro.serve.SemanticCache` sits in front of the service:
re-asked (re-embedded) questions within a cosine threshold of an answered
one are served from the cache without touching the index — scoped per
(collection, k, params, filter), so one agent's cached answers never
leak into another agent's view.

  PYTHONPATH=src python examples/serve_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import MemoryMode, MetadataSchema, PageANNConfig, Tag
from repro.launch.serve import generate
from repro.models import transformer as tf
from repro.serve import SemanticCache, VectorService
from repro.train.step import init_train_state

AGENTS = ("support", "research")


def embed(params, arch, tokens):
    """Mean-pooled final hidden state as the retrieval embedding."""
    batch = {
        "tokens": tokens,
        "positions": jnp.broadcast_to(
            jnp.arange(tokens.shape[1])[None], tokens.shape
        ).astype(jnp.int32),
    }
    logits, _ = tf.forward_train(params, batch, arch)
    # use the (padded-vocab-masked) logits' pre-unembed proxy: mean logits
    # projected back is overkill for a demo — pool the embedding table rows
    emb = params["embed"][tokens].mean(axis=1)
    return emb


def main():
    arch = get_arch("granite-3-2b", smoke=True)
    state = init_train_state(arch, jax.random.PRNGKey(0))

    # one shared corpus of 2000 synthetic passages; each document is owned
    # by one agent (or "shared", visible to all). The index key is the
    # document's mean token embedding (same space as query embeddings).
    rng = np.random.default_rng(0)
    rows = 2000
    tokens = rng.integers(0, arch.vocab_size, (rows, 16), np.int32)
    doc_emb = np.asarray(
        embed(state.params, arch, jnp.asarray(tokens)), np.float32
    )
    owners = rng.choice(AGENTS + ("shared",), size=rows).tolist()

    dim = doc_emb.shape[1]
    cfg = PageANNConfig(
        dim=dim, graph_degree=16, build_beam=32,
        pq_subspaces=8, lsh_sample=512, lsh_entries=8,
        beam_width=48, memory_mode=MemoryMode.HYBRID,
    )
    schema = MetadataSchema(tags=("agent",))
    views = {a: Tag("agent").isin(a, "shared") for a in AGENTS}

    with VectorService(
        batch_size=4, semantic_cache=SemanticCache(threshold=0.98)
    ) as svc:
        print(f"building shared PageANN collection ({rows} docs, "
              f"agents: {', '.join(AGENTS)} + shared) …")
        svc.create_collection(
            "docs", cfg, doc_emb, k=3,
            schema=schema, metadata={"agent": owners},
        )

        requests = jnp.asarray(
            rng.integers(0, arch.vocab_size, (4, 8), np.int32)
        )
        q_emb = np.asarray(embed(state.params, arch, requests), np.float32)
        # requests alternate between the two agents; each dispatch group is
        # keyed by its filter, so the two views never share a batch — and
        # never see each other's documents
        route = [AGENTS[i % len(AGENTS)] for i in range(len(q_emb))]
        futures = [
            svc.submit("docs", q, filter=views[agent])
            for agent, q in zip(route, q_emb)
        ]
        svc.flush()
        rows_out = [f.result() for f in futures]
        ids = np.stack([r.result.ids for r in rows_out])
        for i, (agent, r) in enumerate(zip(route, rows_out)):
            got = np.asarray(r.result.ids)
            seen = {owners[d] for d in got if d >= 0}
            print(f"request {i} [{agent}] -> ids {got} "
                  f"(owners: {sorted(seen)})")
            assert seen <= {agent, "shared"}, "view isolation violated"

        # the same questions again: answered from the semantic cache, no
        # index dispatch — but only within the SAME agent's view
        replay = [
            svc.submit("docs", q, filter=views[agent])
            for agent, q in zip(route, q_emb)
        ]
        svc.flush()
        n_cached = sum(f.result().cached for f in replay)
        m = svc.metrics()
        print(f"replayed {len(replay)} requests: {n_cached} served from "
              f"the semantic cache ({m.semantic_hits} hits / "
              f"{m.semantic_misses} misses)")
        print(f"service: {m.requests} requests in {m.batches} batch(es), "
              f"p50 latency {m.latency_ms_p50:.1f} ms, compile cache "
              f"{m.compile_hits} hits / {m.compile_misses} misses")

    # prepend each request's top document (from ITS view) and decode
    top = np.where(ids[:, 0] >= 0, ids[:, 0], 0)
    context = jnp.asarray(tokens[top])
    prompts = jnp.concatenate([context, requests], axis=1)
    out = generate(state.params, arch, prompts, gen=8)
    print(f"generated continuation tokens:\n{np.asarray(out)}")


if __name__ == "__main__":
    main()
