"""Quickstart: build a PageANN index, search it, inspect I/O counters.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MemoryMode, PageANNConfig, PageANNIndex, recall_at_k
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors


def main():
    x = clustered_vectors(5000, 32, num_clusters=64, seed=0)
    queries = query_vectors(x, 32, seed=1)
    truth = brute_force_knn(x, queries, 10)

    cfg = PageANNConfig(
        dim=32,
        graph_degree=24,          # Vamana degree R
        pq_subspaces=8,           # on-page compressed neighbor codes
        memory_mode=MemoryMode.HYBRID,
        beam_width=64,            # candidate set L
        io_batch=5,               # batched page reads per hop (paper: b=5)
    )
    print("building page-node index …")
    index = PageANNIndex.build(x, cfg)
    s = index.stats
    print(f"  pages={s.pages} capacity={s.capacity} "
          f"mean_page_degree={s.mean_page_degree:.1f}")
    print(f"  logical page bytes={s.logical_page_bytes} "
          f"(padded DMA tile={s.padded_tile_bytes})")
    print(f"  in-memory footprint={s.memory_bytes / 1e6:.2f} MB "
          f"({100 * s.memory_bytes / x.nbytes:.1f}% of dataset)")

    res = index.search(queries, k=10)
    print(f"recall@10 = {recall_at_k(res.ids, truth):.3f}")
    print(f"mean page reads/query = {res.ios.mean():.1f} "
          f"(hops={res.hops.mean():.1f}, cache hits={res.cache_hits.mean():.1f})")


if __name__ == "__main__":
    main()
