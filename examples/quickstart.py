"""Quickstart: the index lifecycle — build, search, save, load, re-search.

  PYTHONPATH=src python examples/quickstart.py

Build-time knobs (page geometry, PQ, memory mode) live in
``PageANNConfig``; runtime knobs (beam L, io batch b, LSH top-T, k) are a
per-call ``SearchParams`` — sweeping them reuses the one built index. The
saved artifact is the paper's disk layout: a raw page-aligned ``pages.bin``
plus numpy sidecars and a JSON manifest, and loading it back returns
bit-identical search results.
"""
import shutil
import tempfile

import numpy as np

from repro.core import (
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    recall_at_k,
)
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors


def main():
    x = clustered_vectors(5000, 32, num_clusters=64, seed=0)
    queries = query_vectors(x, 32, seed=1)
    truth = brute_force_knn(x, queries, 10)

    cfg = PageANNConfig(
        dim=32,
        graph_degree=24,          # Vamana degree R
        pq_subspaces=8,           # on-page compressed neighbor codes
        memory_mode=MemoryMode.HYBRID,
    )
    print("building page-node index …")
    index = PageANNIndex.build(x, cfg)
    s = index.stats
    print(f"  pages={s.pages} capacity={s.capacity} "
          f"mean_page_degree={s.mean_page_degree:.1f}")
    print(f"  logical page bytes={s.logical_page_bytes} "
          f"(padded DMA tile={s.padded_tile_bytes})")
    print(f"  in-memory footprint={s.memory_bytes / 1e6:.2f} MB "
          f"({100 * s.memory_bytes / x.nbytes:.1f}% of dataset)")

    res = index.search(queries, k=10)
    print(f"recall@10 = {recall_at_k(res.ids, truth):.3f}")
    print(f"mean page reads/query = {res.ios.mean():.1f} "
          f"(hops={res.hops.mean():.1f}, cache hits={res.cache_hits.mean():.1f})")

    # runtime knobs are per-call: sweep the beam over the SAME built index
    for beam, entries in ((16, 4), (64, 12), (128, 16)):
        params = SearchParams(k=10, beam_width=beam, lsh_entries=entries)
        r = index.search(queries, params=params)
        print(f"  beam={beam:3d} -> recall={recall_at_k(r.ids, truth):.3f} "
              f"ios={r.ios.mean():.1f}")

    # persist the index (the paper's on-SSD artifact) and reload it
    scratch = tempfile.mkdtemp(prefix="quickstart_index_")
    art = scratch + "/idx.pageann"
    try:
        index.save(art)
        loaded = PageANNIndex.load(art)
        res2 = loaded.search(queries, k=10)
        identical = all(
            np.array_equal(np.asarray(getattr(res, f)),
                           np.asarray(getattr(res2, f)))
            for f in res._fields
        )
        print(f"saved -> {art}; reloaded search bit-identical: {identical}")
        if not identical:
            raise SystemExit("save/load round trip diverged")
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    main()
