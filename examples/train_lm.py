"""End-to-end training driver example: a few hundred steps of a small LM
through the production driver (mesh, microbatching, checkpointing,
preemption guard, straggler monitor) on CPU.

  PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import tempfile

from repro.launch import train as train_driver


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # phase 1: train 120 steps, checkpoint every 40
        train_driver.main([
            "--arch", "granite-3-2b", "--smoke",
            "--steps", "120", "--seq-len", "64", "--batch", "8",
            "--microbatches", "2", "--lr", "3e-3",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "40",
            "--log-every", "20",
        ])
        # phase 2: simulate a restart — the driver restores from the latest
        # checkpoint and continues to 200
        print("\n--- simulated restart (restore from checkpoint) ---")
        train_driver.main([
            "--arch", "granite-3-2b", "--smoke",
            "--steps", "200", "--seq-len", "64", "--batch", "8",
            "--microbatches", "2", "--lr", "3e-3",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "40",
            "--log-every", "20",
        ])
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
