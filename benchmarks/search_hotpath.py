"""Search hot-path microbenchmark: the fused page-scan / top-k hop body.

Times the raw jitted ``core.search.batch_search`` (no serving-engine
overhead) on the exact BENCH_serve.json workload — same dataset, index
config, and 64-query set — at batch sizes 1/8/64, and reports QPS, per-hop
latency (batch wall time / while_loop iterations executed, i.e. the max hop
count in the batch), mean disk I/Os, and recall@10. ``main`` records the
sweep to BENCH_search.json next to the serving baseline's numbers so the
fused-kernel/top-k rewrite's speedup is a tracked artifact.

``--check BENCH_serve.json`` turns the run into a regression gate: the
optimized loop must reproduce the recorded mean I/Os exactly and must not
lose recall — the hop body is a speedup, not a semantic change. The gate
additionally proves request tracing (``repro.obs``) stays off the hot
path: the serving engine is run plain, with a disabled tracer, and with
an enabled tracer over the same workload — all three must return
bit-identical ids AND distances (the tracer never touches the compiled
program), and the enabled-tracer min-of-rounds wall must stay within 3%
of untraced.

  PYTHONPATH=src python -m benchmarks.search_hotpath \
      [--out BENCH_search.json] [--check BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import recall_at_k
from repro.core import search as search_mod

BATCH_SIZES = (1, 8, 64)
K = 10
ROUNDS = 7  # timed passes over the query set; min is reported (timeit style)


def _run_batches(index, queries: np.ndarray, batch_size: int):
    """Dispatch the query set through batch_search in batch_size chunks."""
    params = index.default_params.replace(k=K)
    chunks = [
        jnp.asarray(queries[i:i + batch_size], jnp.float32)
        for i in range(0, len(queries), batch_size)
    ]
    results = [
        jax.block_until_ready(
            search_mod.batch_search(
                c, index.data, params,
                capacity=index.store.capacity,
                mode=index.cfg.memory_mode.value,
            )
        )
        for c in chunks
    ]
    return results


def _measure(index, queries: np.ndarray, batch_size: int) -> dict:
    """Time ROUNDS full passes; report the fastest (the ``timeit`` min
    convention — this container's shared CPU adds ±20% scheduler noise to
    individual rounds, and the minimum is the stable estimate of what the
    code actually costs) plus the median for context."""
    results = _run_batches(index, queries, batch_size)  # compile + warm
    walls = []
    for _ in range(ROUNDS):
        t0 = time.perf_counter()
        results = _run_batches(index, queries, batch_size)
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    wall_median = sorted(walls)[len(walls) // 2]

    ids = np.concatenate([np.asarray(r.ids) for r in results])
    ios = np.concatenate([np.asarray(r.ios) for r in results])
    hops = np.concatenate([np.asarray(r.hops) for r in results])
    # a vmapped while_loop runs until the slowest lane finishes, so the
    # iteration count per dispatch is that dispatch's max hop count
    loop_iters = sum(
        int(np.asarray(r.hops).max()) for r in results
    )
    return dict(
        batch_size=batch_size,
        qps=len(queries) / wall,
        qps_median=len(queries) / wall_median,
        per_hop_ms=1e3 * wall / loop_iters,
        mean_hops=float(hops.mean()),
        mean_ios=float(ios.mean()),
        _ids=ids,
    )


def sweep(batch_sizes=BATCH_SIZES) -> list[dict]:
    x, q, truth = common.dataset()
    index = common.pageann_index(x, common.base_cfg(), "serve")
    points = []
    for bs in batch_sizes:
        pt = _measure(index, q, bs)
        pt["recall"] = recall_at_k(
            index.translate_ids(pt.pop("_ids")), truth
        )
        points.append(pt)
    return points


def tracing_gate(max_overhead: float = 0.03) -> dict:
    """Prove request tracing stays off the hot path.

    Runs the BENCH_serve workload through a ``BatchingEngine`` three ways
    — no tracer, ``Tracer(enabled=False)``, ``Tracer(enabled=True)`` —
    and (a) asserts all three return bit-identical ids and distances
    (tracing never changes the compiled program or the dispatch order),
    (b) measures the enabled-mode wall overhead and gates it at
    ``max_overhead``. The estimator is the median over rounds of the
    *paired* within-round ratio ``on / min(plain, off)`` — all three
    modes run back-to-back inside each round, so shared-CPU scheduler
    drift hits them equally and cancels in the ratio (a min-of-rounds
    difference across sequential runs swings ±3% on this container,
    swamping the ~0.6% true span-recording cost).
    Returns the measurement dict; raises AssertionError on divergence.
    """
    from repro.obs import Tracer
    from repro.serve import BatchingEngine

    x, q, _truth = common.dataset()
    index = common.pageann_index(x, common.base_cfg(), "serve")

    tr = Tracer()
    engines = {
        "plain": BatchingEngine.from_index(index, k=K, batch_size=64),
        "off": BatchingEngine.from_index(
            index, k=K, batch_size=64, tracer=Tracer(enabled=False)
        ),
        "on": BatchingEngine.from_index(
            index, k=K, batch_size=64, tracer=tr
        ),
    }
    results, walls = {}, {name: [] for name in engines}
    try:
        for eng in engines.values():
            eng.search(q)  # compile + warm
        # interleave the timed rounds so slow scheduler drift on a shared
        # CPU hits every mode equally instead of biasing whichever ran last
        for _ in range(max(ROUNDS, 11)):
            for name, eng in engines.items():
                t0 = time.perf_counter()
                rows = eng.search(q)
                walls[name].append(time.perf_counter() - t0)
                results[name] = rows
    finally:
        for eng in engines.values():
            eng.close()

    def arrays(rows):
        return (
            np.stack([np.asarray(r.result.ids) for r in rows]),
            np.stack([np.asarray(r.result.dists) for r in rows]),
        )

    ids_plain, d_plain = arrays(results["plain"])
    wall_plain, wall_off, wall_on = (
        min(walls[n]) for n in ("plain", "off", "on")
    )

    for name in ("off", "on"):
        ids, dists = arrays(results[name])
        assert np.array_equal(ids_plain, ids), (
            f"tracer-{name}: result ids diverged from untraced run"
        )
        assert np.array_equal(
            d_plain.view(np.uint32), dists.view(np.uint32)
        ), f"tracer-{name}: distances not bit-identical to untraced run"

    ratios = sorted(
        on / min(plain, off)
        for plain, off, on in zip(walls["plain"], walls["off"], walls["on"])
    )
    overhead = ratios[len(ratios) // 2] - 1.0
    return dict(
        qps_plain=len(q) / wall_plain,
        qps_disabled=len(q) / wall_off,
        qps_traced=len(q) / wall_on,
        overhead_traced=overhead,
        max_overhead=max_overhead,
        spans=len(tr),
        bit_identical=True,
        ok=overhead < max_overhead,
    )


def _serve_baseline(path: str) -> dict:
    """batch_size -> recorded serving point from BENCH_serve.json."""
    with open(path) as f:
        doc = json.load(f)
    return {pt["batch_size"]: pt for pt in doc["points"]}


def check_regression(points: list[dict], serve_path: str) -> list[str]:
    """Mean I/Os must match the recorded workload exactly; recall must not
    drop. Returns a list of failure strings (empty == pass)."""
    base = _serve_baseline(serve_path)
    failures = []
    for pt in points:
        ref = base.get(pt["batch_size"])
        if ref is None:
            continue
        if abs(pt["mean_ios"] - ref["mean_ios"]) > 1e-9:
            failures.append(
                f"batch{pt['batch_size']}: mean_ios {pt['mean_ios']} != "
                f"recorded {ref['mean_ios']}"
            )
        if pt["recall"] < ref["recall"] - 1e-9:
            failures.append(
                f"batch{pt['batch_size']}: recall {pt['recall']} < "
                f"recorded {ref['recall']}"
            )
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write BENCH_search.json here")
    ap.add_argument(
        "--check", default=None,
        help="BENCH_serve.json to gate mean_ios/recall against",
    )
    args = ap.parse_args(argv)
    points = sweep()
    serve = _serve_baseline(args.check) if args.check else {}
    for pt in points:
        ref = serve.get(pt["batch_size"])
        if ref:
            pt["serve_baseline_qps"] = ref["qps"]
            pt["speedup_vs_serve"] = pt["qps"] / ref["qps"]
        extra = (
            f"  speedup={pt['speedup_vs_serve']:.2f}x" if ref else ""
        )
        print(
            f"batch={pt['batch_size']:3d}  qps={pt['qps']:8.1f}  "
            f"per_hop={pt['per_hop_ms']:6.3f}ms  ios={pt['mean_ios']:6.2f}  "
            f"recall={pt['recall']:.4f}{extra}"
        )
    tracing = None
    if args.check:
        tracing = tracing_gate()
        print(
            f"tracing gate: bit_identical=True  "
            f"overhead={tracing['overhead_traced']:+.2%}  "
            f"(limit {tracing['max_overhead']:.0%}, "
            f"{tracing['spans']} spans recorded)"
        )
    if args.out:
        doc = dict(
            bench="search_hotpath",
            n=common.N,
            dim=common.D,
            queries=common.Q,
            k=K,
            platform=platform.platform(),
            points=points,
        )
        if tracing is not None:
            doc["tracing"] = tracing
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")
    if args.check:
        failures = check_regression(points, args.check)
        if not tracing["ok"]:
            failures.append(
                f"tracing overhead {tracing['overhead_traced']:+.2%} "
                f">= {tracing['max_overhead']:.0%} limit"
            )
        if failures:
            for f_ in failures:
                print(f"REGRESSION: {f_}")
            raise SystemExit(1)
        print(f"regression gate vs {args.check}: ok")


if __name__ == "__main__":
    main()
