"""QPS-vs-memory-budget curve for the out-of-HBM streaming page tier.

The tentpole claim of the streaming tier is that a PageANN artifact much
larger than the device-resident budget still serves **bit-identical**
results: only the hottest pages (by the artifact's persisted
``page_order`` access counts) are pinned on device, the rest stream from
the ``pages.bin`` memmap through a per-hop host callback. This benchmark
quantifies what that costs: one saved artifact is reloaded under a
shrinking :class:`repro.core.MemoryBudget` and each point records

  * read throughput (QPS) and per-query latency of the batched search,
  * recall@10 against brute-force ground truth,
  * the resident/streamed split (``resident_pages`` / ``resident_bytes``
    vs ``disk_bytes``) and the host fetch counters
    (``pages_fetched`` / ``fetch_hits`` / ``fetch_wall_s``),
  * ``bit_identical`` — ids AND dists exactly equal to the fully
    resident baseline (hard-asserted; a mismatch fails the run).

Results land in ``BENCH_stream.json``.

  PYTHONPATH=src python -m benchmarks.stream [--out BENCH_stream.json]
      [--smoke]

``--smoke`` is the CI gate: a tiny index served at a 0.25x budget (~4x
larger than the resident region), with hard bit-identity and recall
assertions against the fully resident load.
"""
from __future__ import annotations

import argparse
import json
import platform
import tempfile
import time

import numpy as np

from repro.core import (
    MemoryBudget,
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    recall_at_k,
)
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

K = 10
BUDGET_FRACTIONS = (1.0, 0.75, 0.5, 0.25, 0.125)


def _timeit(fn, repeats=3):
    import jax

    jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn())
    return out, (time.perf_counter() - t0) / repeats


def _point(idx, res, dt, nq, truth, baseline, frac) -> dict:
    s = idx.stats
    fs = idx.fetch_stats()
    identical = bool(
        np.array_equal(np.asarray(res.ids), np.asarray(baseline.ids))
        and np.array_equal(np.asarray(res.dists), np.asarray(baseline.dists))
    )
    return dict(
        budget_fraction=frac,
        qps=nq / dt if dt > 0 else 0.0,
        us_per_query=1e6 * dt / nq,
        recall=recall_at_k(res.ids, truth),
        mean_ios=float(np.asarray(res.ios).mean()),
        resident_pages=s.resident_pages,
        total_pages=s.pages,
        resident_bytes=s.resident_bytes,
        disk_bytes=s.disk_bytes,
        bit_identical=identical,
        **fs,
    )


def sweep(artifact: str, queries: np.ndarray, truth: np.ndarray,
          params: SearchParams, fractions) -> list[dict]:
    """Load ``artifact`` at each budget and measure; the 1x point is the
    baseline every smaller budget must match bit for bit."""
    points = []
    baseline = None
    for frac in fractions:
        budget = None if frac >= 1.0 else MemoryBudget(fraction=frac)
        idx = PageANNIndex.load(artifact, memory_budget=budget)
        res, dt = _timeit(lambda: idx.search(queries, params=params))
        if baseline is None:
            baseline = res
        pt = _point(idx, res, dt, len(queries), truth, baseline, frac)
        points.append(pt)
        print(
            f"budget={frac:5.3f}x  qps={pt['qps']:8.1f}  "
            f"recall={pt['recall']:.4f}  "
            f"resident={pt['resident_pages']}/{pt['total_pages']} pages  "
            f"fetched={pt['pages_fetched']} (hits={pt['fetch_hits']})  "
            f"bit_identical={pt['bit_identical']}"
        )
        if not pt["bit_identical"]:
            raise SystemExit(
                f"STREAMING MISMATCH: budget {frac}x diverged from the "
                "fully resident baseline"
            )
    return points


def run(n: int, dim: int, q: int, cfg: PageANNConfig, fractions,
        directory: str) -> dict:
    x = clustered_vectors(n, dim, num_clusters=max(8, n // 125), seed=0)
    queries = query_vectors(x, q, seed=1)
    truth = brute_force_knn(x, queries, K)
    params = SearchParams.from_config(cfg)

    t0 = time.perf_counter()
    idx = PageANNIndex.build(x, cfg)
    build_s = time.perf_counter() - t0
    # warm so the saved page_order ranks pages by real access counts — the
    # hotness ordering every budgeted reload pins its resident region by
    idx.warm_cache(np.asarray(queries), params=params)
    idx.save(directory)

    points = sweep(directory, queries, truth, params, fractions)
    return dict(
        bench="stream",
        n=n, dim=dim, queries=q, k=K,
        build_s=build_s,
        page_record_bytes=idx.store.padded_tile_bytes(),
        platform=platform.platform(),
        points=points,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write BENCH_stream.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny CI gate: serve a small index at a 0.25x memory budget "
             "with hard bit-identity + recall assertions",
    )
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = PageANNConfig(
            dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
            lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
            memory_mode=MemoryMode.HYBRID,
        )
        with tempfile.TemporaryDirectory() as tmp:
            doc = run(n=1200, dim=32, q=16, cfg=cfg,
                      fractions=(1.0, 0.25), directory=tmp)
    else:
        from benchmarks import common

        cfg = common.base_cfg()
        x, queries, _ = common.dataset()
        artifact = common.index_cache_path("stream_art", cfg, x)
        from repro.core import persist

        if not persist.is_index_dir(artifact):
            idx = common.pageann_index(x, cfg, "stream")
            idx.warm_cache(
                np.asarray(queries), params=SearchParams.from_config(cfg)
            )
            idx.save(artifact)
        truth = brute_force_knn(x, queries, K)
        points = sweep(artifact, queries, truth,
                       SearchParams.from_config(cfg), BUDGET_FRACTIONS)
        doc = dict(
            bench="stream",
            n=common.N, dim=common.D, queries=common.Q, k=K,
            platform=platform.platform(),
            points=points,
        )

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    if args.smoke:
        full, budgeted = doc["points"][0], doc["points"][-1]
        if not budgeted["bit_identical"]:
            raise SystemExit(
                "STREAM REGRESSION: budgeted results are not bit-identical "
                "to the fully resident load"
            )
        if budgeted["recall"] < full["recall"]:
            raise SystemExit(
                f"STREAM REGRESSION: budgeted recall {budgeted['recall']:.4f}"
                f" < resident {full['recall']:.4f}"
            )
        if budgeted["recall"] < 0.8:
            raise SystemExit(
                f"STREAM REGRESSION: recall {budgeted['recall']:.4f} < 0.8"
            )
        if not budgeted["resident_pages"] * 4 <= budgeted["total_pages"]:
            raise SystemExit(
                f"STREAM REGRESSION: budget not enforced — "
                f"{budgeted['resident_pages']}/{budgeted['total_pages']} "
                "pages resident at a 0.25x budget"
            )
        if budgeted["pages_fetched"] == 0:
            raise SystemExit(
                "STREAM REGRESSION: no host fetches at a 0.25x budget — "
                "the streaming path did not run"
            )
        print(
            f"stream smoke ok: {budgeted['resident_pages']}/"
            f"{budgeted['total_pages']} pages resident, "
            f"{budgeted['pages_fetched']} streamed fetches, results "
            f"bit-identical at recall {budgeted['recall']:.4f}"
        )


if __name__ == "__main__":
    main()
