"""Paper Figs. 10/11 + Table 4: performance vs memory budget.

Sweeps the memory-disk coordination modes (Sec 4.3) from ~0% memory
(DISK_ONLY: only the LSH router + sampled codes in memory) through HYBRID
to MEM_ALL (+ warmed page cache), reporting recall, mean I/Os and the
in-memory footprint of each configuration.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import MemoryMode, SearchParams, recall_at_k


def run() -> list[str]:
    x, q, truth = common.dataset()
    dataset_bytes = x.nbytes
    rows = []
    settings = [
        ("disk_only", MemoryMode.DISK_ONLY, 0),
        ("hybrid", MemoryMode.HYBRID, 0),
        ("mem_all", MemoryMode.MEM_ALL, 0),
        ("mem_all_cache", MemoryMode.MEM_ALL, 64),
    ]
    for tag, mode, cache in settings:
        # the memory mode shapes the *artifact* (page capacity, on-page
        # codes) so each mode is its own disk-cached index; the search
        # knobs ride along as per-call params
        cfg = common.base_cfg(memory_mode=mode, cache_pages=cache)
        params = SearchParams.from_config(cfg)
        idx = common.pageann_index(x, cfg, f"ms_{tag}")
        if cache:
            idx.warm_cache(np.asarray(q), params=params)
        res, dt = common.timeit(lambda: idx.search(q, params=params))
        mem = idx.stats.memory_bytes
        rows.append(
            f"memsweep_{tag},{1e6 * dt / len(q):.1f},"
            f"recall={recall_at_k(res.ids, truth):.3f};ios={res.ios.mean():.1f};"
            f"cache_hits={res.cache_hits.mean():.1f};"
            f"mem_ratio={100 * mem / dataset_bytes:.1f}%;mem_bytes={mem};"
            f"pages={idx.store.num_pages};capacity={idx.store.capacity}"
        )
    # Table 4 analog: minimum memory to reach recall 0.9 — the DISK_ONLY row
    # carries only the router (~lsh bytes), mirroring the paper's 0.05%.
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
