"""Paper Figs. 10/11 + Table 4: performance vs memory budget.

Two sweeps share one report:

* **mode sweep** — the memory-disk coordination modes (Sec 4.3) from ~0%
  memory (DISK_ONLY: only the LSH router + sampled codes in memory)
  through HYBRID to MEM_ALL (+ warmed page cache), reporting recall, mean
  I/Os and the in-memory footprint of each configuration.
* **budget sweep** — REAL out-of-HBM streaming: one artifact loaded under
  a shrinking ``MemoryBudget`` (1x, 0.5x, 0.25x of the page file), so
  only the hottest pages stay device-resident and the rest stream from
  the ``pages.bin`` memmap per hop. Each row reports QPS, recall, the
  resident/streamed split and the host fetch counters, and asserts the
  streamed results stay bit-identical to the fully resident baseline.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import (
    MemoryBudget,
    MemoryMode,
    PageANNIndex,
    SearchParams,
    persist,
    recall_at_k,
)

BUDGET_FRACTIONS = (1.0, 0.5, 0.25)


def mode_rows(x, q, truth) -> list[str]:
    dataset_bytes = x.nbytes
    rows = []
    settings = [
        ("disk_only", MemoryMode.DISK_ONLY, 0),
        ("hybrid", MemoryMode.HYBRID, 0),
        ("mem_all", MemoryMode.MEM_ALL, 0),
        ("mem_all_cache", MemoryMode.MEM_ALL, 64),
    ]
    for tag, mode, cache in settings:
        # the memory mode shapes the *artifact* (page capacity, on-page
        # codes) so each mode is its own disk-cached index; the search
        # knobs ride along as per-call params
        cfg = common.base_cfg(memory_mode=mode, cache_pages=cache)
        params = SearchParams.from_config(cfg)
        idx = common.pageann_index(x, cfg, f"ms_{tag}")
        if cache:
            idx.warm_cache(np.asarray(q), params=params)
        res, dt = common.timeit(lambda: idx.search(q, params=params))
        mem = idx.stats.memory_bytes
        rows.append(
            f"memsweep_{tag},{1e6 * dt / len(q):.1f},"
            f"recall={recall_at_k(res.ids, truth):.3f};ios={res.ios.mean():.1f};"
            f"cache_hits={res.cache_hits.mean():.1f};"
            f"mem_ratio={100 * mem / dataset_bytes:.1f}%;mem_bytes={mem};"
            f"pages={idx.store.num_pages};capacity={idx.store.capacity}"
        )
    # Table 4 analog: minimum memory to reach recall 0.9 — the DISK_ONLY row
    # carries only the router (~lsh bytes), mirroring the paper's 0.05%.
    return rows


def streamed_artifact(x, q, cfg) -> str:
    """One saved artifact all budget points reload: built (or pulled from
    the bench cache), warmed so the persisted ``page_order`` carries real
    access counts — that ordering is what a budgeted load pins by."""
    params = SearchParams.from_config(cfg)
    path = common.index_cache_path("ms_budget_art", cfg, x)
    if not persist.is_index_dir(path):
        idx = common.pageann_index(x, cfg, "ms_budget")
        idx.warm_cache(np.asarray(q), params=params)
        idx.save(path)
    return path


def budget_rows(x, q, truth) -> list[str]:
    cfg = common.base_cfg()
    params = SearchParams.from_config(cfg)
    path = streamed_artifact(x, q, cfg)
    rows = []
    baseline = None
    for frac in BUDGET_FRACTIONS:
        budget = None if frac >= 1.0 else MemoryBudget(fraction=frac)
        idx = PageANNIndex.load(path, memory_budget=budget)
        res, dt = common.timeit(lambda: idx.search(q, params=params))
        if baseline is None:
            baseline = res
        identical = bool(
            np.array_equal(np.asarray(res.ids), np.asarray(baseline.ids))
            and np.array_equal(
                np.asarray(res.dists), np.asarray(baseline.dists)
            )
        )
        if not identical:
            raise SystemExit(
                f"STREAMING MISMATCH at budget {frac}: results diverged "
                "from the fully resident baseline"
            )
        s = idx.stats
        fs = idx.fetch_stats()
        rows.append(
            f"memsweep_budget_{frac:g}x,{1e6 * dt / len(q):.1f},"
            f"recall={recall_at_k(res.ids, truth):.3f};"
            f"resident_pages={s.resident_pages}/{s.pages};"
            f"resident_bytes={s.resident_bytes};disk_bytes={s.disk_bytes};"
            f"pages_fetched={fs['pages_fetched']};"
            f"fetch_hits={fs['fetch_hits']};"
            f"fetch_wall_s={fs['fetch_wall_s']:.3f};"
            f"bit_identical={identical}"
        )
    return rows


def run() -> list[str]:
    x, q, truth = common.dataset()
    return mode_rows(x, q, truth) + budget_rows(x, q, truth)


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
