"""Paper Table 5: pre-processing (index build) time breakdown — plus the
lifecycle rows that replace rebuilds in every other process: ``save`` /
``load`` wall time and the on-disk artifact size. Load time is the cost a
serving process pays instead of the full build."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import PageANNIndex


def _dir_bytes(path: str) -> int:
    return sum(
        os.path.getsize(os.path.join(root, name))
        for root, _, names in os.walk(path)
        for name in names
    )


def run() -> list[str]:
    x, _, _ = common.dataset()
    cfg = common.base_cfg()
    t0 = time.perf_counter()
    idx = PageANNIndex.build(x[:4000], cfg)   # fresh build incl. Vamana
    total = time.perf_counter() - t0
    s = idx.stats

    art = tempfile.mkdtemp(prefix="repro_build_overhead_")
    try:
        t1 = time.perf_counter()
        idx.save(art)
        save_s = time.perf_counter() - t1
        art_bytes = _dir_bytes(art)
        page_bytes = os.path.getsize(os.path.join(art, "pages.bin"))
        t2 = time.perf_counter()
        PageANNIndex.load(art)
        load_s = time.perf_counter() - t2
    finally:
        shutil.rmtree(art, ignore_errors=True)

    return [
        f"build_total,{1e6 * total:.0f},n=4000;pages={s.pages};cap={s.capacity}",
        f"build_vamana,{1e6 * s.vamana_s:.0f},share={100 * s.vamana_s / total:.0f}%",
        f"build_grouping,{1e6 * s.grouping_s:.0f},share={100 * s.grouping_s / total:.0f}%",
        f"build_pq,{1e6 * s.pq_s:.0f},share={100 * s.pq_s / total:.0f}%",
        f"build_pack,{1e6 * s.pack_s:.0f},share={100 * s.pack_s / total:.0f}%",
        f"build_lsh,{1e6 * s.lsh_s:.0f},share={100 * s.lsh_s / total:.0f}%",
        f"lifecycle_save,{1e6 * save_s:.0f},artifact_bytes={art_bytes};page_file_bytes={page_bytes}",
        f"lifecycle_load,{1e6 * load_s:.0f},speedup_vs_build={total / load_s:.1f}x",
    ]


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
