"""Paper Table 5: pre-processing (index build) time breakdown."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import PageANNIndex


def run() -> list[str]:
    x, _, _ = common.dataset()
    cfg = common.base_cfg()
    t0 = time.perf_counter()
    idx = PageANNIndex.build(x[:4000], cfg)   # fresh build incl. Vamana
    total = time.perf_counter() - t0
    s = idx.stats
    return [
        f"build_total,{1e6 * total:.0f},n=4000;pages={s.pages};cap={s.capacity}",
        f"build_vamana,{1e6 * s.vamana_s:.0f},share={100 * s.vamana_s / total:.0f}%",
        f"build_grouping,{1e6 * s.grouping_s:.0f},share={100 * s.grouping_s / total:.0f}%",
        f"build_pq,{1e6 * s.pq_s:.0f},share={100 * s.pq_s / total:.0f}%",
        f"build_pack,{1e6 * s.pack_s:.0f},share={100 * s.pack_s / total:.0f}%",
        f"build_lsh,{1e6 * s.lsh_s:.0f},share={100 * s.lsh_s / total:.0f}%",
    ]


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
