"""Adaptive query engine benchmark: what query-sensitive search buys.

Three claims from the adaptive engine (PR 7), measured over ONE built
index on a **skewed query mix** — half "easy" queries (duplicates of base
vectors: the index finds them in a handful of hops) and half "hard"
held-out queries (the long tail that needs the full traversal):

  * **Early termination** (``AdaptiveParams.patience``): easy queries
    exit the hop loop when their top-k stops improving instead of running
    until the beam is exhausted — mean hops and mean I/Os drop while
    recall stays within a hair of the non-adaptive run.
  * **Entry selection** (``entry_slack_bits``): confidently-routed
    queries seed the beam only with entry candidates within a Hamming
    slack of their best hit, scheduling fewer junk pages up front.
  * **Autotuning** (``PageANNIndex.autotune``): given only a recall
    target, the binary-searched operating point lands within a few
    percent of the best QPS an exhaustive grid search finds at that
    recall — nobody hand-picks beam/patience again.

Each row records params / recall / QPS / mean+p99 hops / mean I/Os; the
autotune section additionally records the grid-search optimum it is
judged against. Results land in ``BENCH_adaptive.json``.

  PYTHONPATH=src python -m benchmarks.adaptive [--out BENCH_adaptive.json]
      [--smoke]

``--smoke`` is the CI gate: a tiny index, hard-asserting that
(a) results with adaptive features disabled — both ``adaptive=None`` and
an all-default ``AdaptiveParams()`` — are **bit-identical** to the
pre-adaptive loop on every ``SearchResult`` field, and (b) the autotuned
operating point actually meets its recall floor.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.core import (
    AdaptiveParams,
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    recall_at_k,
)
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

K = 10
RECALL_TARGET = 0.95
# autotuned QPS must land within this factor of the grid-search optimum
AUTOTUNE_QPS_SLACK = 0.90


def skewed_mix(x: np.ndarray, queries: np.ndarray, n_each: int, seed: int = 3):
    """Half duplicates of base vectors (easy), half held-out (hard)."""
    rng = np.random.default_rng(seed)
    easy = x[rng.choice(len(x), n_each, replace=False)]
    hard = np.asarray(queries)[:n_each]
    return np.concatenate([easy, hard]), n_each


def measure(idx: PageANNIndex, mix: np.ndarray, n_easy: int,
            truth: np.ndarray, params: SearchParams, label: str) -> dict:
    import jax

    jax.block_until_ready(idx.search(mix, params=params).dists)  # compile
    t0 = time.perf_counter()
    res = idx.search(mix, params=params)
    jax.block_until_ready(res.dists)
    dt = time.perf_counter() - t0
    hops = np.asarray(res.hops)
    ios = np.asarray(res.ios)
    return dict(
        label=label,
        params=params.to_json(),
        recall=recall_at_k(res.ids, truth),
        qps=len(mix) / dt if dt > 0 else 0.0,
        us_per_query=1e6 * dt / len(mix),
        mean_hops=float(hops.mean()),
        p99_hops=float(np.percentile(hops, 99)),
        mean_hops_easy=float(hops[:n_easy].mean()),
        mean_hops_hard=float(hops[n_easy:].mean()),
        mean_ios=float(ios.mean()),
        mean_ios_easy=float(ios[:n_easy].mean()),
    )


def adaptive_rows(idx: PageANNIndex, x: np.ndarray, queries: np.ndarray,
                  cfg: PageANNConfig, n_each: int) -> list[dict]:
    """Hand-picked vs progressively adaptive rows over the same index."""
    mix, n_easy = skewed_mix(x, queries, n_each)
    truth = brute_force_knn(x, mix, K)
    base = SearchParams.from_config(cfg)
    rows = []
    for label, p in (
        ("hand-picked", base),
        ("early-termination", base.replace(adaptive=AdaptiveParams(patience=2))),
        ("entry+termination", base.replace(adaptive=AdaptiveParams(
            patience=2, entry_slack_bits=4, min_entries=4))),
    ):
        row = measure(idx, mix, n_easy, truth, p, label)
        rows.append(row)
        print(
            f"{label:18s} recall={row['recall']:.4f} "
            f"qps={row['qps']:8.1f} hops={row['mean_hops']:6.2f} "
            f"(easy {row['mean_hops_easy']:5.2f} / hard "
            f"{row['mean_hops_hard']:5.2f}) ios={row['mean_ios']:6.2f}"
        )
    return rows


def autotune_section(idx: PageANNIndex, x: np.ndarray, queries: np.ndarray,
                     cfg: PageANNConfig, n_each: int,
                     recall_target: float) -> dict:
    """Autotune on held-out tune queries, judge on the eval mix, and
    compare against an exhaustive grid search at the same target."""
    tune_q = query_vectors(x, max(16, n_each), seed=2)
    win = idx.autotune(
        np.asarray(tune_q), recall_target=recall_target, k=K,
        patience_grid=(None, 2, 4),
        entries_grid=(max(4, cfg.lsh_entries // 2),),
    )
    mix, n_easy = skewed_mix(x, queries, n_each)
    truth = brute_force_knn(x, mix, K)
    tuned_row = measure(idx, mix, n_easy, truth, win["params"], "autotuned")

    # exhaustive grid at the same target: the optimum autotune is judged by
    base = SearchParams.from_config(cfg, k=K)
    grid = []
    for bw in sorted({max(cfg.lsh_entries, cfg.beam_width // 4),
                      max(cfg.lsh_entries, cfg.beam_width // 2),
                      cfg.beam_width, 2 * cfg.beam_width}):
        for pat in (None, 2, 4):
            a = None if pat is None else AdaptiveParams(patience=pat)
            p = base.replace(beam_width=bw, adaptive=a)
            grid.append(measure(idx, mix, n_easy, truth, p,
                                f"grid:bw={bw},pat={pat}"))
    ok = [g for g in grid if g["recall"] >= recall_target]
    optimum = max(ok or grid, key=lambda g: g["qps"])
    print(
        f"autotuned          recall={tuned_row['recall']:.4f} "
        f"qps={tuned_row['qps']:8.1f}  (grid optimum {optimum['label']}: "
        f"recall={optimum['recall']:.4f} qps={optimum['qps']:8.1f})"
    )
    return dict(
        recall_target=recall_target,
        tuned=tuned_row,
        tuned_point={k: v for k, v in win.items() if k != "params"}
        | {"params": win["params"].to_json()},
        grid=grid,
        grid_optimum=optimum,
        qps_vs_optimum=(
            tuned_row["qps"] / optimum["qps"] if optimum["qps"] else 0.0
        ),
    )


def bit_identity_check(idx: PageANNIndex, queries: np.ndarray,
                       params: SearchParams) -> None:
    """Disabled adaptive features must change NOTHING: adaptive=None and
    an all-default AdaptiveParams() produce equal ids/dists/ios/hops/
    cache_hits."""
    want = idx.search(queries, params=params.replace(adaptive=None))
    got = idx.search(queries, params=params.replace(adaptive=AdaptiveParams()))
    for field in want._fields:
        if not np.array_equal(np.asarray(getattr(want, field)),
                              np.asarray(getattr(got, field))):
            raise SystemExit(
                f"ADAPTIVE REGRESSION: disabled-mode SearchResult.{field} "
                "is not bit-identical to the non-adaptive loop"
            )


def run_smoke() -> dict:
    cfg = PageANNConfig(
        dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    x = clustered_vectors(1200, 32, num_clusters=16, seed=0)
    queries = query_vectors(x, 16, seed=1)
    idx = PageANNIndex.build(x, cfg)
    bit_identity_check(idx, queries, SearchParams.from_config(cfg))
    print("disabled-mode bit-identity: ok")
    rows = adaptive_rows(idx, x, queries, cfg, n_each=16)
    tuned = autotune_section(idx, x, queries, cfg, n_each=16,
                             recall_target=0.9)
    return dict(
        bench="adaptive", smoke=True,
        n=1200, dim=32, k=K,
        platform=platform.platform(),
        rows=rows, autotune=tuned,
    )


def run_full() -> dict:
    from benchmarks import common

    cfg = common.base_cfg()
    x, queries, _ = common.dataset()
    idx, acquired, acq_s = common.pageann_index_timed(x, cfg, "adaptive")
    print(f"index: {acquired} in {acq_s:.1f}s")
    bit_identity_check(idx, np.asarray(queries)[:16],
                       SearchParams.from_config(cfg))
    print("disabled-mode bit-identity: ok")
    rows = adaptive_rows(idx, x, queries, cfg, n_each=32)
    tuned = autotune_section(idx, x, queries, cfg, n_each=32,
                             recall_target=RECALL_TARGET)
    return dict(
        bench="adaptive",
        n=common.N, dim=common.D, k=K,
        platform=platform.platform(),
        rows=rows, autotune=tuned,
    )


def run(out: str | None = "BENCH_adaptive.json") -> list[str]:
    """Harness entry (``benchmarks.run``): full bench, CSV-ish rows."""
    doc = run_full()
    if out:
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
    rows = [
        f"adaptive_{r['label'].replace('-', '_').replace('+', '_')},"
        f"{r['us_per_query']:.1f},"
        f"recall={r['recall']:.3f};hops={r['mean_hops']:.2f};"
        f"ios={r['mean_ios']:.1f};qps={r['qps']:.0f}"
        for r in doc["rows"]
    ]
    t = doc["autotune"]
    rows.append(
        f"adaptive_autotuned,{t['tuned']['us_per_query']:.1f},"
        f"recall={t['tuned']['recall']:.3f};qps={t['tuned']['qps']:.0f};"
        f"grid_optimum_qps={t['grid_optimum']['qps']:.0f};"
        f"qps_vs_optimum={t['qps_vs_optimum']:.2f}"
    )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write BENCH_adaptive.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny CI gate: disabled-mode bit-identity + tuned-params "
             "recall floor",
    )
    args = ap.parse_args(argv)

    doc = run_smoke() if args.smoke else run_full()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    # gates (hard in --smoke, reported otherwise): adaptive rows must not
    # give up recall, and the tuned point must meet its floor
    base = doc["rows"][0]
    et = doc["rows"][1]
    tuned = doc["autotune"]["tuned"]
    target = doc["autotune"]["recall_target"]
    if args.smoke:
        if et["recall"] < base["recall"] - 0.002:
            raise SystemExit(
                f"ADAPTIVE REGRESSION: early-termination recall "
                f"{et['recall']:.4f} dropped more than 0.002 below "
                f"hand-picked {base['recall']:.4f}"
            )
        if et["mean_hops"] > base["mean_hops"]:
            raise SystemExit(
                f"ADAPTIVE REGRESSION: early termination did not reduce "
                f"mean hops ({et['mean_hops']:.2f} vs "
                f"{base['mean_hops']:.2f})"
            )
        if tuned["recall"] < target - 0.02:
            raise SystemExit(
                f"ADAPTIVE REGRESSION: tuned operating point recall "
                f"{tuned['recall']:.4f} misses its target {target} by "
                "more than 0.02 on the eval mix"
            )
        print(
            f"adaptive smoke ok: bit-identical when disabled; "
            f"ET hops {base['mean_hops']:.2f}->{et['mean_hops']:.2f} at "
            f"recall {et['recall']:.4f}; tuned point recall "
            f"{tuned['recall']:.4f} (target {target})"
        )


if __name__ == "__main__":
    main()
