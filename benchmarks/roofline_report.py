"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_sec(s):
    if s is None:
        return "-"
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def table(recs, mesh="pod16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "useful/HLO flops | peak GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skip":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped |  "
                f"{r['skip_reason']} | — | — |"
            )
            continue
        if r.get("status") != "ok" or "compute_s" not in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        ufr = r.get("useful_flops_ratio")
        rows.append(
            "| {a} | {s} | {c} | {m} | {x} | {b} | {u} | {p} | {f} |".format(
                a=r["arch"], s=r["shape"],
                c=fmt_sec(r.get("compute_s")), m=fmt_sec(r.get("memory_s")),
                x=fmt_sec(r.get("collective_s")), b=r.get("bottleneck", "?"),
                u=f"{ufr:.2f}" if ufr else "-",
                p=r.get("peak_gib_per_device", "-"),
                f="yes" if r.get("fits_hbm") else "NO",
            )
        )
    return "\n".join(rows)


def summary(recs) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    skip = [r for r in recs if r.get("status") == "skip"]
    err = [r for r in recs if r.get("status") not in ("ok", "skip")]
    return {"ok": len(ok), "skip": len(skip), "error": len(err)}


def main():
    recs = load()
    print(table(recs))
    print()
    print("multi-pod compile proof:")
    mp = [r for r in recs if r.get("mesh") == "pod2x16x16"]
    print(f"  ok={sum(1 for r in mp if r['status'] == 'ok')} "
          f"skip={sum(1 for r in mp if r['status'] == 'skip')} "
          f"err={sum(1 for r in mp if r['status'] not in ('ok', 'skip'))}")
    print("totals:", summary(recs))


if __name__ == "__main__":
    main()
