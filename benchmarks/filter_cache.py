"""Filtered search recall parity + semantic query cache throughput.

Two claims, one artifact (``BENCH_filter.json``):

1. **Recall parity** — enforcing a metadata predicate *inside* the page
   scan (filtered members scored ``+inf`` before the top-k merge, beam
   pow2-oversampled by measured selectivity) matches a post-filter brute
   force oracle across selectivities {0.5, 0.1, 0.01}, and
   ``filter=None`` stays bit-identical to an index built with no
   metadata at all. The filtered path is also checked bit-identical
   between the fully resident index and a save/load under a
   ``MemoryBudget`` (the PR-6 streamed tier).

2. **Semantic cache throughput** — a :class:`repro.serve.SemanticCache`
   in front of :class:`repro.serve.VectorService` on a Zipf-distributed
   query mix (repeat questions dominate, the RAG serving pattern) beats
   the uncached service by >= 2x QPS, and a write to the collection
   invalidates its cached answers.

Usage:
  PYTHONPATH=src python -m benchmarks.filter_cache --smoke --out BENCH_filter.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (
    MemoryBudget,
    MemoryMode,
    MetadataSchema,
    MutableIndex,
    Num,
    PageANNConfig,
    PageANNIndex,
    recall_at_k,
)
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.serve import SemanticCache, VectorService

K = 10
SELECTIVITIES = (0.5, 0.1, 0.01)
ZIPF_QUERIES = 400      # total requests in the cache mix
ZIPF_UNIQUE = 48        # distinct questions the mix draws from
ZIPF_EXPONENT = 1.1


# --------------------------------------------------------------- oracles
def filtered_truth(x: np.ndarray, q: np.ndarray, mask: np.ndarray, k: int):
    """Post-filter brute force: exact top-k restricted to passing rows."""
    idx = np.flatnonzero(mask)
    d = ((q[:, None, :] - x[idx][None]) ** 2).sum(-1)
    take = min(k, len(idx))
    order = np.argsort(d, axis=1)[:, :take]
    out = np.full((len(q), k), -1, np.int64)
    out[:, :take] = idx[order]
    return out


def results_equal(a, b) -> bool:
    return np.array_equal(np.asarray(a.ids), np.asarray(b.ids)) and np.array_equal(
        np.asarray(a.dists), np.asarray(b.dists)
    )


# --------------------------------------------------------- filter parity
def measure_filtered(idx, queries, expr, truth, label):
    idx.search(queries, K, filter=expr)  # compile
    t0 = time.perf_counter()
    res = idx.search(queries, K, filter=expr)
    dt = time.perf_counter() - t0
    rec = recall_at_k(res.ids, truth)
    _, sel = idx.compiled_filter(expr)
    return dict(
        label=label,
        selectivity=round(sel, 4),
        recall=round(float(rec), 4),
        us_per_query=round(dt / len(queries) * 1e6, 1),
    ), res


def run_filter_section(x, queries, cfg, tmpdir):
    """Parity rows + the two bit-identity gates. Returns (rows, ok)."""
    rng = np.random.default_rng(7)
    scores = rng.uniform(0.0, 1.0, len(x))
    schema = MetadataSchema(numerics=("score",))
    idx = PageANNIndex.build(x, cfg, schema=schema, metadata={"score": scores})

    rows, ok = [], True
    resident = {}
    for sel in SELECTIVITIES:
        thr = float(np.quantile(scores, sel))
        expr = Num("score").le(thr)
        mask = scores <= thr
        truth = filtered_truth(x, queries, mask, K)
        row, res = measure_filtered(idx, queries, expr, truth, f"sel={sel}")
        resident[sel] = (expr, res)
        ok = ok and row["recall"] >= 0.9
        rows.append(row)

    # filter=None must be bit-identical to an index built with no metadata
    plain = PageANNIndex.build(x, cfg)
    bit_ok = results_equal(idx.search(queries, K), plain.search(queries, K))
    ok = ok and bit_ok
    rows.append(dict(label="no_filter_bit_identity", passed=bool(bit_ok)))

    # streamed tier: save/load under a budget, filtered results identical
    import os

    d = os.path.join(tmpdir, "filter_bench.pageann")
    idx.save(d)
    streamed = PageANNIndex.load(d, memory_budget=MemoryBudget(fraction=0.25))
    stream_ok = all(
        results_equal(streamed.search(queries, K, filter=expr), res)
        for expr, res in resident.values()
    )
    ok = ok and stream_ok
    rows.append(dict(label="streamed_bit_identity", passed=bool(stream_ok)))
    return rows, ok


# ------------------------------------------------------------ cache mix
def zipf_mix(dim: int, x: np.ndarray, seed: int = 3):
    """A Zipf-distributed repeat-heavy query stream over a small pool of
    distinct questions — the shape a semantic cache is built for."""
    rng = np.random.default_rng(seed)
    pool = query_vectors(x, ZIPF_UNIQUE, seed=seed)
    ranks = rng.zipf(ZIPF_EXPONENT, size=ZIPF_QUERIES * 4)
    ranks = ranks[ranks <= ZIPF_UNIQUE][:ZIPF_QUERIES]
    while len(ranks) < ZIPF_QUERIES:  # zipf tail can overshoot the pool
        ranks = np.concatenate([ranks, ranks])[:ZIPF_QUERIES]
    return pool[ranks - 1]


def timed_qps(svc: VectorService, mix: np.ndarray) -> float:
    svc.search("docs", mix[:8])  # compile
    t0 = time.perf_counter()
    futs = [svc.submit("docs", q) for q in mix]
    svc.flush()
    for f in futs:
        f.result()
    return len(mix) / (time.perf_counter() - t0)


def run_cache_section(x, cfg):
    """QPS with/without the cache on the same Zipf mix + an invalidation
    check after a write. Returns (rows, ok)."""
    mix = zipf_mix(cfg.dim, x)
    base = PageANNIndex.build(x, cfg)

    with VectorService(batch_size=16) as svc:
        svc.create_collection("docs", MutableIndex(base), k=K)
        qps_plain = timed_qps(svc, mix)

    cache = SemanticCache(threshold=0.999)
    with VectorService(batch_size=16, semantic_cache=cache) as svc:
        svc.create_collection("docs", MutableIndex(base), k=K)
        qps_cached = timed_qps(svc, mix)
        m = svc.metrics()
        hits, misses = m.semantic_hits, m.semantic_misses

        # a write must invalidate: the hottest question re-asked after an
        # insert is a miss, not a stale hit
        hot = mix[0]
        svc.insert("docs", hot[None] + 0.5)
        fut = svc.submit("docs", hot)
        svc.flush()
        inval_ok = (not fut.result().cached) and (
            svc.metrics().semantic_invalidations > 0
        )

    speedup = qps_cached / max(qps_plain, 1e-9)
    ok = speedup >= 2.0 and inval_ok and hits > misses
    rows = [
        dict(
            label="semantic_cache_zipf",
            qps_uncached=round(qps_plain, 1),
            qps_cached=round(qps_cached, 1),
            speedup=round(speedup, 2),
            hits=hits,
            misses=misses,
            invalidation_ok=bool(inval_ok),
        )
    ]
    return rows, ok


# ------------------------------------------------------------- harness
def smoke_cfg() -> PageANNConfig:
    return PageANNConfig(
        dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )


def run_smoke():
    import tempfile

    x = clustered_vectors(1200, 32, num_clusters=16, seed=0)
    queries = query_vectors(x, 16, seed=1)
    with tempfile.TemporaryDirectory() as tmp:
        filter_rows, filter_ok = run_filter_section(x, queries, smoke_cfg(), tmp)
    cache_rows, cache_ok = run_cache_section(x, smoke_cfg())
    return filter_rows + cache_rows, filter_ok, cache_ok


def run_full():
    import tempfile

    import repro.core.vamana as vam
    from benchmarks import common

    x, queries, _ = common.dataset()
    cfg = common.base_cfg()
    # vamana dominates build time and is metadata-independent: share the
    # harness's cached graph across the three builds here
    nbrs = common.vamana_graph(x)
    orig = vam.build_vamana
    vam.build_vamana = lambda *a, **k: nbrs
    try:
        with tempfile.TemporaryDirectory() as tmp:
            filter_rows, filter_ok = run_filter_section(x, queries, cfg, tmp)
        cache_rows, cache_ok = run_cache_section(x, cfg)
    finally:
        vam.build_vamana = orig
    return filter_rows + cache_rows, filter_ok, cache_ok


def run(out: str = "BENCH_filter.json"):
    """Harness entry (benchmarks.run): full dataset, returns row strings."""
    rows, filter_ok, cache_ok = run_full()
    doc = dict(rows=rows, filter_ok=filter_ok, cache_ok=cache_ok)
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
    lines = []
    for r in rows:
        us = r.get("us_per_query", 0.0)
        detail = ";".join(f"{k}={v}" for k, v in r.items() if k != "label")
        lines.append(f"filter_{r['label']},{us},{detail}")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_filter.json")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    rows, filter_ok, cache_ok = run_smoke() if args.smoke else run_full()
    doc = dict(
        mode="smoke" if args.smoke else "full",
        rows=rows,
        filter_ok=filter_ok,
        cache_ok=cache_ok,
    )
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    for r in rows:
        print(r)
    print(f"wrote {args.out}")

    if args.smoke:
        if not filter_ok:
            raise SystemExit(
                "FILTER REGRESSION: filtered recall below parity or "
                "bit-identity gate failed (see rows above)"
            )
        if not cache_ok:
            raise SystemExit(
                "CACHE REGRESSION: semantic cache speedup < 2x or "
                "invalidation failed (see rows above)"
            )
        print("smoke gates passed: recall parity, bit identity, cache >=2x")


if __name__ == "__main__":
    main()
