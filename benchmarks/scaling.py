"""Paper Fig. 12: throughput/latency vs concurrency.

The paper scales query *threads*; the TPU-native analog is the vmapped
query batch dimension. Near-linear QPS scaling with batch = the same
property (fixed per-query work, amortized dispatch).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import recall_at_k
from repro.data.pipeline import query_vectors


def run() -> list[str]:
    x, q, truth = common.dataset()
    cfg = common.base_cfg()
    idx = common.pageann_index(x, cfg, "scale")
    rows = []
    base_qps = None
    for batch in (1, 4, 16, 64):
        qb = query_vectors(x, batch, seed=7)
        res, dt = common.timeit(lambda: idx.search(qb, k=10))
        qps = batch / dt
        if base_qps is None:
            base_qps = qps
        rows.append(
            f"scaling_batch{batch},{1e6 * dt / batch:.1f},"
            f"qps={qps:.0f};speedup_vs_b1={qps / base_qps:.2f}x"
        )
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
