"""Multi-collection serving: one VectorService vs N separate engines.

Builds N same-geometry collections (one config, one corpus size, distinct
data) and measures what the database-level API buys:

  * **marginal compile cost** — time-to-first-result per collection as it
    is added to one shared ``VectorService``. The first collection pays
    the jit compile for its geometry; every later same-geometry collection
    dispatches through the already-warm executable (the compile-cache
    hit/miss counters are recorded per step, and the expected shape is
    ``compile_misses_delta == 0`` from collection 1 on). The projected
    N-process cost — each process compiling its own executable — is
    ``N * first_collection_s`` and is reported alongside.
  * **steady-state throughput** — the same warm interleaved query stream
    (round-robin across collections) driven through the one service vs
    through N independent ``BatchingEngine.from_index`` instances, so the
    routing layer's overhead is visible (expected: parity — routing is a
    dict lookup, the searches are identical executables).
  * **recall@10 per collection** against brute force, and (``--smoke``)
    a hard bit-identity assertion: the service must return exactly what N
    independent engines return.

Results land in ``BENCH_multi.json``.

  PYTHONPATH=src python -m benchmarks.serve_database [--out BENCH_multi.json]
      [--smoke] [--collections N]

``--smoke`` is the CI gate: a tiny two-collection database, recall- and
bit-identity-gated, with a hard zero-marginal-compile assertion.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.core import PageANNIndex, recall_at_k
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.serve import BatchingEngine, VectorService

K = 10
ROUNDS = 3  # interleaved throughput rounds (min wall wins)


def _build_collections(c: int, n: int, dim: int, q: int, cfg):
    """C same-geometry corpora: one config, one size, distinct data."""
    cols = []
    for i in range(c):
        x = clustered_vectors(n, dim, num_clusters=max(8, n // 125), seed=i)
        queries = query_vectors(x, q, seed=100 + i)
        t0 = time.perf_counter()
        index = PageANNIndex.build(x, cfg)
        build_s = time.perf_counter() - t0
        cols.append(
            dict(
                name=f"c{i}", x=x, queries=queries, index=index,
                build_s=build_s, truth=brute_force_knn(x, queries, K),
            )
        )
    return cols


def _interleaved(submit_fn, cols, flush_fn) -> float:
    """Round-robin one query per collection until every stream drains;
    returns the wall seconds for the full interleave."""
    nq = len(cols[0]["queries"])
    t0 = time.perf_counter()
    futs = []
    for j in range(nq):
        for col in cols:
            futs.append(submit_fn(col["name"], col["queries"][j]))
    flush_fn()
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def run(cols: list[dict], batch_size: int) -> dict:
    c = len(cols)
    n, dim = cols[0]["x"].shape
    q = len(cols[0]["queries"])
    nq_total = q * c

    # ---- one shared service: per-collection marginal cost as it grows
    svc = VectorService(batch_size=batch_size)
    points = []
    prev = svc.metrics()
    for col in cols:
        t0 = time.perf_counter()
        svc.create_collection(col["name"], col["index"], k=K)
        rows = svc.search(col["name"], col["queries"])
        first_result_s = time.perf_counter() - t0
        m = svc.metrics()
        ids = np.stack([r.result.ids for r in rows])
        points.append(
            dict(
                collection=col["name"],
                build_s=col["build_s"],
                first_result_s=first_result_s,
                compile_misses_delta=m.compile_misses - prev.compile_misses,
                compile_hits_delta=m.compile_hits - prev.compile_hits,
                recall=recall_at_k(ids, col["truth"]),
                mean_ios=float(np.mean([np.asarray(r.result.ios) for r in rows])),
            )
        )
        prev = m
        pt = points[-1]
        print(
            f"{pt['collection']}: first_result={pt['first_result_s']:.3f}s  "
            f"compile_misses+={pt['compile_misses_delta']}  "
            f"hits+={pt['compile_hits_delta']}  recall={pt['recall']:.4f}"
        )

    # ---- steady-state interleaved throughput: service vs N engines
    svc_wall = min(
        _interleaved(
            lambda name, qq: svc.submit(name, qq, k=K), cols, svc.flush
        )
        for _ in range(ROUNDS)
    )
    svc_metrics = svc.metrics()
    svc.close()

    engines = {
        col["name"]: BatchingEngine.from_index(
            col["index"], k=K, batch_size=batch_size
        )
        for col in cols
    }
    try:
        eng_wall = min(
            _interleaved(
                lambda name, qq: engines[name].submit(qq, k=K),
                cols,
                lambda: [e.flush() for e in engines.values()],
            )
            for _ in range(ROUNDS)
        )
    finally:
        for e in engines.values():
            e.close()

    doc = dict(
        bench="serve_database",
        collections=c, n=n, dim=dim, queries=q, k=K,
        batch_size=batch_size,
        platform=platform.platform(),
        points=points,
        service_qps=nq_total / svc_wall,
        engines_qps=nq_total / eng_wall,
        qps_ratio=eng_wall / svc_wall,
        # what N one-index-per-process deployments would pay in compile
        # wall vs what the shared-cache service actually paid
        projected_nprocess_first_result_s=c * points[0]["first_result_s"],
        service_first_result_s=sum(p["first_result_s"] for p in points),
        compile=dict(
            hits=svc_metrics.compile_hits,
            misses=svc_metrics.compile_misses,
            executables=svc_metrics.compiled_executables,
        ),
    )
    print(
        f"interleaved x{c} collections: service {doc['service_qps']:.1f} qps "
        f"vs {c} engines {doc['engines_qps']:.1f} qps "
        f"(ratio {doc['qps_ratio']:.2f})"
    )
    print(
        f"compile wall: shared-cache service {doc['service_first_result_s']:.2f}s "
        f"vs projected {c}-process {doc['projected_nprocess_first_result_s']:.2f}s"
    )
    return doc


def _bit_identity_check(cols: list[dict], batch_size: int):
    """Service results must be byte-for-byte what independent engines
    return — routing adds a key, never a different dispatch."""
    with VectorService(batch_size=batch_size) as svc:
        for col in cols:
            svc.create_collection(col["name"], col["index"], k=K)
        got = {
            col["name"]: svc.search(col["name"], col["queries"])
            for col in cols
        }
    for col in cols:
        with BatchingEngine.from_index(
            col["index"], k=K, batch_size=batch_size
        ) as eng:
            want = eng.search(col["queries"])
        for g, w in zip(got[col["name"]], want):
            for field in ("ids", "dists", "ios", "hops", "cache_hits"):
                a = np.asarray(getattr(g.result, field))
                b = np.asarray(getattr(w.result, field))
                assert np.array_equal(a, b), (col["name"], field)
    print(f"bit-identity: service == {len(cols)} independent engines, "
          "all fields")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write BENCH_multi.json here")
    ap.add_argument("--collections", type=int, default=None)
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny CI gate: two-collection database, recall floor, "
             "bit-identity vs independent engines, zero marginal compiles",
    )
    args = ap.parse_args(argv)

    if args.smoke:
        from repro.core import MemoryMode, PageANNConfig

        c = args.collections or 2
        cfg = PageANNConfig(
            dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
            lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
            memory_mode=MemoryMode.HYBRID,
        )
        # build the collections ONCE and share them between the throughput
        # run and the bit-identity check (same seeds -> same data anyway)
        cols = _build_collections(c, 900, 32, 16, cfg)
        doc = run(cols, batch_size=8)
        _bit_identity_check(cols, batch_size=8)
    else:
        from benchmarks import common

        c = args.collections or 3
        cols = _build_collections(
            c, common.N, common.D, common.Q, common.base_cfg()
        )
        doc = run(cols, batch_size=64)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    if args.smoke:
        for pt in doc["points"][1:]:
            if pt["compile_misses_delta"] != 0:
                raise SystemExit(
                    f"MULTI-COLLECTION REGRESSION: {pt['collection']} compiled "
                    f"{pt['compile_misses_delta']} new executables — same-"
                    "geometry collections must share the warm cache"
                )
        floor = doc["points"][0]["recall"] - 0.02
        for pt in doc["points"]:
            if pt["recall"] < floor:
                raise SystemExit(
                    f"MULTI-COLLECTION REGRESSION: {pt['collection']} recall "
                    f"{pt['recall']:.4f} < {floor:.4f}"
                )
        print(
            f"serve_database smoke ok: {doc['collections']} collections, "
            "0 marginal compiles, recall + bit-identity gates passed"
        )


if __name__ == "__main__":
    main()
