"""Shared benchmark setup: datasets, disk-backed index builds, timing.

Index reuse goes through the lifecycle API: ``pageann_index`` saves the
built index to a cache directory (``PageANNIndex.save``) keyed by the
config, and later runs — including later *points of the same sweep in a
different process* — reload it with ``PageANNIndex.load`` instead of
rebuilding Vamana + PQ + packing. Runtime knobs (beam, io batch, LSH
top-T) are per-call ``SearchParams`` now, so a sweep over them shares ONE
cached artifact.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MemoryMode, PageANNConfig, PageANNIndex, recall_at_k
from repro.core import baselines as bl
from repro.core import persist
from repro.core import pq as pq_mod
from repro.core.vamana import brute_force_knn, build_vamana
from repro.data.pipeline import clustered_vectors, query_vectors

CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
N, D, Q = 8000, 32, 64


def dataset():
    x = clustered_vectors(N, D, num_clusters=64, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cache_path(tag: str) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, tag + ".pkl")


def cached(tag: str, build_fn):
    path = _cache_path(tag)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = build_fn()
    with open(path, "wb") as f:
        pickle.dump(obj, f)
    return obj


def base_cfg(**kw) -> PageANNConfig:
    base = dict(
        dim=D, graph_degree=24, build_beam=48, pq_subspaces=8,
        lsh_sample=1024, lsh_entries=12, beam_width=64, max_hops=64,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


def vamana_graph(x):
    """Shared Vamana graph (built once, pickled; keyed by the data — a
    same-shape dataset change must not resurrect a stale graph)."""
    def build():
        return build_vamana(x, degree=24, beam=48, seed=0)

    return cached(f"vamana_{len(x)}_{x.shape[1]}_{data_digest(x)}", build)


def cfg_digest(cfg: PageANNConfig) -> str:
    doc = dataclasses.asdict(cfg)
    doc["memory_mode"] = cfg.memory_mode.value
    return hashlib.sha256(repr(sorted(doc.items())).encode()).hexdigest()[:12]


def data_digest(x: np.ndarray) -> str:
    """The cache must be keyed on the data too: /tmp survives across code
    revisions, and a changed dataset silently loading a stale index would
    poison every downstream recall number."""
    h = hashlib.sha256(str(x.shape).encode())
    h.update(np.ascontiguousarray(x).tobytes())
    return h.hexdigest()[:12]


def build_pageann(x, cfg: PageANNConfig) -> PageANNIndex:
    """Build with the shared (pickled) Vamana graph substituted in —
    vamana dominates build time and is identical across sweep configs."""
    import repro.core.vamana as vam

    nbrs = vamana_graph(x)
    orig = vam.build_vamana
    vam.build_vamana = lambda *a, **k: nbrs
    try:
        return PageANNIndex.build(x, cfg)
    finally:
        vam.build_vamana = orig


def index_cache_path(tag: str, cfg: PageANNConfig, x: np.ndarray) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(
        CACHE, f"pageann_{tag}_{cfg_digest(cfg)}_{data_digest(x)}"
    )


def pageann_index_timed(
    x, cfg: PageANNConfig, tag: str
) -> tuple[PageANNIndex, str, float]:
    """Disk-backed build-once reuse: load the saved artifact when this
    (tag, config, data) was built before — by this run or a previous
    process. Returns (index, "load"|"build", acquisition seconds) so
    benchmarks can record what the lifecycle actually cost."""
    path = index_cache_path(tag, cfg, x)
    t0 = time.perf_counter()
    if persist.is_index_dir(path):
        idx, acquired = PageANNIndex.load(path), "load"
    else:
        idx, acquired = build_pageann(x, cfg), "build"
        idx.save(path)
    return idx, acquired, time.perf_counter() - t0


def pageann_index(x, cfg: PageANNConfig, tag: str) -> PageANNIndex:
    return pageann_index_timed(x, cfg, tag)[0]


def baseline_data(x):
    nbrs = vamana_graph(x)
    books = cached(
        f"pq_books_{data_digest(x)}",
        lambda: np.asarray(pq_mod.train_pq(x, 8, 256, 10)),
    )
    return nbrs, books


def timeit(fn, *args, repeats=3):
    import jax

    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
