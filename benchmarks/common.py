"""Shared benchmark setup: datasets, index builds (cached), timing."""
from __future__ import annotations

import hashlib
import os
import pickle
import time

import jax.numpy as jnp
import numpy as np

from repro.core import MemoryMode, PageANNConfig, PageANNIndex, recall_at_k
from repro.core import baselines as bl
from repro.core import pq as pq_mod
from repro.core.vamana import brute_force_knn, build_vamana
from repro.data.pipeline import clustered_vectors, query_vectors

CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
N, D, Q = 8000, 32, 64


def dataset():
    x = clustered_vectors(N, D, num_clusters=64, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cache_path(tag: str) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, tag + ".pkl")


def cached(tag: str, build_fn):
    path = _cache_path(tag)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = build_fn()
    with open(path, "wb") as f:
        pickle.dump(obj, f)
    return obj


def base_cfg(**kw) -> PageANNConfig:
    base = dict(
        dim=D, graph_degree=24, build_beam=48, pq_subspaces=8,
        lsh_sample=1024, lsh_entries=12, beam_width=64, max_hops=64,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


def vamana_graph(x):
    """Shared Vamana graph (built once, pickled)."""
    def build():
        return build_vamana(x, degree=24, beam=48, seed=0)

    return cached(f"vamana_{len(x)}_{x.shape[1]}", build)


def pageann_index(x, cfg: PageANNConfig, tag: str) -> PageANNIndex:
    # PageANNIndex holds jnp arrays; rebuild each run but reuse the graph
    # via monkeypatched build below (vamana dominates build time).
    import repro.core.index as index_mod
    import repro.core.vamana as vam

    nbrs = vamana_graph(x)
    orig = vam.build_vamana
    vam.build_vamana = lambda *a, **k: nbrs
    try:
        idx = PageANNIndex.build(x, cfg)
    finally:
        vam.build_vamana = orig
    return idx


def baseline_data(x):
    nbrs = vamana_graph(x)
    books = cached(
        "pq_books", lambda: np.asarray(pq_mod.train_pq(x, 8, 256, 10))
    )
    return nbrs, books


def timeit(fn, *args, repeats=3):
    import jax

    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
