"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring
for the paper artifact it reproduces). The roofline/dry-run tables live in
``roofline_report`` and read experiments/dryrun/*.json.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        adaptive,
        build_overhead,
        filter_cache,
        memory_sweep,
        read_amplification,
        recall_io,
        scaling,
        serve_throughput,
    )

    # benchmarks.search_hotpath, benchmarks.churn, and
    # benchmarks.serve_database are NOT registered here: CI runs each as
    # its own gated step (--check BENCH_serve.json / --smoke) right after
    # this harness, and registering them too would pay for their sweeps
    # twice. benchmarks.adaptive IS registered: its CI step runs only the
    # tiny --smoke gate (fresh 1200-vector index), so the full sweep here
    # is not duplicated work.
    modules = [
        ("table1_read_amplification", read_amplification),
        ("fig7_8_table3_recall_io", recall_io),
        ("fig10_11_table4_memory_sweep", memory_sweep),
        ("fig12_scaling", scaling),
        ("table5_build_overhead", build_overhead),
        ("adaptive_engine", adaptive),
        ("serve_throughput", serve_throughput),
        ("filter_cache", filter_cache),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for row in mod.run():
                print(row)
            print(f"{name}__wall,{1e6 * (time.perf_counter() - t0):.0f},ok")
        except Exception as e:
            failures += 1
            print(f"{name}__wall,0,FAILED:{e!r}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
