"""Paper Figs. 7/8 + Table 3: latency / throughput / mean I/Os vs recall@10.

Sweeps the runtime search knobs (beam L, LSH top-T) for PageANN and both
baselines; reports the full curve plus the Table-3-style comparison at
recall >= 0.9. All three systems are driven through the same
``VectorIndex`` protocol (``search(queries, k, params)``), and the PageANN
sweep runs over ONE built index: each point is a per-call ``SearchParams``
(a fresh jit executable, not a fresh index). The sweep wall-clock is
recorded both ways — build-once (measured) vs rebuild-per-point (what the
pre-lifecycle API paid, estimated from the measured single acquisition) —
into ``BENCH_recall_io.json`` so the API win is a tracked number.

Wall-clock QPS on this CPU container is a *relative* proxy (all three run
the same JAX/XLA substrate); the architecture-level metric is mean I/Os
per query.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from benchmarks import common
from repro.core import AdaptiveParams, SearchParams, recall_at_k
from repro.core import baselines as bl

# (beam L, LSH top-T) sweep — the paper's recall axis
PAGEANN_SWEEP = ((16, 4), (32, 8), (64, 12), (96, 16), (128, 24))
BASELINE_BEAMS = (16, 32, 64, 96, 128)


def _sweep_index(idx, q, truth, system: str, points) -> list[dict]:
    """One built index, one protocol, many ``SearchParams`` — no rebuilds."""
    out = []
    for params in points:
        res, dt = common.timeit(lambda: idx.search(q, params=params))
        out.append(
            dict(system=system, beam=params.beam_width,
                 entries=params.lsh_entries,
                 recall=recall_at_k(np.asarray(res.ids), truth),
                 ios=float(np.asarray(res.ios).mean()), qps=len(q) / dt,
                 ms=1000 * dt / len(q))
        )
    return out


def _curve_pageann(x, q, truth) -> tuple[list[dict], dict]:
    cfg = common.base_cfg()
    idx, acquired, acquire_s = common.pageann_index_timed(x, cfg, "recall_io")

    points = [
        SearchParams(k=10, beam_width=beam, io_batch=cfg.io_batch,
                     max_hops=cfg.max_hops, lsh_entries=entries)
        for beam, entries in PAGEANN_SWEEP
    ]
    t1 = time.perf_counter()
    curve = _sweep_index(idx, q, truth, "pageann", points)
    search_s = time.perf_counter() - t1
    timing = dict(
        acquired=acquired,              # "build" (cold cache) or "load"
        acquire_s=acquire_s,
        search_sweep_s=search_s,
        points=len(points),
        # the lifecycle-API workflow: one acquisition, N SearchParams
        build_once_wall_s=acquire_s + search_s,
    )
    if acquired == "build":
        # what the pre-SearchParams API paid: one full build per point
        # (only meaningful when this run actually measured a fresh build)
        timing["rebuild_per_point_wall_s_est"] = (
            len(points) * acquire_s + search_s
        )

    # adaptive rows over the SAME built index: hand-picked defaults vs the
    # same knobs with early termination vs the autotuned operating point —
    # the I/O-reduction claim of the adaptive engine as a tracked number
    from repro.data.pipeline import query_vectors

    hand = SearchParams.from_config(cfg)
    et = hand.replace(adaptive=AdaptiveParams(patience=2))
    adaptive = _sweep_index(idx, q, truth, "pageann_hand", [hand])
    adaptive += _sweep_index(idx, q, truth, "pageann_early_term", [et])
    tuned = idx.autotune(
        np.asarray(query_vectors(x, len(q), seed=2)),
        recall_target=0.95, k=10, patience_grid=(None, 2, 4),
    )["params"]
    adaptive += _sweep_index(idx, q, truth, "pageann_autotuned", [tuned])
    return curve + adaptive, timing


def _curve_baseline(x, q, truth, style: str) -> list[dict]:
    nbrs, books = common.baseline_data(x)
    if style == "starling":
        from repro.core.page_graph import group_pages

        cap = common.base_cfg().resolve_capacity()
        g = group_pages(x, nbrs, capacity=cap, h=2)
        idx = bl.StarlingIndex.from_data(x, nbrs, books, page_of=g.page_of)
    else:
        idx = bl.DiskANNIndex.from_data(x, nbrs, books)
    points = [
        SearchParams(k=10, beam_width=beam, max_hops=64)
        for beam in BASELINE_BEAMS
    ]
    return _sweep_index(idx, q, truth, style, points)


def _at_recall(curve, target=0.9):
    ok = [c for c in curve if c["recall"] >= target]
    return min(ok, key=lambda c: c["ios"]) if ok else None


def run(out: str | None = "BENCH_recall_io.json") -> list[str]:
    x, q, truth = common.dataset()
    pageann_curve, timing = _curve_pageann(x, q, truth)
    curves = (
        pageann_curve
        + _curve_baseline(x, q, truth, "diskann")
        + _curve_baseline(x, q, truth, "starling")
    )
    rows = []
    for c in curves:
        rows.append(
            f"recall_io_{c['system']}_beam{c['beam']},{1e6 * c['ms'] / 1000:.1f},"
            f"recall={c['recall']:.3f};ios={c['ios']:.1f};qps={c['qps']:.0f}"
        )
    est = timing.get("rebuild_per_point_wall_s_est")
    rows.append(
        f"recall_io_sweep_wall,{1e6 * timing['build_once_wall_s']:.0f},"
        f"acquired={timing['acquired']};"
        f"build_once_s={timing['build_once_wall_s']:.2f}"
        + (f";rebuild_per_point_s_est={est:.2f}" if est is not None else "")
    )
    # Table 3 analog at recall@10 >= 0.9
    best = {
        s: _at_recall([c for c in curves if c["system"] == s])
        for s in ("pageann", "diskann", "starling")
    }
    if all(best.values()):
        p, d, s = best["pageann"], best["diskann"], best["starling"]
        second = min(d, s, key=lambda c: c["ios"])
        rows.append(
            f"table3_at_r90,0.0,pageann_ios={p['ios']:.1f};second_best_ios={second['ios']:.1f};"
            f"io_reduction={100 * (1 - p['ios'] / second['ios']):.1f}%;"
            f"pageann_qps={p['qps']:.0f};diskann_qps={d['qps']:.0f};starling_qps={s['qps']:.0f}"
        )
    if out:
        doc = dict(
            bench="recall_io",
            n=common.N,
            dim=common.D,
            queries=common.Q,
            platform=platform.platform(),
            sweep_timing=timing,
            curves=curves,
        )
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_recall_io.json")
    args = ap.parse_args(argv)
    for r in run(out=args.out):
        print(r)


if __name__ == "__main__":
    main()
