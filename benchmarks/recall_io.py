"""Paper Figs. 7/8 + Table 3: latency / throughput / mean I/Os vs recall@10.

Sweeps the search beam for PageANN and both baselines; reports the full
curve plus the Table-3-style comparison at recall >= 0.9. Wall-clock QPS on
this CPU container is a *relative* proxy (all three run the same JAX/XLA
substrate); the architecture-level metric is mean I/Os per query.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import recall_at_k
from repro.core import baselines as bl


def _curve_pageann(x, q, truth):
    out = []
    for beam, entries in ((16, 4), (32, 8), (64, 12), (96, 16), (128, 24)):
        cfg = common.base_cfg(beam_width=beam, lsh_entries=entries)
        idx = common.pageann_index(x, cfg, f"rc_{beam}")
        res, dt = common.timeit(lambda: idx.search(q, k=10))
        out.append(
            dict(system="pageann", beam=beam,
                 recall=recall_at_k(res.ids, truth),
                 ios=float(res.ios.mean()), qps=len(q) / dt,
                 ms=1000 * dt / len(q))
        )
    return out


def _curve_baseline(x, q, truth, style):
    nbrs, books = common.baseline_data(x)
    if style == "starling":
        from repro.core.page_graph import group_pages

        cap = common.base_cfg().resolve_capacity()
        g = group_pages(x, nbrs, capacity=cap, h=2)
        data = bl.make_baseline_data(x, nbrs, books, page_of=g.page_of)
        fn = bl.starling_search
    else:
        data = bl.make_baseline_data(x, nbrs, books)
        fn = bl.diskann_search
    out = []
    qj = jnp.asarray(q)
    for beam in (16, 32, 64, 96, 128):
        res, dt = common.timeit(
            lambda: fn(qj, data, beam=beam, k=10, max_hops=64)
        )
        out.append(
            dict(system=style, beam=beam,
                 recall=recall_at_k(np.asarray(res.ids), truth),
                 ios=float(np.asarray(res.ios).mean()), qps=len(q) / dt,
                 ms=1000 * dt / len(q))
        )
    return out


def _at_recall(curve, target=0.9):
    ok = [c for c in curve if c["recall"] >= target]
    return min(ok, key=lambda c: c["ios"]) if ok else None


def run() -> list[str]:
    x, q, truth = common.dataset()
    curves = (
        _curve_pageann(x, q, truth)
        + _curve_baseline(x, q, truth, "diskann")
        + _curve_baseline(x, q, truth, "starling")
    )
    rows = []
    for c in curves:
        rows.append(
            f"recall_io_{c['system']}_beam{c['beam']},{1e6 * c['ms'] / 1000:.1f},"
            f"recall={c['recall']:.3f};ios={c['ios']:.1f};qps={c['qps']:.0f}"
        )
    # Table 3 analog at recall@10 >= 0.9
    best = {
        s: _at_recall([c for c in curves if c["system"] == s])
        for s in ("pageann", "diskann", "starling")
    }
    if all(best.values()):
        p, d, s = best["pageann"], best["diskann"], best["starling"]
        second = min(d, s, key=lambda c: c["ios"])
        rows.append(
            f"table3_at_r90,0.0,pageann_ios={p['ios']:.1f};second_best_ios={second['ios']:.1f};"
            f"io_reduction={100 * (1 - p['ios'] / second['ios']):.1f}%;"
            f"pageann_qps={p['qps']:.0f};diskann_qps={d['qps']:.0f};starling_qps={s['qps']:.0f}"
        )
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
