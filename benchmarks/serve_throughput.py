"""Serving-path throughput: the batching engine swept over batch sizes.

Drives the request stream of ``repro.serve.BatchingEngine`` (one query per
``submit``, fixed-shape dispatch, demux) at batch sizes 1/8/64 and reports
QPS, p50/p99 request latency, and mean disk page reads per query — the
serving analogue of the paper's Fig. 7 throughput axis. ``main`` records the
sweep to BENCH_serve.json so later PRs have a perf trajectory to beat.

  PYTHONPATH=src python -m benchmarks.serve_throughput [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from benchmarks import common
from repro.core import recall_at_k
from repro.serve import BatchingEngine

BATCH_SIZES = (1, 8, 64)
K = 10


def _drive(index, queries: np.ndarray, batch_size: int) -> dict:
    """Stream every query through a fresh engine; return the sweep point."""
    # warm the jit cache so compile time doesn't pollute the latency stats
    with BatchingEngine.from_index(index, k=K, batch_size=batch_size) as warm:
        warm.search(queries[:batch_size])

    with BatchingEngine.from_index(index, k=K, batch_size=batch_size) as engine:
        t0 = time.perf_counter()
        futures = [engine.submit(q) for q in queries]
        engine.flush()
        rows = [f.result() for f in futures]
        wall = time.perf_counter() - t0
        m = engine.metrics()
    ids = np.stack([r.result.ids for r in rows])
    return dict(
        batch_size=batch_size,
        qps=len(queries) / wall,
        p50_ms=m.latency_ms_p50,
        p99_ms=m.latency_ms_p99,
        mean_ios=m.mean_ios,
        batches=m.batches,
        occupancy=m.mean_batch_occupancy,
        _ids=ids,
    )


def sweep(batch_sizes=BATCH_SIZES) -> list[dict]:
    x, q, truth = common.dataset()
    index = common.pageann_index(x, common.base_cfg(), "serve")
    points = []
    for bs in batch_sizes:
        pt = _drive(index, q, bs)
        pt["recall"] = recall_at_k(pt.pop("_ids"), truth)
        points.append(pt)
    return points


def run() -> list[str]:
    rows = []
    for pt in sweep():
        rows.append(
            f"serve_batch{pt['batch_size']},{1e3 * pt['p50_ms']:.1f},"
            f"qps={pt['qps']:.0f};p99_ms={pt['p99_ms']:.1f};"
            f"ios={pt['mean_ios']:.1f};recall={pt['recall']:.3f}"
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write BENCH_serve.json here")
    args = ap.parse_args(argv)
    points = sweep()
    for pt in points:
        print(
            f"batch={pt['batch_size']:3d}  qps={pt['qps']:8.1f}  "
            f"p50={pt['p50_ms']:7.2f}ms  p99={pt['p99_ms']:7.2f}ms  "
            f"ios={pt['mean_ios']:5.1f}  recall={pt['recall']:.3f}"
        )
    if args.out:
        doc = dict(
            bench="serve_throughput",
            n=common.N,
            dim=common.D,
            queries=common.Q,
            k=K,
            platform=platform.platform(),
            points=points,
        )
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
