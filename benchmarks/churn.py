"""Mixed read-write churn benchmark for the mutable index.

Drives a ``core.delta.MutableIndex`` through a 95/5 read-write workload
(the classic serving mix): reads are batched top-10 searches through the
unified fresh+disk path, writes are small insert batches plus base-id
deletes (tombstones). At several delta-fill levels it records

  * read throughput (QPS) and write throughput (vectors/s) of the mixed
    loop,
  * mean disk I/Os per query (the delta scan adds zero page reads — I/O
    stays flat as the delta fills; the scan cost shows up in QPS),
  * recall@10 against brute-force ground truth over the CURRENT live set
    (base ∪ inserts − deletes),

then triggers ``compact()`` and records the post-compaction operating
point (delta folded in, tombstones gone) plus the compaction wall time.
Results land in ``BENCH_churn.json``.

  PYTHONPATH=src python -m benchmarks.churn [--out BENCH_churn.json]
      [--smoke]

``--smoke`` is the CI gate: a tiny dataset, a few hundred inserts +
deletes and one compaction, with a hard recall assertion.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from repro.core import MutableIndex, PageANNIndex, recall_at_k
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

K = 10
READ_BATCH = 16
READ_FRACTION = 0.95
# one write op inserts INSERT_CHUNK vectors; every DELETE_EVERY write ops
# also deletes one live base id (tombstone pressure rides along)
INSERT_CHUNK = 8
DELETE_EVERY = 4


class _Workload:
    """The dataset split into a built base and an insert stream, with a
    live mask tracking (base ∪ inserts − deletes). External ids are row
    indices into the full dataset, so ground truth is a brute-force scan
    over the live rows."""

    def __init__(self, x: np.ndarray, queries: np.ndarray, n_base: int,
                 seed: int = 7):
        self.x = x
        self.queries = queries
        self.live = np.zeros(len(x), bool)
        self.live[:n_base] = True
        self.cursor = n_base          # next stream row to insert
        self.rng = np.random.default_rng(seed)

    def insert_op(self, index: MutableIndex) -> int:
        take = min(INSERT_CHUNK, len(self.x) - self.cursor)
        if take == 0:
            return 0
        rows = np.arange(self.cursor, self.cursor + take)
        index.insert(self.x[rows], ids=rows)
        self.live[rows] = True
        self.cursor += take
        return take

    def delete_op(self, index: MutableIndex, n_base: int) -> int:
        live_base = np.nonzero(self.live[:n_base])[0]
        if live_base.size == 0:
            return 0
        victim = self.rng.choice(live_base, size=1)
        index.delete(victim)
        self.live[victim] = False
        return 1

    def recall(self, index: MutableIndex) -> float:
        live_rows = np.nonzero(self.live)[0]
        truth_local = brute_force_knn(self.x[live_rows], self.queries, K)
        truth = live_rows[truth_local]
        res = index.search(self.queries, k=K)
        return recall_at_k(np.asarray(res.ids), truth)


def _mixed_phase(
    index: MutableIndex, work: _Workload, n_base: int, target_fraction: float
) -> dict:
    """Run the 95/5 mix until the delta reaches ``target_fraction`` of the
    base; returns throughput/IO measured over the whole phase."""
    reads_per_write = round(READ_FRACTION / (1 - READ_FRACTION))
    queries = work.queries
    nq = queries.shape[0]
    t0 = time.perf_counter()
    q_done = 0
    v_written = 0
    ios = []
    writes = 0
    while index.delta_fraction < target_fraction and work.cursor < len(work.x):
        v_written += work.insert_op(index)
        writes += 1
        if writes % DELETE_EVERY == 0:
            v_written += work.delete_op(index, n_base)
        for r in range(reads_per_write):
            lo = (q_done % nq)
            batch = np.take(
                queries, range(lo, lo + READ_BATCH), axis=0, mode="wrap"
            )
            res = index.search(batch, k=K)
            ios.append(np.asarray(res.ios))
            q_done += READ_BATCH
    wall = time.perf_counter() - t0
    return dict(
        read_qps=q_done / wall if wall > 0 else 0.0,
        write_vps=v_written / wall if wall > 0 else 0.0,
        queries=q_done,
        writes=v_written,
        mean_ios=float(np.concatenate(ios).mean()) if ios else 0.0,
        wall_s=wall,
    )


def _point(index: MutableIndex, work: _Workload, phase: str, **extra) -> dict:
    s = index.stats
    return dict(
        phase=phase,
        delta_fraction=round(index.delta_fraction, 4),
        delta_live=s.delta_live,
        tombstones=s.tombstones,
        base_rows=s.base_rows,
        generation=s.generation,
        recall=work.recall(index),
        **extra,
    )


def run(
    n: int, n_base: int, dim: int, q: int, fill_levels, cfg
) -> dict:
    x = clustered_vectors(n, dim, num_clusters=max(8, n // 125), seed=0)
    queries = query_vectors(x, q, seed=1)

    t0 = time.perf_counter()
    base = PageANNIndex.build(x[:n_base], cfg)
    build_s = time.perf_counter() - t0

    index = MutableIndex(base, auto_compact=False)
    work = _Workload(x, queries, n_base)

    # static reference point: the read-only path before any write
    static = index.search(queries, k=K)
    points = [
        _point(
            index, work, "static",
            read_qps=0.0, write_vps=0.0,
            mean_ios=float(np.asarray(static.ios).mean()),
        )
    ]
    for level in fill_levels:
        mixed = _mixed_phase(index, work, n_base, level)
        points.append(_point(index, work, "churn", **mixed))
        pt = points[-1]
        print(
            f"fill={pt['delta_fraction']:.3f}  read_qps={pt['read_qps']:8.1f}  "
            f"write_vps={pt['write_vps']:7.1f}  ios={pt['mean_ios']:6.2f}  "
            f"recall={pt['recall']:.4f}  (tombstones={pt['tombstones']})"
        )

    t0 = time.perf_counter()
    compacted = index.compact()
    compact_s = time.perf_counter() - t0
    post = index.search(queries, k=K)
    points.append(
        _point(
            index, work, "post_compact",
            read_qps=0.0, write_vps=0.0,
            mean_ios=float(np.asarray(post.ios).mean()),
            compact_s=compact_s, compacted=compacted,
        )
    )
    pt = points[-1]
    print(
        f"post-compact: gen={pt['generation']} ios={pt['mean_ios']:6.2f} "
        f"recall={pt['recall']:.4f} (rebuild {compact_s:.1f}s)"
    )
    return dict(
        bench="churn",
        n=n, n_base=n_base, dim=dim, queries=q, k=K,
        read_fraction=READ_FRACTION,
        read_batch=READ_BATCH,
        base_build_s=build_s,
        platform=platform.platform(),
        points=points,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write BENCH_churn.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny CI gate: few hundred inserts+deletes, one compaction, "
             "hard recall assertion",
    )
    args = ap.parse_args(argv)

    if args.smoke:
        from repro.core import MemoryMode, PageANNConfig

        cfg = PageANNConfig(
            dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
            lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
            memory_mode=MemoryMode.HYBRID,
        )
        doc = run(
            n=1200, n_base=900, dim=32, q=16,
            fill_levels=(0.1, 0.2, 0.32), cfg=cfg,
        )
    else:
        from benchmarks import common

        doc = run(
            n=common.N, n_base=int(common.N * 0.8), dim=common.D,
            q=common.Q, fill_levels=(0.05, 0.125, 0.25),
            cfg=common.base_cfg(),
        )

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    if args.smoke:
        static_recall = doc["points"][0]["recall"]
        floor = static_recall - 0.02
        for pt in doc["points"]:
            if pt["recall"] < floor:
                raise SystemExit(
                    f"CHURN REGRESSION: {pt['phase']} recall {pt['recall']:.4f}"
                    f" < static {static_recall:.4f} - 0.02"
                )
        last = doc["points"][-1]
        assert last["phase"] == "post_compact" and last["generation"] >= 1
        assert last["tombstones"] == 0 and last["delta_live"] == 0
        print(
            f"churn smoke ok: recall stayed >= {floor:.4f} across "
            f"{len(doc['points'])} points incl. one compaction"
        )


if __name__ == "__main__":
    main()
