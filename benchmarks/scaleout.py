"""Scale-out load generator: QPS-vs-shards against the HTTP frontend.

The scale-out claim is end to end: a data-sharded collection
(``repro.dist.ShardedPageStore``) served through the network frontend
(``repro.serve.http.HttpFrontend`` via ``repro.launch.serve
--http-port``) answers MORE queries per second than the unsharded index
at recall parity, and the admission-control surface (deadline sheds,
in-flight 503s) actually sheds. This module is the external driver: the
server runs in a SEPARATE process per shard count, and every request
travels real HTTP + JSON.

Per shard count S in (1, 2, 4) it:

  * builds (or reloads from the bench cache) a one-collection database —
    unsharded ``PageANNIndex`` at S=1, ``ShardedPageStore`` otherwise,
  * spawns ``python -m repro.launch.serve --smoke --db-dir ...
    --http-port 0 --serve-forever``, scraping the printed frontend URL,
  * hammers ``POST /search`` with the full query batch for R rounds,
    recording QPS, wall-clock percentiles and recall@10,
  * on the 2-shard server, exercises load shedding: a batch with a
    sub-millisecond ``deadline_ms`` must come back 504 with the engine's
    ``sheds`` counter advanced, and a concurrent stampede against
    ``--max-inflight 2`` must surface 503s in
    ``pageann_http_rejected_total{reason="inflight"}`` — both asserted
    from a real ``/metrics`` scrape.

Hard gates (CI): 2- and 4-shard recall within 0.02 of unsharded, QPS
scaling >= 1.6x at 2 shards, shed counters advanced, exposition parses.
Results land in ``BENCH_scaleout.json``.

  PYTHONPATH=src python -m benchmarks.scaleout [--smoke]
      [--out BENCH_scaleout.json]

``--smoke`` only trims the number of timed rounds — the gates and the
dataset are the full ones (the QPS ratio needs the real corpus).
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import os
import platform
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np

from benchmarks.common import (
    CACHE,
    base_cfg,
    cfg_digest,
    data_digest,
    dataset,
    pageann_index,
)
from repro.core import persist, recall_at_k
from repro.obs import parse_prometheus_text, sample_value

K = 10
SHARD_COUNTS = (1, 2, 4)
SCALING_FLOOR_2SHARD = 1.6
RECALL_PARITY_SLACK = 0.02
SERVER_START_TIMEOUT_S = 600


# --------------------------------------------------------------- databases
def _db_dir(tag: str, cfg, x) -> str:
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(
        CACHE, f"scaleout_{tag}_{cfg_digest(cfg)}_{data_digest(x)}"
    )


def build_databases(x, cfg) -> dict[int, str]:
    """One single-collection database directory per shard count, cached
    on disk across runs (keyed by config + data)."""
    from repro.dist import ShardedPageStore

    dirs = {}
    for s in SHARD_COUNTS:
        d = _db_dir(f"s{s}", cfg, x)
        if not persist.is_database_dir(d):
            if s == 1:
                index = pageann_index(x, cfg, "scaleout")
            else:
                index = ShardedPageStore.build(x, cfg, num_shards=s)
            persist.save_database({"wiki": index}, d)
        dirs[s] = d
    return dirs


# ------------------------------------------------------------------ server
class Frontend:
    """One ``repro.launch.serve --serve-forever`` subprocess + its URL."""

    def __init__(self, db_dir: str, *, batch: int, max_inflight: int):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONUNBUFFERED"] = "1"
        self.proc = subprocess.Popen(
            [
                sys.executable, "-m", "repro.launch.serve", "--smoke",
                "--db-dir", db_dir, "--http-port", "0", "--serve-forever",
                "--batch", str(batch), "--max-inflight", str(max_inflight),
            ],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env,
        )
        self.url = None
        self._lines: list[str] = []
        deadline = time.monotonic() + SERVER_START_TIMEOUT_S
        for line in self.proc.stdout:
            self._lines.append(line)
            if line.startswith("frontend: "):
                self.url = line.split(" ", 1)[1].strip()
                break
            if time.monotonic() > deadline or self.proc.poll() is not None:
                break
        if self.url is None:
            err = self.proc.stderr.read() if self.proc.stderr else ""
            self.close()
            raise RuntimeError(
                "server never printed its frontend URL\n--- stdout ---\n"
                + "".join(self._lines[-30:]) + "\n--- stderr ---\n"
                + err[-3000:]
            )
        # keep draining stdout so the server never blocks on a full pipe
        self._drain = threading.Thread(
            target=lambda: [None for _ in self.proc.stdout], daemon=True
        )
        self._drain.start()

    def close(self) -> None:
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=10)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def post(url: str, doc: dict, timeout: float = 300.0):
    req = urllib.request.Request(
        url, json.dumps(doc).encode(), {"Content-Type": "application/json"}
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def get(url: str, timeout: float = 60.0) -> bytes:
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read()


# -------------------------------------------------------------- load phases
def timed_rounds(url: str, q: np.ndarray, truth, rounds: int) -> dict:
    """R sequential full-batch search requests; returns QPS + percentiles
    + recall of the last response."""
    payload = {"collection": "wiki", "queries": q.tolist(), "k": K}
    code, doc = post(url + "/search", payload)   # warm (excluded)
    if code != 200:
        raise RuntimeError(f"warm search failed: {code} {doc}")
    walls = []
    t0 = time.perf_counter()
    for _ in range(rounds):
        t1 = time.perf_counter()
        code, doc = post(url + "/search", payload)
        walls.append((time.perf_counter() - t1) * 1e3)
        if code != 200:
            raise RuntimeError(f"timed search failed: {code} {doc}")
    wall_s = time.perf_counter() - t0
    ids = np.array([r["ids"] for r in doc["results"]])
    walls = np.asarray(walls)
    return dict(
        qps=rounds * len(q) / wall_s,
        recall=recall_at_k(ids, truth),
        wall_ms_mean=float(walls.mean()),
        wall_ms_p50=float(np.percentile(walls, 50)),
        wall_ms_p99=float(np.percentile(walls, 99)),
        requests=rounds,
        queries_per_request=len(q),
    )


def exercise_shedding(url: str, q: np.ndarray) -> dict:
    """Deadline sheds (504 + engine ``sheds``) and in-flight 503s, both
    confirmed from the /metrics exposition."""
    # 1) queue-deadline expiry: a microsecond deadline cannot survive the
    #    submit->flush gap, so every row sheds and the request is 504
    code, doc = post(url + "/search", {
        "collection": "wiki", "queries": q.tolist(), "k": K,
        "deadline_ms": 0.001,
    })
    deadline_code = code
    # 2) in-flight cap: a stampede of concurrent batches against
    #    --max-inflight 2 must shed some requests with 503
    payload = {"collection": "wiki", "queries": q[:8].tolist(), "k": K}
    with concurrent.futures.ThreadPoolExecutor(max_workers=8) as pool:
        codes = list(pool.map(
            lambda _: post(url + "/search", payload)[0], range(16)
        ))
    parsed = parse_prometheus_text(get(url + "/metrics").decode())
    sheds = sample_value(parsed, "pageann_sheds_total")
    try:
        rejected_inflight = sample_value(
            parsed, "pageann_http_rejected_total", reason="inflight"
        )
    except KeyError:
        rejected_inflight = 0.0
    try:
        rejected_deadline = sample_value(
            parsed, "pageann_http_rejected_total", reason="deadline"
        )
    except KeyError:
        rejected_deadline = 0.0
    return dict(
        deadline_code=deadline_code,
        stampede_codes=sorted(set(codes)),
        http_503=sum(c == 503 for c in codes),
        sheds_total=sheds,
        rejected_inflight=rejected_inflight,
        rejected_deadline=rejected_deadline,
        metrics_series=len(parsed),
    )


# -------------------------------------------------------------------- main
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scaleout.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: fewer timed rounds, same gates")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args(argv)
    rounds = args.rounds or (4 if args.smoke else 8)

    x, q, truth = dataset()
    cfg = base_cfg()
    dirs = build_databases(x, cfg)

    points = []
    shed = None
    for s in SHARD_COUNTS:
        with Frontend(dirs[s], batch=len(q), max_inflight=2) as fe:
            point = dict(shards=s, db_dir=dirs[s], **timed_rounds(
                fe.url, q, truth, rounds
            ))
            stats = json.loads(get(fe.url + "/stats"))
            m = stats.get("metrics", {})
            point["server"] = {
                key: m.get(key) for key in (
                    "requests", "batches", "sheds", "compile_misses",
                    "mean_batch_occupancy",
                )
            }
            if s == 2:
                shed = exercise_shedding(fe.url, q)
            points.append(point)
            print(
                f"shards={s}: qps={point['qps']:.0f} "
                f"recall={point['recall']:.3f} "
                f"p50={point['wall_ms_p50']:.1f}ms "
                f"p99={point['wall_ms_p99']:.1f}ms"
            )

    base = next(p for p in points if p["shards"] == 1)
    scaling = {
        str(p["shards"]): p["qps"] / base["qps"]
        for p in points if p["shards"] != 1
    }
    doc = dict(
        bench="scaleout",
        host=dict(
            platform=platform.platform(),
            python=platform.python_version(),
        ),
        collection="wiki",
        k=K,
        rounds=rounds,
        points=points,
        scaling_vs_unsharded=scaling,
        shed=shed,
    )

    # ------------------------------------------------------------- gates
    failures = []
    for p in points:
        if p["shards"] == 1:
            continue
        if p["recall"] < base["recall"] - RECALL_PARITY_SLACK:
            failures.append(
                f"recall parity: {p['shards']}-shard {p['recall']:.3f} < "
                f"unsharded {base['recall']:.3f} - {RECALL_PARITY_SLACK}"
            )
    if scaling.get("2", 0.0) < SCALING_FLOOR_2SHARD:
        failures.append(
            f"qps scaling at 2 shards {scaling.get('2', 0.0):.2f}x < "
            f"{SCALING_FLOOR_2SHARD}x"
        )
    if shed is None:
        failures.append("shed exercise never ran")
    else:
        if shed["deadline_code"] != 504:
            failures.append(
                f"deadline batch answered {shed['deadline_code']}, want 504"
            )
        if shed["sheds_total"] < len(q):
            failures.append(
                f"engine sheds_total {shed['sheds_total']} < {len(q)} "
                "(deadline batch not counted)"
            )
        if shed["http_503"] < 1 or shed["rejected_inflight"] < 1:
            failures.append(
                "in-flight stampede produced no 503 sheds "
                f"(503s={shed['http_503']}, "
                f"rejected={shed['rejected_inflight']})"
            )
        if shed["metrics_series"] < 10:
            failures.append(
                f"/metrics exposition suspiciously small "
                f"({shed['metrics_series']} series)"
            )
    doc["gates"] = dict(
        scaling_floor_2shard=SCALING_FLOOR_2SHARD,
        recall_parity_slack=RECALL_PARITY_SLACK,
        failures=failures,
    )
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {args.out}; scaling: " + ", ".join(
        f"{s} shards {v:.2f}x" for s, v in sorted(scaling.items())
    ))
    if failures:
        raise SystemExit("scaleout gates FAILED:\n  " + "\n  ".join(failures))
    print("scaleout gates ok")


if __name__ == "__main__":
    main()
