"""Paper Table 1: read amplification (bytes fetched / bytes useful).

PageANN fetches whole pages whose entire content (member vectors + topology
+ on-page compressed neighbors) is consumed by Alg. 2 — amplification ~1 by
construction (padding only). DiskANN-style traversal fetches a 4 KB page per
expanded node but uses only that node's (vector + adjacency) record.

The PageANN "padded" figure is the packed record tile actually DMA'd per
hop (``PageStore.recs``, densely packed members + f32-lane neighbor codes
+ counts — see ``layout.pack_page_records``), so the ratio reports the real
lane-padding overhead of the TPU mapping, not a hypothetical tight packing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import MemoryMode, recall_at_k
from repro.core import baselines as bl


def run() -> list[str]:
    x, q, truth = common.dataset()
    rows = []

    cfg = common.base_cfg(memory_mode=MemoryMode.DISK_ONLY)
    idx = common.pageann_index(x, cfg, "ra_disk")
    res = idx.search(q, k=10)
    logical = idx.store.logical_page_bytes(cfg)
    padded = idx.store.padded_tile_bytes()
    # every byte of the logical page record is consumed by the search
    ra_pageann = padded / logical
    rows.append(
        f"read_amp_pageann,{ra_pageann:.2f},recall={recall_at_k(res.ids, truth):.3f}"
        f";ios={res.ios.mean():.1f};logical={logical};padded={padded}"
    )

    nbrs, books = common.baseline_data(x)
    data = bl.make_baseline_data(x, nbrs, books)
    bres = bl.diskann_search(jnp.asarray(q), data, beam=64, k=10, max_hops=64)
    used = x.shape[1] * 4 + nbrs.shape[1] * 4         # vector + adjacency
    ra_diskann = 4096 / used
    rows.append(
        f"read_amp_diskann,{ra_diskann:.2f},recall={recall_at_k(np.asarray(bres.ids), truth):.3f}"
        f";ios={np.asarray(bres.ios).mean():.1f};used_per_read={used}"
    )

    # Starling-style: co-located pages, opportunistic full-page use on hit
    from repro.core.page_graph import group_pages

    g = group_pages(x, nbrs, capacity=idx.store.capacity, h=2)
    sdata = bl.make_baseline_data(x, nbrs, books, page_of=g.page_of)
    sres = bl.starling_search(jnp.asarray(q), sdata, beam=64, k=10, max_hops=64)
    # unique-page reads; each page contributes ~capacity co-located vectors,
    # but topology still requires per-node records -> partial utility
    util = (idx.store.capacity * x.shape[1] * 4) / 4096
    rows.append(
        f"read_amp_starling,{1.0 / min(util, 1.0):.2f},recall="
        f"{recall_at_k(np.asarray(sres.ids), truth):.3f};ios={np.asarray(sres.ios).mean():.1f}"
    )
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
