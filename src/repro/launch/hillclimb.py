import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (§Perf): re-runs a dry-run cell under named
optimization variants and records before/after roofline terms.

Variants (composable):
  zero1      — hoist FSDP param all-gather out of the microbatch loop
  bf16       — bf16 activations + compute-dtype weight casts
  attn_pairs — triangular pair-scan attention (exact causal FLOPs)
  chunks<q>x<k> — attention chunk shape override

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch granite-3-2b \
      --shape train_4k --variants baseline zero1 zero1+bf16
"""
import argparse
import json
import traceback

from repro.launch.dryrun import dryrun_cell


def variant_kwargs(variant: str) -> dict:
    kw: dict = {"arch_overrides": {}, "zero1": False}
    for part in variant.split("+"):
        if part == "baseline":
            continue
        elif part == "zero1":
            kw["zero1"] = True
        elif part == "bf16":
            kw["arch_overrides"]["activation_dtype"] = "bfloat16"
        elif part == "attn_pairs":
            kw["arch_overrides"]["attn_pairs"] = True
        elif part.startswith("chunks"):
            qc, kc = part[len("chunks"):].split("x")
            kw["arch_overrides"]["q_chunk"] = int(qc)
            kw["arch_overrides"]["kv_chunk"] = int(kc)
        elif part.startswith("remat-"):
            kw["arch_overrides"]["remat"] = part.split("-", 1)[1]
        elif part == "repkv":
            kw["arch_overrides"]["replicate_kv"] = True
        elif part.startswith("padheads"):
            # pad head counts up to a mesh-divisible multiple (extra wo rows
            # are zero in a real deployment -> numerically exact); removes
            # the replicated-attention fallback for e.g. 56- or 40-head archs
            n = int(part[len("padheads"):])
            kw["arch_overrides"]["num_heads"] = n
            # MHA archs pad kv heads alongside
            kw["_pad_kv"] = n
        else:
            raise ValueError(f"unknown variant part '{part}'")
    return kw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", nargs="+", default=["baseline"])
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for variant in args.variants:
        tag = f"{args.arch}_{args.shape}_{variant}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    print(f"skip existing {tag}")
                    continue
        try:
            kw = variant_kwargs(variant)
            pad_kv = kw.pop("_pad_kv", None)
            if pad_kv is not None:
                from repro.configs.registry import get_arch

                base = get_arch(args.arch)
                if base.num_kv_heads == base.num_heads:  # MHA: pad kv too
                    kw["arch_overrides"]["num_kv_heads"] = pad_kv
            rec = dryrun_cell(
                args.arch, args.shape, multi_pod=False, **kw
            )
            rec["variant"] = variant
        except Exception as e:
            rec = {"arch": args.arch, "shape": args.shape, "variant": variant,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"FAIL {tag}: {e!r}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
