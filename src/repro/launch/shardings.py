"""Input/state sharding builders for the dry-run and the drivers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.sharding import Rules, param_specs


def _dp(rules: Rules, size: int):
    """dp axes if the dim is divisible, else replicate."""
    import numpy as np

    dp_size = int(np.prod([rules.mesh.shape[a] for a in rules.dp]))
    if size % dp_size == 0:
        return rules.dp if len(rules.dp) > 1 else rules.dp[0]
    return None


def batch_specs(arch: ArchConfig, shape: ShapeConfig, rules: Rules) -> dict:
    from repro.models.frontend import train_input_specs

    specs = train_input_specs(arch, shape)
    out = {}
    for k, v in specs.items():
        if k == "positions3":
            out[k] = P(None, _dp(rules, v.shape[1]), None)
        else:
            out[k] = P(_dp(rules, v.shape[0]), *([None] * (len(v.shape) - 1)))
    return out


def state_specs(state_shapes, rules: Rules):
    """TrainState sharding: params by PARAM_RULES; optimizer moments follow
    their parameter's layout (same tree structure rank-matched)."""
    params_spec = param_specs(state_shapes.params, rules)

    def moment_spec(path, leaf):
        # AdamW m/v mirror params exactly; Adafactor vr/vc drop trailing dims
        del path
        return None

    # opt_state: match by structure — AdamW: m, v same spec as params;
    # Adafactor: vr (param rank-1), vc (rank-2 + last dim) — derive by rank.
    def derive(spec_tree, leaf_tree):
        flat_specs = jax.tree.leaves(spec_tree)
        flat_params = jax.tree.leaves(state_shapes.params)
        by_shape = list(zip(flat_params, flat_specs))

        def match(leaf):
            shape = tuple(leaf.shape)
            for p, s in by_shape:
                ps = tuple(p.shape)
                if shape == ps:
                    return s
                if shape == ps[:-1]:  # adafactor vr
                    return P(*tuple(s)[:-1])
                if len(ps) >= 2 and shape == ps[:-2] + ps[-1:]:  # vc
                    return P(*(tuple(s)[:-2] + tuple(s)[-1:]))
            return P()

        return jax.tree.map(match, leaf_tree)

    opt = state_shapes.opt_state
    if hasattr(opt, "m"):  # AdamW
        opt_spec = type(opt)(
            step=P(),
            m=jax.tree.map(lambda s: s, params_spec),
            v=jax.tree.map(lambda s: s, params_spec),
        )
    else:  # Adafactor
        opt_spec = type(opt)(
            step=P(),
            vr=derive(params_spec, opt.vr),
            vc=derive(params_spec, opt.vc),
        )
    from repro.train.step import TrainState

    return TrainState(params=params_spec, opt_state=opt_spec, step=P())


def cache_spec_tree(cache_shapes, arch: ArchConfig, rules: Rules):
    """KV / SSM / RG-LRU cache shardings (see DESIGN §6 serving notes):
    batch over dp when divisible; KV *sequence* over 'model' (flash-
    decoding style split-KV); SSM heads / recurrence width over 'model'."""

    def leaf_spec(path, leaf):
        name = None
        for p in reversed(path):
            key = getattr(p, "key", None)
            if isinstance(key, str):
                name = key
                break
        shape = tuple(leaf.shape)
        nd = len(shape)
        from repro.models.sharding import fix_spec

        if name in ("k", "v"):
            core = (_dp(rules, shape[nd - 4]), "model", None, None)
            spec = P(*((None,) * (nd - 4) + core))
        elif name == "conv":
            core = (_dp(rules, shape[nd - 3]), None, "model")
            spec = P(*((None,) * (nd - 3) + core))
        elif name == "ssd":
            core = (_dp(rules, shape[nd - 4]), "model", None, None)
            spec = P(*((None,) * (nd - 4) + core))
        elif name == "h":
            core = (_dp(rules, shape[nd - 2]), "model")
            spec = P(*((None,) * (nd - 2) + core))
        else:
            raise KeyError(f"no cache rule for {name}")
        return fix_spec(spec, shape, rules.mesh)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
