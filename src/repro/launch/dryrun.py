import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions, and compiles for the production meshes — and extract its
roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST precede any other import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_arch
from repro.launch import roofline as rf
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.shardings import (
    batch_specs,
    cache_spec_tree,
    state_specs,
    to_shardings,
)
from repro.models.frontend import decode_input_specs, train_input_specs
from repro.models.sharding import Rules
from repro.models.transformer import init_cache
from repro.train.step import init_train_state, make_serve_step, make_train_step

SDS = jax.ShapeDtypeStruct

# >=400 GB of params: shard FSDP across the pod axis too (DESIGN §6).
_FSDP_POD_THRESHOLD = 400e9


def _rules(mesh, arch) -> Rules:
    return Rules(
        mesh,
        fsdp_over_pod=arch.param_count() >= _FSDP_POD_THRESHOLD,
        replicate_kv=arch.replicate_kv,
    )


def lower_train(arch, shape, mesh, zero1: bool = False):
    rules = _rules(mesh, arch)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(arch, jax.random.PRNGKey(0))
    )
    state_spec = state_specs(state_shapes, rules)
    state_sh = to_shardings(state_spec, mesh)
    b_spec = batch_specs(arch, shape, rules)
    b_sh = to_shardings(b_spec, mesh)
    step_fn = make_train_step(arch, shape, rules, zero1=zero1)
    batch_sds = train_input_specs(arch, shape)
    with mesh:
        lowered = jax.jit(
            step_fn,
            in_shardings=(state_sh, b_sh),
            donate_argnums=(0,),
        ).lower(state_shapes, batch_sds)
    return lowered


def lower_serve(arch, shape, mesh):
    """decode_* / long_*: one new token against a seq_len cache."""
    rules = _rules(mesh, arch)
    params_shapes = jax.eval_shape(
        lambda: init_train_state(arch, jax.random.PRNGKey(0))
    ).params
    from repro.models.sharding import param_specs

    params_sh = to_shardings(param_specs(params_shapes, rules), mesh)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(arch, shape.global_batch, shape.seq_len)
    )
    cache_sh = to_shardings(cache_spec_tree(cache_shapes, arch, rules), mesh)
    ins = decode_input_specs(arch, shape)
    serve = make_serve_step(arch)
    args = [params_shapes, cache_shapes, ins["token"], ins["pos"]]
    shardings = [params_sh, cache_sh, None, None]
    if arch.mrope:
        args.append(ins["positions3"])
        shardings.append(None)
    with mesh:
        lowered = jax.jit(
            serve,
            in_shardings=tuple(shardings),
            donate_argnums=(1,),
        ).lower(*args)
    return lowered


def lower_prefill(arch, shape, mesh):
    """prefill_32k: full forward over the prompt (logits)."""
    import dataclasses

    arch = dataclasses.replace(arch, attn_fwd_only=True)
    rules = _rules(mesh, arch)
    params_shapes = jax.eval_shape(
        lambda: init_train_state(arch, jax.random.PRNGKey(0))
    ).params
    from repro.models.sharding import param_specs
    from repro.models.transformer import forward_train

    params_sh = to_shardings(param_specs(params_shapes, rules), mesh)
    specs = train_input_specs(arch, shape)
    specs.pop("labels")
    b_spec = {
        k: v for k, v in batch_specs(arch, shape, rules).items() if k != "labels"
    }
    b_sh = to_shardings(b_spec, mesh)

    def prefill_fn(params, batch):
        logits, _ = forward_train(params, batch, arch, rules=rules)
        return logits

    with mesh:
        lowered = jax.jit(
            prefill_fn, in_shardings=(params_sh, b_sh)
        ).lower(params_shapes, specs)
    return lowered


import dataclasses


def _units(arch) -> int:
    """Scan units: hybrid archs scan blocks, everything else scans layers."""
    if arch.family == "hybrid":
        return (arch.num_layers - len(arch.tail_pattern)) // len(arch.block_pattern)
    return arch.num_layers


def _with_units(arch, n: int):
    if arch.family == "hybrid":
        L = n * len(arch.block_pattern) + len(arch.tail_pattern)
    else:
        L = n
    return dataclasses.replace(arch, num_layers=L, unroll_loops=True)


def _cost_of(lowered) -> dict:
    compiled = lowered.compile()
    hlo = compiled.as_text()
    return rf.cost_terms(compiled, hlo)


_COST_KEYS = ("hlo_flops", "hlo_bytes", "collective_bytes")


def _lin(c1: dict, c2: dict, u1: int, u2: int, u: float) -> dict:
    """Linear extrapolation of cost counters in the unit count. A negative
    slope is nonphysical (cost_analysis noise from folding/aliasing) —
    fall back to per-unit proportional scaling."""
    out = {}
    for k in _COST_KEYS:
        slope = (c2[k] - c1[k]) / (u2 - u1)
        if slope < 0:
            out[k] = c2[k] / u2 * u
        else:
            out[k] = max(c1[k] + slope * (u - u1), 0.0)
    return out


def _sub(a: dict, b: dict) -> dict:
    return {k: a[k] - b[k] for k in _COST_KEYS}


def _add(a: dict, b: dict, scale: float = 1.0) -> dict:
    return {k: max(a[k] + scale * b[k], 0.0) for k in _COST_KEYS}


def calibrated_counters(arch, shape, mesh, zero1: bool = False) -> dict:
    """True per-step flop/byte/collective counters, extrapolated from small
    fully-unrolled lowerings (XLA cost_analysis counts loop bodies once, so
    the full lowering's counters are NOT trip-count aware; see §Dry-run).

    Train: cost(L, m) = O(L) + m * S(L) with O, S linear in scan units —
    four calibration points. Prefill/decode: linear in units — two points.
    """
    u1, u2 = 1, 2
    if shape.kind == "train":
        from repro.train.step import effective_microbatches

        num_mb = effective_microbatches(shape, _rules(mesh, arch))
        shape = dataclasses.replace(shape, num_microbatches=num_mb)
        mb_batch = shape.global_batch // num_mb
        sh1 = dataclasses.replace(
            shape, global_batch=mb_batch, num_microbatches=1
        )
        sh2 = dataclasses.replace(
            shape, global_batch=2 * mb_batch, num_microbatches=2
        )
        p = {}
        for u in (u1, u2):
            a = _with_units(arch, u)
            p[(u, 1)] = _cost_of(lower_train(a, sh1, mesh, zero1=zero1))
            p[(u, 2)] = _cost_of(lower_train(a, sh2, mesh, zero1=zero1))
        s1 = _sub(p[(u1, 2)], p[(u1, 1)])   # one extra microbatch at u1
        s2 = _sub(p[(u2, 2)], p[(u2, 1)])
        o1 = _sub(p[(u1, 1)], s1)           # mb-independent part at u1
        o2 = _sub(p[(u2, 1)], s2)
        uf = _units(arch)
        s_full = _lin(s1, s2, u1, u2, uf)
        o_full = _lin(o1, o2, u1, u2, uf)
        return _add(o_full, s_full, scale=shape.num_microbatches)
    # prefill / decode
    if shape.kind == "prefill":
        calib = lambda a: dataclasses.replace(
            a, q_chunk=4096, kv_chunk=4096
        )  # fewer unrolled chunk bodies; flop totals are chunk-size invariant
        c1 = _cost_of(lower_prefill(calib(_with_units(arch, u1)), shape, mesh))
        c2 = _cost_of(lower_prefill(calib(_with_units(arch, u2)), shape, mesh))
    else:
        c1 = _cost_of(lower_serve(_with_units(arch, u1), shape, mesh))
        c2 = _cost_of(lower_serve(_with_units(arch, u2), shape, mesh))
    return _lin(c1, c2, u1, u2, _units(arch))


def dryrun_cell(
    arch_id: str, shape_id: str, multi_pod: bool, verbose=True,
    arch_overrides: dict | None = None, zero1: bool = False,
) -> dict:
    arch = get_arch(arch_id)
    if arch_overrides:
        arch = dataclasses.replace(arch, **arch_overrides)
    shape = SHAPES[shape_id]
    ok, why = shape_applicable(arch, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch_id, "shape": shape_id, "mesh": mesh_name,
        "status": "skip" if not ok else None, "skip_reason": why or None,
    }
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    if shape.kind == "train":
        lowered = lower_train(arch, shape, mesh, zero1=zero1)
    elif shape.kind == "prefill":
        lowered = lower_prefill(arch, shape, mesh)
    else:
        lowered = lower_serve(arch, shape, mesh)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    hlo = compiled.as_text()
    terms = rf.cost_terms(compiled, hlo)
    mem = rf.memory_stats(compiled)
    mf = rf.model_flops(arch, shape)
    n_dev = mesh.size
    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        devices=n_dev,
        model_flops_global=mf,
        model_flops_per_device=mf / n_dev,
        hlo_bytes_text=len(hlo),
        raw_loop_body_terms=terms,       # trip-count-blind (structure only)
        collective_breakdown=terms["collective_breakdown"],
        collective_counts=terms["collective_counts"],
        memory=mem,
    )
    # the roofline table is single-pod only; calibration is the expensive
    # part, so multi-pod cells stop at the compile proof.
    if not multi_pod:
        t3 = time.perf_counter()
        calib = calibrated_counters(arch, shape, mesh, zero1=zero1)
        t4 = time.perf_counter()
        cterms = rf.terms_from_counters(calib)
        rec.update(
            calib_s=round(t4 - t3, 2),
            **cterms,                    # calibrated, trip-count-true
            useful_flops_ratio=(mf / n_dev) / cterms["hlo_flops"]
            if cterms["hlo_flops"] else None,
        )
    peak = mem.get("peak_bytes_per_device")
    if peak is not None:
        rec["fits_hbm"] = bool(peak <= HBM_BYTES)
        rec["peak_gib_per_device"] = round(peak / 2**30, 3)
    if verbose:
        print(json.dumps({k: v for k, v in rec.items() if k != "memory"}))
        print("memory:", mem)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for aid in archs:
        for sid in shapes:
            for mp in meshes:
                tag = f"{aid}_{sid}_{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skip"):
                            continue
                try:
                    rec = dryrun_cell(aid, sid, mp)
                except Exception as e:
                    failures += 1
                    rec = {
                        "arch": aid, "shape": sid,
                        "mesh": "pod2x16x16" if mp else "pod16x16",
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    print(f"FAIL {tag}: {e!r}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
