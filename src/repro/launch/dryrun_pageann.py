import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S OWN workload at production scale: the sharded
PageANN index (SIFT100M-like: 100M x 128 uint8->f32, 4 KB pages) lowered
and compiled on the production meshes.

The search loop is data-dependent (while_loop), so cost_analysis reports
one *hop-batch body*; the roofline row multiplies by the measured mean hop
count from the CPU benchmark (recall_io) — recorded in EXPERIMENTS.md
§Roofline as the pageann-serve rows.

  PYTHONPATH=src python -m repro.launch.dryrun_pageann --mesh both
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MemoryMode, PageANNConfig
from repro.core import distributed as dist
from repro.core import layout as layout_mod
from repro.core import search as search_mod
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh

SDS = jax.ShapeDtypeStruct

# SIFT100M geometry (paper Table 2) under the Sec 4.2 page equation
N_VECTORS = 100_000_000
DIM = 128
QUERY_BATCH = 1024
MEAN_HOPS = 18.0        # measured by benchmarks/recall_io on the CPU proxy


def synthetic_sharded_specs(cfg: PageANNConfig, num_shards: int):
    cap = cfg.resolve_capacity()
    per_shard = N_VECTORS // num_shards
    pages = -(-per_shard // cap)
    n_pad = pages * cap
    rp, m = cfg.page_degree, cfg.pq_subspaces
    m_mem = 2 * m
    s = num_shards
    # MEM_ALL records carry no on-page code rows (codes live in memory)
    m_rec = 0 if cfg.memory_mode == MemoryMode.MEM_ALL else m
    rec_rows = layout_mod.record_rows(cap, DIM, m_rec)
    data = search_mod.SearchData(
        page_recs=SDS((s, pages, rec_rows, layout_mod.PAGE_LANES), jnp.float32),
        member_count=SDS((s, pages), jnp.int32),
        nbr_ids=SDS((s, pages, rp), jnp.int32),
        nbr_count=SDS((s, pages), jnp.int32),
        resident_map=SDS((s, pages), jnp.int32),
        mem_codes=SDS((s, n_pad, m_mem), jnp.uint8),
        mem_mask=SDS((s, n_pad), jnp.bool_),
        mem_codebooks=SDS((s, m_mem, cfg.pq_ksub, DIM // m_mem), jnp.float32),
        disk_codebooks=SDS((s, m, cfg.pq_ksub, DIM // m), jnp.float32),
        cached_pages=SDS((s, 4096), jnp.int32),
        lsh_planes=SDS((s, DIM, cfg.lsh_bits), jnp.float32),
        lsh_ids=SDS((s, cfg.lsh_sample), jnp.int32),
        lsh_codes=SDS((s, cfg.lsh_sample, cfg.lsh_bits // 32), jnp.uint32),
        lsh_pq=SDS((s, cfg.lsh_sample, m), jnp.uint8),
    )
    return data, cap, pages


def run(multi_pod: bool, mode: str = "hybrid", io_batch: int = 5) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard_axis_size = mesh.shape["data"]
    cfg = PageANNConfig(
        dim=DIM, graph_degree=32, page_degree=48, pq_subspaces=16,
        lsh_sample=262_144, lsh_bits=64, lsh_entries=32,
        beam_width=128, io_batch=io_batch, max_hops=64,
        memory_mode=MemoryMode(mode),
    )
    data, cap, pages = synthetic_sharded_specs(cfg, shard_axis_size)
    queries = SDS((QUERY_BATCH, DIM), jnp.float32)
    fn, in_shard = dist.make_sharded_search(mesh, cfg, cap, k=10)
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(
            lambda d, q: fn(d, q), in_shardings=in_shard
        ).lower(data, queries)
        compiled = lowered.compile()
    t1 = time.perf_counter()
    hlo = compiled.as_text()
    body = rf.cost_terms(compiled, hlo)
    mem = rf.memory_stats(compiled)
    # per-query totals: body counters are per while-iteration (hop batch)
    scaled = {
        "hlo_flops": body["hlo_flops"] * MEAN_HOPS,
        "hlo_bytes": body["hlo_bytes"] * MEAN_HOPS,
        "collective_bytes": body["collective_bytes"],  # merge happens once
    }
    terms = rf.terms_from_counters(scaled)
    rec = {
        "arch": "pageann-sift100m", "shape": f"serve_q{QUERY_BATCH}",
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "mode": mode, "io_batch": io_batch,
        "status": "ok",
        "devices": mesh.size,
        "pages_per_shard": pages, "page_capacity": cap,
        "compile_s": round(t1 - t0, 2),
        "mean_hops_assumed": MEAN_HOPS,
        "raw_loop_body_terms": body,
        **terms,
        "memory": mem,
    }
    peak = mem.get("peak_bytes_per_device")
    if peak is not None:
        from repro.launch.mesh import HBM_BYTES

        rec["peak_gib_per_device"] = round(peak / 2**30, 3)
        rec["fits_hbm"] = bool(peak <= HBM_BYTES)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="hybrid",
                    choices=[m.value for m in MemoryMode])
    ap.add_argument("--io-batch", type=int, default=5)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for mp in {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]:
        rec = run(mp, mode=args.mode, io_batch=args.io_batch)
        suffix = "" if (args.mode == "hybrid" and args.io_batch == 5) \
            else f"_{args.mode}_b{args.io_batch}"
        tag = f"pageann_serve_{'multi' if mp else 'single'}{suffix}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: v for k, v in rec.items() if k != "memory"}))


if __name__ == "__main__":
    main()
