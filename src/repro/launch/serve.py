"""Serving driver: batched prefill + greedy decode, optionally retrieval-
augmented through a PERSISTED vector index (the paper's system as a
first-class serving feature — see examples/serve_rag.py for the full RAG
loop). ``--index-dir`` loads a saved index (``PageANNIndex.save`` /
``DiskANNIndex.save`` / ``StarlingIndex.save`` / ``MutableIndex.save``
artifact — whichever kind the manifest names) through the ``VectorIndex``
lifecycle and retrieves neighbor ids for every prompt embedding before
decoding: the build-once / serve-many workflow, no index rebuild in the
serving process.

``--db-dir`` loads a whole multi-collection DATABASE
(``VectorService.load`` over a ``db.json`` artifact — see
``repro.serve.service``) instead of one index: every prompt's retrieval is
routed to a named collection through ONE shared service. ``--route`` picks
the routing — a comma-separated list of ``:collection``-prefixed entries
cycled over the prompt batch (e.g. ``--route :wiki,:notes`` sends prompt
0 to ``wiki``, prompt 1 to ``notes``, prompt 2 to ``wiki``, …); it
defaults to round-robin over every collection in the database.

``--memory-budget`` serves the index (or every database collection) under
an out-of-HBM memory budget: only the hottest page records stay resident
on device, the rest stream from the artifact's ``pages.bin`` memmap per
hop — same results, bounded footprint (see ``repro.core.MemoryBudget``).

``--mutable`` wraps the loaded index in a ``core.delta.MutableIndex`` (a
loaded mutable artifact is already one) and exercises the write path
end to end: the prompt embeddings are INSERTED as fresh documents through
``engine.insert``, retrieved back (each prompt now finds itself), then
DELETED again — the serving process takes writes without an index rebuild.

``--semantic-cache THRESHOLD`` (with ``--db-dir``) puts a
``repro.serve.SemanticCache`` in front of the service and replays the
prompt retrievals to demonstrate similarity hits: repeat queries within
the cosine threshold of an answered one skip the dispatch entirely.

Observability (``repro.obs``): ``--metrics-port PORT`` starts the stdlib
HTTP sidecar serving ``/metrics`` (Prometheus text exposition),
``/healthz`` and ``/stats`` next to the serving loop (0 = ephemeral
port, printed); ``--trace-out FILE`` threads a request tracer through
the engine/service and writes the capture as Chrome ``trace_event`` JSON
(open in Perfetto, or render with ``python -m repro.obs.report``);
``--obs-selfcheck`` scrapes the process's own sidecar over real HTTP and
asserts the exposition parses and its counters reconcile with
``metrics()`` — the CI smoke gate.

Usage (CPU smoke; --arch defaults to granite-3-2b):
  PYTHONPATH=src python -m repro.launch.serve --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--index-dir idx.pageann] \
      [--mutable] [--db-dir db/ [--route :wiki,:notes] [--semantic-cache 0.98]]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models import transformer as tf
from repro.train.step import init_train_state


def generate(params, arch, prompts: jnp.ndarray, gen: int):
    """Teacher-forced prefill then greedy decode. prompts: (B, T)."""
    B, T = prompts.shape
    max_len = T + gen
    cache = tf.init_cache(arch, B, max_len)
    # prefill token-by-token through the decode path (cache-exact)
    tok = prompts[:, 0]
    logits = None
    for t in range(T):
        logits, cache = tf.decode_step(params, cache, prompts[:, t], jnp.int32(t), arch)
    out = [jnp.argmax(logits[:, : arch.vocab_size], -1).astype(jnp.int32)]
    for t in range(T, T + gen - 1):
        logits, cache = tf.decode_step(params, cache, out[-1], jnp.int32(t), arch)
        out.append(jnp.argmax(logits[:, : arch.vocab_size], -1).astype(jnp.int32))
    return jnp.stack(out, axis=1)


def _start_obs(args, source):
    """Start the metrics sidecar over ``source`` (an engine or service)
    when ``--metrics-port`` was given. Returns the server or None."""
    if args.metrics_port is None:
        return None
    from repro.obs import MetricsServer, serve_registry

    registry = serve_registry(source)
    server = MetricsServer(
        registry, source=source, port=args.metrics_port
    )
    print(f"metrics sidecar: {server.url}/metrics (+ /healthz, /stats)")
    return server


def _parse_rate_limits(specs):
    """['wiki=200:400', 'notes=50'] -> {'wiki': (200.0, 400.0),
    'notes': (50.0, 50.0)} (burst defaults to the rate)."""
    out = {}
    for spec in specs or ():
        name, _, rhs = spec.partition("=")
        if not name or not rhs:
            raise SystemExit(f"--rate-limit {spec!r}: want COLL=RATE[:BURST]")
        rate, _, burst = rhs.partition(":")
        try:
            r = float(rate)
            b = float(burst) if burst else r
        except ValueError:
            raise SystemExit(f"--rate-limit {spec!r}: bad number")
        out[name] = (r, b)
    return out


def _start_frontend(args, svc):
    """Warm each collection's serving executable, then open the network
    frontend — external load must not pay first-dispatch compile."""
    from repro.serve.http import HttpFrontend

    for name in svc.list_collections():
        dim = svc.index_of(name).dim
        svc.search(name, np.zeros((1, dim), np.float32))
    frontend = HttpFrontend(
        svc,
        port=args.http_port,
        max_inflight=args.max_inflight,
        default_deadline_ms=args.default_deadline_ms,
        rate_limits=_parse_rate_limits(args.rate_limit),
    )
    # the load generator greps this line for the bound address
    print(f"frontend: {frontend.url}", flush=True)
    return frontend


def _obs_selfcheck(server, source):
    """Scrape the process's own sidecar over real HTTP and reconcile the
    exposition against a fresh ``metrics()`` snapshot (no concurrent
    traffic at this point, so the counters must agree exactly)."""
    import json
    import urllib.request

    from repro.obs import parse_prometheus_text, sample_value

    if urllib.request.urlopen(f"{server.url}/healthz").read() != b"ok\n":
        raise SystemExit("obs selfcheck: /healthz did not answer ok")
    text = urllib.request.urlopen(f"{server.url}/metrics").read().decode()
    parsed = parse_prometheus_text(text)     # raises on malformed lines
    m = source.metrics()
    checks = {
        "pageann_requests_total": m.requests,
        "pageann_batches_total": m.batches,
        "pageann_compile_misses_total": m.compile_misses,
        "pageann_early_exits_total": m.early_exits,
        "pageann_collections": m.collections,
    }
    for name, want in checks.items():
        got = sample_value(parsed, name)     # KeyError if the series is gone
        if got != float(want):
            raise SystemExit(
                f"obs selfcheck: {name} exposed {got}, metrics() says {want}"
            )
    if sample_value(parsed, "pageann_request_latency_ms_count") < m.requests:
        raise SystemExit(
            "obs selfcheck: latency histogram lost requests"
        )
    stats = json.loads(
        urllib.request.urlopen(f"{server.url}/stats").read()
    )
    if "metrics" not in stats:
        raise SystemExit("obs selfcheck: /stats payload has no metrics")
    print(
        f"obs selfcheck ok: {len(parsed)} series, "
        f"{m.requests} requests reconciled"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--index-dir", default=None,
        help="saved VectorIndex directory: retrieve neighbor ids for each "
             "prompt embedding through the loaded index before decoding",
    )
    ap.add_argument("--retrieve-k", type=int, default=3)
    ap.add_argument(
        "--mutable", action="store_true",
        help="serve the index through the mutable delta tier and exercise "
             "engine.insert / engine.delete with the prompt embeddings",
    )
    ap.add_argument(
        "--db-dir", default=None,
        help="saved VectorService database directory (db.json): serve every "
             "collection from one process and route each prompt's retrieval",
    )
    ap.add_argument(
        "--route", default=None,
        help="comma-separated :collection entries cycled over the prompt "
             "batch (e.g. ':wiki,:notes'); default round-robins every "
             "collection in the database",
    )
    ap.add_argument(
        "--memory-budget", default=None,
        help="cap the device-resident page region of the loaded index / of "
             "each database collection: bytes ('268435456', '256MB') or a "
             "fraction of the page file ('0.25'); pages beyond the budget "
             "stream from the pages.bin memmap per hop with bit-identical "
             "results. Default: fully resident",
    )
    ap.add_argument(
        "--semantic-cache", type=float, default=None, metavar="THRESHOLD",
        help="(with --db-dir) put a semantic query cache in front of the "
             "service: repeat prompt embeddings within this cosine "
             "similarity of an answered one are served from the cache "
             "instead of dispatching (e.g. 0.98). Hit/miss counters are "
             "printed with the metrics. Default: no cache",
    )
    ap.add_argument(
        "--recall-target", type=float, default=None,
        help="serve the index with the autotuned operating point meeting "
             "this recall (the manifest 'tuned' section written by "
             "PageANNIndex.autotune) instead of hand-picked SearchParams. "
             "With --index-dir an artifact with no qualifying tuned point "
             "fails loudly; with --db-dir collections without one keep "
             "their own defaults",
    )
    ap.add_argument(
        "--metrics-port", type=int, default=None, metavar="PORT",
        help="start the repro.obs HTTP sidecar on this port serving "
             "/metrics (Prometheus text), /healthz and /stats (0 = pick "
             "an ephemeral port and print it). Default: no sidecar",
    )
    ap.add_argument(
        "--http-port", type=int, default=None, metavar="PORT",
        help="(with --db-dir) start the network frontend on this port: "
             "POST /search /insert /delete + GET /collections over the "
             "loaded database, with admission control and per-collection "
             "QoS; /metrics, /healthz and /stats are mounted on the same "
             "port (0 = ephemeral, printed as 'frontend: URL')",
    )
    ap.add_argument(
        "--max-inflight", type=int, default=64,
        help="frontend admission control: maximum concurrently admitted "
             "requests; excess requests are shed with 503 (default 64)",
    )
    ap.add_argument(
        "--default-deadline-ms", type=float, default=None, metavar="MS",
        help="frontend: default per-request queue deadline; a request "
             "still queued when it expires completes with 504 and counts "
             "as an engine shed. Per-request 'deadline_ms' overrides",
    )
    ap.add_argument(
        "--rate-limit", action="append", default=None,
        metavar="COLL=RATE[:BURST]",
        help="frontend QoS: token-bucket limit for one collection "
             "(requests/s, optional burst, e.g. 'wiki=200:400'); repeat "
             "per collection. Unlisted collections are unlimited",
    )
    ap.add_argument(
        "--serve-forever", action="store_true",
        help="(with --http-port) block serving HTTP until interrupted "
             "instead of exiting after the smoke retrievals — the mode "
             "an external load generator drives",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="FILE",
        help="thread a request tracer through the serving path and write "
             "the captured spans as Chrome trace_event JSON (view in "
             "Perfetto or render with python -m repro.obs.report)",
    )
    ap.add_argument(
        "--obs-selfcheck", action="store_true",
        help="(with --metrics-port) scrape this process's own sidecar "
             "over HTTP and assert the exposition parses and reconciles "
             "with metrics() — exits nonzero on mismatch",
    )
    args = ap.parse_args(argv)
    if args.obs_selfcheck and args.metrics_port is None:
        raise SystemExit("--obs-selfcheck needs --metrics-port")
    if args.http_port is not None and not args.db_dir:
        raise SystemExit("--http-port needs --db-dir (a database to serve)")
    if args.serve_forever and args.http_port is None:
        raise SystemExit("--serve-forever needs --http-port")
    if (args.metrics_port is not None or args.trace_out) and not (
        args.db_dir or args.index_dir
    ):
        raise SystemExit(
            "--metrics-port/--trace-out need --index-dir or --db-dir "
            "(nothing to observe without a serving path)"
        )
    tracer = None
    if args.trace_out is not None:
        from repro.obs import Tracer

        tracer = Tracer()
    memory_budget = None
    if args.memory_budget is not None:
        from repro.core import MemoryBudget

        memory_budget = MemoryBudget.parse(args.memory_budget)
    if args.db_dir and args.index_dir:
        raise SystemExit("pass either --index-dir or --db-dir, not both")

    arch = get_arch(args.arch, smoke=args.smoke)
    if not arch.is_decoder:
        raise SystemExit(f"{args.arch} is encoder-only; no decode step")
    state = init_train_state(arch, jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, arch.vocab_size
    )

    if args.semantic_cache is not None and not args.db_dir:
        raise SystemExit("--semantic-cache needs --db-dir")

    if args.db_dir:
        from repro.serve import SemanticCache, VectorService

        semantic_cache = (
            SemanticCache(threshold=args.semantic_cache)
            if args.semantic_cache is not None else None
        )
        emb = np.asarray(
            state.params["embed"][prompts].mean(axis=1), np.float32
        )
        with VectorService.load(
            args.db_dir, batch_size=args.batch, memory_budget=memory_budget,
            recall_target=args.recall_target,
            semantic_cache=semantic_cache,
            tracer=tracer,
        ) as svc:
            obs_server = _start_obs(args, svc)
            names = svc.list_collections()
            if not names:
                raise SystemExit(f"{args.db_dir}: database has no collections")
            route = [
                entry.lstrip(":")
                for entry in (args.route.split(",") if args.route else names)
                if entry.lstrip(":")
            ]
            unknown = sorted(set(route) - set(names))
            if unknown:
                raise SystemExit(
                    f"--route names unknown collections {unknown}; "
                    f"database has {sorted(names)}"
                )
            # the prompt-retrieval demo only makes sense against
            # collections in the model's embedding space; a pure serving
            # database (arbitrary dim, fronted over HTTP) skips it
            demo = [n for n in route if svc.index_of(n).dim == emb.shape[1]]
            if not demo and args.http_port is None:
                raise SystemExit(
                    f"prompt embedding dim {emb.shape[1]} matches no "
                    f"routed collection (dims: "
                    f"{ {n: svc.index_of(n).dim for n in route} })"
                )
            targets = [demo[i % len(demo)] for i in range(len(emb))] \
                if demo else []
            futs = [
                svc.submit(coll, e, k=args.retrieve_k)
                for coll, e in zip(targets, emb)
            ]
            svc.flush()
            m = svc.metrics()
            print(
                f"loaded database {args.db_dir} "
                f"({len(names)} collections: {', '.join(names)}); "
                f"compile cache {m.compile_hits} hits / "
                f"{m.compile_misses} misses"
            )
            for i, (coll, fut) in enumerate(zip(targets, futs)):
                ids = np.asarray(fut.result().result.ids)
                print(f"prompt {i} -> :{coll} -> ids {ids}")
            if semantic_cache is not None and targets:
                # replay the same prompts: every retrieval should now be a
                # cache hit (an already-completed future, no dispatch)
                replay = [
                    svc.submit(coll, e, k=args.retrieve_k)
                    for coll, e in zip(targets, emb)
                ]
                svc.flush()
                cached = sum(f.result().cached for f in replay)
                m = svc.metrics()
                print(
                    f"semantic cache (threshold {args.semantic_cache}): "
                    f"replay served {cached}/{len(replay)} from cache; "
                    f"{m.semantic_hits} hits / {m.semantic_misses} misses"
                )
            if args.http_port is not None:
                frontend = _start_frontend(args, svc)
                if args.serve_forever:
                    try:
                        while True:
                            time.sleep(3600)
                    except KeyboardInterrupt:
                        pass
                frontend.close()
            if obs_server is not None:
                if args.obs_selfcheck:
                    _obs_selfcheck(obs_server, svc)
                obs_server.close()
    elif args.index_dir:
        from repro.core import MutableIndex, load_index
        from repro.serve import BatchingEngine

        index = load_index(args.index_dir, memory_budget=memory_budget)
        tuned_params = None
        if args.recall_target is not None:
            # strict: a serving target against an artifact with no
            # qualifying tuned point is an operator error, not a fallback
            try:
                tuned_params = index.params_for_target(
                    recall_target=args.recall_target
                )
            except (LookupError, AttributeError) as e:
                raise SystemExit(
                    f"--recall-target {args.recall_target}: {e}"
                )
            print(
                f"--recall-target {args.recall_target}: serving tuned "
                f"operating point {tuned_params}"
            )
        if args.mutable and not isinstance(index, MutableIndex):
            index = MutableIndex(index)
        emb = np.asarray(
            state.params["embed"][prompts].mean(axis=1), np.float32
        )
        if emb.shape[1] != index.dim:
            raise SystemExit(
                f"prompt embedding dim {emb.shape[1]} != index dim {index.dim}"
            )
        with BatchingEngine.from_index(
            index, k=args.retrieve_k, batch_size=args.batch,
            params=tuned_params, tracer=tracer,
        ) as engine:
            obs_server = _start_obs(args, engine)
            rows = engine.search(emb)
            ids = np.stack([r.result.ids for r in rows])
            print(f"loaded {type(index).__name__} from {args.index_dir}; "
                  f"retrieved ids per prompt:\n{ids}")
            if args.mutable:
                # write path: insert the prompts as fresh documents, retrieve
                # them back (exact match -> each prompt finds itself), drop
                # them
                new_ids = engine.insert(emb)
                rows = engine.search(emb, k=1)
                found = np.stack([r.result.ids for r in rows])[:, 0]
                removed = engine.delete(new_ids)
                m = engine.metrics()
                print(f"mutable: inserted {m.inserts} docs -> ids {new_ids}; "
                      f"self-retrieval {found}; deleted {removed}")
                if not np.array_equal(np.sort(found), np.sort(new_ids)):
                    raise SystemExit(
                        "inserted prompts did not retrieve themselves"
                    )
            if obs_server is not None:
                if args.obs_selfcheck:
                    _obs_selfcheck(obs_server, engine)
                obs_server.close()

    if tracer is not None:
        tracer.save(args.trace_out)
        print(
            f"trace: {len(tracer)} spans -> {args.trace_out} "
            f"(render: python -m repro.obs.report {args.trace_out})"
        )

    t0 = time.perf_counter()
    out = generate(state.params, arch, prompts, args.gen)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(np.asarray(out[:, :8]))
    return out


if __name__ == "__main__":
    main()
