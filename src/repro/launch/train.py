"""Production training driver.

Wires every substrate piece together: mesh + sharding rules, sharded data
pipeline, microbatched train_step, async checkpointing, preemption guard,
straggler monitoring, and restart-with-restore. On real TPU hosts this runs
under ``jax.distributed``; with --smoke it runs the reduced config on CPU
end-to-end (examples/train_lm.py drives it that way).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointing as ckpt
from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_arch
from repro.data.pipeline import TokenPipeline
from repro.ft.failures import PreemptionGuard, StragglerMonitor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.sharding import Rules, param_shardings
from repro.train.step import TrainState, init_train_state, make_train_step


def build(args):
    arch = get_arch(args.arch, smoke=args.smoke)
    if args.smoke:
        shape = ShapeConfig(
            "smoke", args.seq_len, args.batch, "train",
            num_microbatches=args.microbatches,
        )
        mesh = make_host_mesh()
    else:
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = Rules(mesh)
    return arch, shape, mesh, rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch, shape, mesh, rules = build(args)
    guard = PreemptionGuard()
    monitor = StragglerMonitor()

    with mesh:
        state = init_train_state(arch, jax.random.PRNGKey(0), args.lr)
        step_fn = jax.jit(
            make_train_step(arch, shape, rules, lr=args.lr),
            donate_argnums=(0,),
        )
        start = 0
        writer = None
        if args.ckpt_dir:
            writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                shardings = TrainState(
                    params=param_shardings(state.params, rules),
                    opt_state=None, step=None,
                )
                state = ckpt.restore(args.ckpt_dir, latest, state)
                start = latest
                print(f"restored step {latest} from {args.ckpt_dir}")

        pipe = TokenPipeline(arch, shape, seed=0)
        t_last = time.perf_counter()
        for step in range(start, args.steps):
            monitor.start_step(step)
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
            state, metrics = step_fn(state, batch)
            slow = monitor.end_step()
            if monitor.should_rebalance():
                print(f"step {step}: straggler threshold hit — a production "
                      "deployment would elastic_remesh() here")
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t_last
                t_last = time.perf_counter()
                print(
                    f"step {step} loss={float(metrics['loss']):.4f} "
                    f"nll={float(metrics['nll']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"({dt:.2f}s)" + (" [SLOW]" if slow else "")
                )
            if writer and (step + 1) % args.ckpt_every == 0:
                writer.submit(step + 1, state)
            if guard.preempted:
                print(f"preemption: checkpointing at step {step + 1} and exiting")
                if writer:
                    writer.submit(step + 1, state)
                break
        if writer:
            writer.submit(args.steps, state)
            writer.close()
    return state


if __name__ == "__main__":
    main()
