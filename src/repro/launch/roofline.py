"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

cost_analysis() reports the per-device (SPMD) module. collective bytes are
not in cost_analysis, so we parse the post-partitioning HLO text and sum
the output-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (``-start`` counted, ``-done`` skipped).
"""
from __future__ import annotations

import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_LINE_RE = re.compile(
    r"=\s*(?P<ty>\(?[a-z0-9_\[\]\{\}:,\s\#\*]*?\)?)\s*"
    r"(?P<kind>" + "|".join(_COLL_KINDS) + r")(?P<phase>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind byte totals + op counts from post-SPMD HLO text."""
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for m in _LINE_RE.finditer(hlo_text):
        if m.group("phase") == "-done":
            continue
        kind = m.group("kind")
        out[kind] += _shape_bytes(m.group("ty"))
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts
    return out


def cost_terms(compiled, hlo_text: str) -> dict:
    """The three roofline terms (seconds) + raw counters."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    terms = {
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": float(coll["total"]),
        "collective_breakdown": {
            k: coll[k] for k in _COLL_KINDS
        },
        "collective_counts": coll["counts"],
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": byts / HBM_BW,
        "collective_s": coll["total"] / ICI_BW,
    }
    dom = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def terms_from_counters(counters: dict) -> dict:
    """Roofline terms from (possibly calibrated) raw counters."""
    flops = counters["hlo_flops"]
    byts = counters["hlo_bytes"]
    coll = counters["collective_bytes"]
    terms = {
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": coll,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": byts / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dom = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # XLA:CPU may not expose it for all programs
        return {"error": str(e)}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    if "argument_size_in_bytes" in out:
        out["peak_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def model_flops(arch, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N per
    generated token for decode."""
    n = arch.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
