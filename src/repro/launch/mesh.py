"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
only data parallelism (+ optional FSDP for the >=400B archs), keeping the
slow inter-pod links off the per-layer critical path.
"""
from __future__ import annotations

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the same code path."""
    return compat.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline (EXPERIMENTS.md §Roofline).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (per chip, one direction)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip
