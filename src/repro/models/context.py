"""Trace-time sharding context.

``forward_train`` installs the active ``Rules`` here so that deeply nested
layers (MoE dispatch, SSD scan) can pin activation shardings without
threading a mesh handle through every call. This is trace-time state only —
it never leaks into the jitted computation.
"""
from __future__ import annotations

import contextlib
import contextvars

_rules = contextvars.ContextVar("repro_sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules):
    tok = _rules.set(rules)
    try:
        yield
    finally:
        _rules.reset(tok)


def current_dp_size() -> int:
    """Product of the active dp mesh axes (1 when no rules installed)."""
    rules = _rules.get()
    if rules is None:
        return 1
    import numpy as np

    return int(np.prod([rules.mesh.shape[a] for a in rules.dp]))


def act_shard(x, *logical):
    """Constrain activation ``x`` to the logical axes if rules are active."""
    rules = _rules.get()
    if rules is None:
        return x
    from repro.models.sharding import fix_spec

    spec = fix_spec(rules.spec(*logical), x.shape, rules.mesh)
    import jax
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
