"""Token-choice top-k MoE with capacity-bounded, shard-batched dispatch.

Dispatch is Megatron-style sort/rank, restructured for GSPMD: a plain
scatter over the assignment dim cannot be partitioned (the indexed dim is
the sharded one), so XLA replicates the (N*k, d) dispatch tensor on every
device — observed +14 GiB/device at 1T scale. Instead tokens are dispatched
*per dp shard*: the scatter is batched over a leading shard dim (which GSPMD
partitions), each shard owns capacity C/S per expert, and the
(S, E, C/S, d) -> (E, S*C/S, d) transpose becomes the token all-to-all of
classic expert parallelism. Per-shard capacity is also what a real EP system
enforces (each host bounds its own send buffer).

Experts and their FFN einsums shard over 'model' (EP); assignments beyond
capacity are dropped (standard).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.context import act_shard, current_dp_size
from repro.models.layers import dense_init


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "we_gate": dense_init(ks[1], (e, d, ff), 1, dtype),
        "we_up": dense_init(ks[2], (e, d, ff), 1, dtype),
        "we_down": dense_init(ks[3], (e, ff, d), 1, dtype)
        / (2 * cfg.num_layers) ** 0.5,
    }


def moe_capacity(tokens_per_shard: int, cfg) -> int:
    """Per-shard, per-expert capacity (8-padded for lane alignment)."""
    per = tokens_per_shard * cfg.experts_per_token / cfg.num_experts
    cap = int(per * cfg.moe_capacity_factor) + 1
    return max(8, -(-cap // 8) * 8)


def moe_layer(params, x, cfg):
    """x: (B, T, d) -> (B, T, d)."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n = b * t
    s = current_dp_size()
    if n % s != 0:
        s = 1
    ns = n // s                       # tokens per dp shard
    c = moe_capacity(ns, cfg)         # per-shard capacity

    xt = x.reshape(n, d)
    logits = (xt.astype(jnp.float32) @ params["router"])        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(s, ns * k)                           # (S, ns*k)
    flat_p = top_p.reshape(s, ns * k)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(ns), k)[None], (s, ns * k)
    )

    # rank of each assignment within its (shard, expert) segment
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    rank_sorted = jnp.broadcast_to(jnp.arange(ns * k)[None], (s, ns * k))
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_e)                                                 # (S, E)
    rank_sorted = rank_sorted - jnp.take_along_axis(seg_start, sorted_e, -1)
    rank = jnp.zeros_like(rank_sorted).at[
        jnp.arange(s)[:, None], order
    ].set(rank_sorted)                                          # (S, ns*k)

    keep = rank < c
    slot = jnp.where(keep, flat_e * c + rank, e * c)            # (S, ns*k)

    # batched scatter: leading shard dim partitions over dp
    xs = act_shard(xt.reshape(s, ns, d), "dp", None, "tp")
    dispatched = jnp.take_along_axis(xs, tok[..., None], axis=1)  # (S, ns*k, d)
    dispatched = act_shard(dispatched, "dp", None, "tp")
    buf = act_shard(jnp.zeros((s, e * c + 1, d), xt.dtype), "dp", None, "tp")
    buf = jax.vmap(lambda bf, sl, dp: bf.at[sl].add(dp))(buf, slot, dispatched)
    buf = act_shard(buf, "dp", None, "tp")
    buf = buf[:, :-1].reshape(s, e, c, d)
    # (S, E, C, d) -> (E, S*C, d): the EP token all-to-all
    buf = act_shard(
        buf.transpose(1, 0, 2, 3).reshape(e, s * c, d), "tp", None, None
    )

    # expert FFNs: one batched einsum over the expert axis (EP over 'model')
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_down"])  # (E, S*C, d)
    out_buf = act_shard(out_buf, "tp", None, None)

    # return all-to-all: (E, S*C, d) -> (S, E*C, d), gather per shard
    back = out_buf.reshape(e, s, c, d).transpose(1, 0, 2, 3).reshape(s, e * c, d)
    back = act_shard(back, "dp", None, "tp")
    safe_slot = jnp.minimum(slot, e * c - 1)
    gathered = jnp.take_along_axis(back, safe_slot[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0.0)        # (S, ns*k, d)
    combined = act_shard(jnp.zeros((s, ns, d), xt.dtype), "dp", None, "tp")
    combined = jax.vmap(lambda cb, tk, gt: cb.at[tk].add(gt))(
        combined, tok, (gathered * flat_p[..., None].astype(xt.dtype))
    )
    combined = act_shard(combined, "dp", None, "tp")
    return combined.reshape(b, t, d)


def moe_aux_loss(params, x, cfg):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_i * p_i)."""
    b, t, d = x.shape
    xt = x.reshape(b * t, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top_e, cfg.num_experts), axis=0)
    p = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(f * p)
