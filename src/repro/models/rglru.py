"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
  r_t = sigmoid(x_t . W_a + b_a)              (recurrence gate)
  i_t = sigmoid(x_t . W_x + b_x)              (input gate)
  a_t = exp(c * softplus(Lambda) * (-r_t))    (learned decay, c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan over T (log-depth); decode is the
O(1) per-token update — this is what keeps the ``long_500k`` cell runnable
for the hybrid arch. The surrounding block is the Griffin recurrent block:
linear in -> temporal conv (width 4) -> RG-LRU -> gated linear out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init

_C = 8.0


def init_rglru(key, cfg, dtype):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    return {
        "rg_in": dense_init(ks[0], (d, 2 * w), 0, dtype),   # [x | gate]
        "rg_out": dense_init(ks[1], (w, d), 0, dtype) / (2 * cfg.num_layers) ** 0.5,
        "rg_conv_w": dense_init(ks[2], (cfg.conv_width, w), 0, dtype),
        "rg_conv_b": jnp.zeros((w,), dtype),
        "rg_a_param": jnp.log(
            jnp.expm1(jnp.linspace(0.9, 0.999, w)) + 0.0
        ).astype(jnp.float32),  # softplus^-1 of decay targets
        "rg_wa": dense_init(ks[4], (w, 1), 0, jnp.float32)[:, 0],
        "rg_wx": dense_init(ks[5], (w, 1), 0, jnp.float32)[:, 0],
    }


def _gates(params, x):
    """x: (..., w) -> (a_t, gated input). Diagonal gates (elementwise)."""
    r = jax.nn.sigmoid(x.astype(jnp.float32) * params["rg_wa"])
    i = jax.nn.sigmoid(x.astype(jnp.float32) * params["rg_wx"])
    log_a = -_C * jax.nn.softplus(params["rg_a_param"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, gated


def rglru_scan(params, x, h0=None):
    """x: (B, T, w). Returns (y, h_T). Associative scan over time."""
    a, gx = _gates(params, x)          # (B, T, w) each
    if h0 is not None:
        # fold the carried state in as a virtual timestep contribution
        gx = gx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    A, Y = lax.associative_scan(combine, (a, gx), axis=1)
    return Y.astype(x.dtype), Y[:, -1]


def rglru_step(params, x1, h):
    """One-token step. x1: (B, w); h: (B, w) f32."""
    a, gx = _gates(params, x1)
    h_new = a * h + gx
    return h_new.astype(x1.dtype), h_new


def _conv(params, x, conv_state=None):
    w = params["rg_conv_w"]
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    return out + params["rg_conv_b"], xp[:, -(width - 1):]


def recurrent_block(params, u, cfg, state=None):
    """Full Griffin recurrent block. u: (B, T, d). Returns (out, new_state)."""
    proj = u @ params["rg_in"]
    x, gate = jnp.split(proj, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["h"]
    x, new_conv = _conv(params, x, conv_state)
    y, hT = rglru_scan(params, x, h0)
    y = y * jax.nn.gelu(gate)
    return y @ params["rg_out"], {"conv": new_conv, "h": hT}


def recurrent_block_step(params, u1, cfg, state):
    """One-token step. u1: (B, d)."""
    proj = u1 @ params["rg_in"]
    x1, gate = jnp.split(proj, 2, axis=-1)
    conv = state["conv"]
    w = params["rg_conv_w"]
    xp = jnp.concatenate([conv, x1[:, None, :]], axis=1)
    xc = (xp * w[None]).sum(1) + params["rg_conv_b"]
    new_conv = xp[:, 1:]
    y, h = rglru_step(params, xc, state["h"])
    y = y * jax.nn.gelu(gate)
    return y @ params["rg_out"], {"conv": new_conv, "h": h}
