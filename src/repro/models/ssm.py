"""Mamba-2 (SSD — state-space duality) layer: chunked scan for training /
prefill, O(1) recurrent state for decode. Follows the "minimal SSD"
formulation of arXiv:2405.21060 §6 with multi-head x, shared (B, C) per
group (ngroups=1 here, as in mamba2-370m).

Shapes: d_inner = expand * d_model; heads = d_inner / head_dim; state = N.
The chunked algorithm computes, per chunk of length Q:
  intra-chunk (quadratic in Q) + inter-chunk via the running state,
giving O(T*Q) work and O(1)-in-T memory — which is also why the arch keeps
the ``long_500k`` decode cell runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init


def init_ssm(key, cfg, dtype):
    d = cfg.d_model
    din = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = din + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # in_proj packs [z (din) | x (din) | B (n) | C (n) | dt (h)]
        "ssm_in": dense_init(ks[0], (d, 2 * din + 2 * n + h), 0, dtype),
        "ssm_out": dense_init(ks[1], (din, d), 0, dtype) / (2 * cfg.num_layers) ** 0.5,
        "conv_w": dense_init(ks[2], (cfg.conv_width, conv_dim), 0, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "ssm_norm": jnp.ones((din,), dtype),
    }


def _split_proj(params, u, cfg):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ params["ssm_in"]
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(params, xbc, conv_state=None):
    """Depthwise causal conv over time. xbc: (B, T, conv_dim)."""
    w = params["conv_w"]                        # (W, conv_dim)
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state                        # (B, W-1, conv_dim)
    xp = jnp.concatenate([pad, xbc], axis=1)    # (B, T+W-1, conv_dim)
    out = sum(
        xp[:, i : i + xbc.shape[1]] * w[i] for i in range(width)
    ) + params["conv_b"]
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, unroll: bool = False):
    """SSD chunked scan.

    x: (b, T, h, p); dt: (b, T, h); A: (h,) negative decay rates;
    B, C: (b, T, n). Returns y: (b, T, h, p), final_state: (b, h, p, n).
    """
    b, T, h, p = x.shape
    n = B.shape[-1]
    pad = -T % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // chunk
    xs = x.reshape(b, nc, chunk, h, p)
    dts = dt.reshape(b, nc, chunk, h)
    Bs = B.reshape(b, nc, chunk, n)
    Cs = C.reshape(b, nc, chunk, n)

    dA = dts * A[None, None, None, :]            # (b, nc, Q, h)  (negative)
    cum = jnp.cumsum(dA, axis=2)                 # within-chunk cumulative

    def chunk_step(state, inp):
        xs_c, dts_c, Bs_c, Cs_c, dA_c, cum_c = inp   # leading dim b
        # intra-chunk (quadratic): L[i,j] = exp(cum_i - cum_j) for i >= j
        li = cum_c[:, :, None, :] - cum_c[:, None, :, :]      # (b, Q, Q, h)
        iota = jnp.arange(cum_c.shape[1])
        causal = iota[:, None] >= iota[None, :]
        L = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        G = jnp.einsum("bqn,bkn->bqk", Cs_c, Bs_c)            # (b, Q, Q)
        M = G[..., None] * L                                   # (b, Q, Q, h)
        y_intra = jnp.einsum(
            "bqkh,bkh,bkhp->bqhp", M, dts_c, xs_c,
            preferred_element_type=jnp.float32,
        )
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum(
            "bqn,bhpn,bqh->bqhp", Cs_c, state, jnp.exp(cum_c),
            preferred_element_type=jnp.float32,
        )
        # state update: decay full chunk, add this chunk's outer products
        decay_chunk = jnp.exp(cum_c[:, -1])                    # (b, h)
        w = jnp.exp(cum_c[:, -1:, :] - cum_c)                  # (b, Q, h)
        state_new = state * decay_chunk[:, :, None, None] + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", w * dts_c, Bs_c, xs_c,
            preferred_element_type=jnp.float32,
        )
        return state_new, (y_intra + y_inter).astype(x.dtype)

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    inputs = (
        xs.transpose(1, 0, 2, 3, 4),
        dts.transpose(1, 0, 2, 3),
        Bs.transpose(1, 0, 2, 3),
        Cs.transpose(1, 0, 2, 3),
        dA.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    final_state, ys = lax.scan(chunk_step, state0, inputs, unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, Tp, h, p)[:, :T]
    return y, final_state


def ssm_forward(params, u, cfg, state=None):
    """Full mamba2 mixer. u: (B, T, d_model).

    state: None (train/prefill from scratch) or dict with 'conv' and 'ssd'
    for streaming prefill. Returns (out, new_state).
    """
    din, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(params, u, cfg)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(params, xbc, conv_state)
    x, B, C = jnp.split(xbc, [din, din + n], axis=-1)
    bsz, T = u.shape[0], u.shape[1]
    x = x.reshape(bsz, T, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, ssd_state = ssd_chunked(
        x, dt, A, B, C, cfg.ssm_chunk, unroll=cfg.unroll_loops
    )
    y = y + x * params["D"][None, None, :, None]
    y = y.reshape(bsz, T, din)
    y = y * jax.nn.silu(z)
    y = y * jax.lax.rsqrt(
        jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True) + 1e-6
    ).astype(y.dtype) * params["ssm_norm"]
    out = y @ params["ssm_out"]
    return out, {"conv": new_conv, "ssd": ssd_state}


def ssm_decode_step(params, u1, cfg, state):
    """One-token recurrent step. u1: (B, d_model); state from prefill."""
    din, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt = _split_proj(params, u1[:, None, :], cfg)
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]
    # conv ring update
    conv = state["conv"]                         # (B, W-1, conv_dim)
    w = params["conv_w"]
    xp = jnp.concatenate([conv, xbc[:, None, :]], axis=1)  # (B, W, conv)
    out = (xp * w[None]).sum(1) + params["conv_b"]
    xbc1 = jax.nn.silu(out)
    new_conv = xp[:, 1:]
    x, B, C = jnp.split(xbc1, [din, din + n], axis=-1)
    x = x.reshape(-1, h, p)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt1 * A[None, :])               # (B, h)
    s = state["ssd"]                             # (B, h, p, n)
    s = s * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt1, B, x, preferred_element_type=jnp.float32
    )
    y = jnp.einsum("bn,bhpn->bhp", C, s, preferred_element_type=jnp.float32)
    y = y + x * params["D"][None, :, None]
    y = y.reshape(-1, din).astype(u1.dtype)
    y = y * jax.nn.silu(z)
    y = y * jax.lax.rsqrt(
        jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True) + 1e-6
    ).astype(y.dtype) * params["ssm_norm"]
    return y @ params["ssm_out"], {"conv": new_conv, "ssd": s}
