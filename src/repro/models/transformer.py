"""Composable LM assembled from an ArchConfig.

One module covers the whole assigned zoo:
  dense / moe (+ dense_residual)  : pre-norm attention + (MLP | MoE)
  ssm                             : mamba2 mixer blocks (attention-free)
  hybrid                          : Griffin pattern (rec, rec, attn) blocks
  audio                           : encoder-only, inputs are frame embeddings
  vlm                             : dense + M-RoPE (+ stubbed patch embeds)

Layers are scan-stacked (HLO O(1) in depth) and rematerialized per the
config policy. Three entry points: ``forward_train`` (logits + aux),
``prefill`` (logits at last position + cache), ``decode_step`` (one token).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    attention_out,
    attention_qkv,
    blockwise_attention,
    decode_attention,
    dense_init,
    gated_mlp,
    init_attention,
    init_mlp,
    rms_norm,
)

FRONTEND_DIM = 512  # stubbed modality frontends emit this width


# ------------------------------------------------------------------ params --
def _init_dense_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "scale": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "scale2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        if cfg.dense_residual:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, cfg.num_layers)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.num_layers)
    return p


def _init_ssm_layer(key, cfg, dtype):
    return {
        "scale": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm_mod.init_ssm(key, cfg, dtype),
    }


def _init_rec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "scale": jnp.ones((cfg.d_model,), dtype),
        "rec": rg_mod.init_rglru(ks[0], cfg, dtype),
        "scale2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, cfg.num_layers),
    }


def _layer_initializer(kind: str):
    return {
        "dense": _init_dense_layer,
        "ssm": _init_ssm_layer,
        "rec": _init_rec_layer,
        "attn": _init_dense_layer,
    }[kind]


def hybrid_layout(cfg: ArchConfig) -> tuple[int, tuple[str, ...], tuple[str, ...]]:
    """(#full blocks, block pattern, tail pattern) covering num_layers."""
    pat = cfg.block_pattern
    nb = (cfg.num_layers - len(cfg.tail_pattern)) // len(pat)
    used = nb * len(pat) + len(cfg.tail_pattern)
    assert used == cfg.num_layers, (used, cfg.num_layers)
    return nb, pat, cfg.tail_pattern


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict = {"final": {"scale": jnp.ones((cfg.d_model,), dtype)}}
    vpad = cfg.padded_vocab
    if cfg.embed_inputs:
        params["embed"] = dense_init(keys[0], (vpad, cfg.d_model), 1, dtype)
    else:
        params["in_proj_frontend"] = dense_init(
            keys[0], (FRONTEND_DIM, cfg.d_model), 0, dtype
        )
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["unembed"] = dense_init(
            keys[1], (cfg.d_model, vpad), 0, dtype
        )

    if cfg.family == "hybrid":
        nb, pat, tail = hybrid_layout(cfg)
        blocks = {}
        for i, kind in enumerate(pat):
            lkeys = jax.random.split(jax.random.fold_in(keys[2], i), nb)
            blocks[f"pos{i}_{kind}"] = jax.vmap(
                lambda k: _layer_initializer(kind)(k, cfg, dtype)
            )(lkeys)
        params["blocks"] = blocks
        params["tail"] = {
            f"tail{i}_{kind}": _layer_initializer(kind)(
                jax.random.fold_in(keys[3], i), cfg, dtype
            )
            for i, kind in enumerate(tail)
        }
    else:
        kind = "ssm" if cfg.family == "ssm" else "dense"
        lkeys = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_initializer(kind)(k, cfg, dtype)
        )(lkeys)
    return params


# ------------------------------------------------------------- layer fns ----
def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        # save only batch-free dot outputs: keeps weight-stationary matmul
        # results but NOT attention-score tensors (which scale with T^2 and
        # would be stacked across the layer scan).
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    return jax.checkpoint(fn)


def _cast_layer_params(p, cfg):
    """Compute-dtype cast (bf16 activations lever, §Perf): router stays f32
    for routing numerics; everything else follows activation_dtype."""
    act = jnp.dtype(cfg.activation_dtype)

    def cast(path, w):
        name = ""
        for q in reversed(path):
            k = getattr(q, "key", None)
            if isinstance(k, str):
                name = k
                break
        if name == "router" or not jnp.issubdtype(w.dtype, jnp.floating):
            return w
        return w.astype(act)

    if act == jnp.dtype(cfg.param_dtype):
        return p
    return jax.tree_util.tree_map_with_path(cast, p)


def _dense_layer_fwd(p, x, cfg, positions, positions3):
    p = _cast_layer_params(p, cfg)
    xn = rms_norm(x, p["scale"], cfg.norm_eps)
    q, k, v = attention_qkv(p["attn"], xn, cfg, positions, positions3)
    window = cfg.window if cfg.family == "hybrid" else 0
    if cfg.attn_pairs and cfg.causal:
        from repro.models.layers import pairscan_attention

        attn = pairscan_attention(
            q, k, v, causal=True, window=window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            unroll=cfg.unroll_loops,
        )
    else:
        attn = blockwise_attention(
            q, k, v, causal=cfg.causal, window=window,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            fwd_only=cfg.attn_fwd_only, unroll=cfg.unroll_loops,
        )
    x = x + attention_out(p["attn"], attn)
    xn2 = rms_norm(x, p["scale2"], cfg.norm_eps)
    if cfg.family == "moe" and "moe" in p:
        ff = moe_mod.moe_layer(p["moe"], xn2, cfg)
        aux = moe_mod.moe_aux_loss(p["moe"], xn2, cfg)
        if cfg.dense_residual:
            ff = ff + gated_mlp(p["mlp"], xn2)
    else:
        ff = gated_mlp(p["mlp"], xn2)
        aux = jnp.float32(0.0)
    return x + ff, aux


def _ssm_layer_fwd(p, x, cfg):
    p = _cast_layer_params(p, cfg)
    xn = rms_norm(x, p["scale"], cfg.norm_eps)
    out, _ = ssm_mod.ssm_forward(p["ssm"], xn, cfg)
    return x + out, jnp.float32(0.0)


def _rec_layer_fwd(p, x, cfg):
    p = _cast_layer_params(p, cfg)
    xn = rms_norm(x, p["scale"], cfg.norm_eps)
    out, _ = rg_mod.recurrent_block(p["rec"], xn, cfg)
    x = x + out
    xn2 = rms_norm(x, p["scale2"], cfg.norm_eps)
    return x + gated_mlp(p["mlp"], xn2), jnp.float32(0.0)


# ------------------------------------------------------------ forward (train)
def embed_inputs(params, batch, cfg: ArchConfig):
    if cfg.embed_inputs:
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"].astype(params["in_proj_frontend"].dtype) \
            @ params["in_proj_frontend"]
    return x.astype(jnp.dtype(cfg.activation_dtype))


def unembed(params, x, cfg: ArchConfig):
    logits = x @ params["unembed"] if "unembed" in params else x @ params["embed"].T
    if cfg.padded_vocab != cfg.vocab_size:
        cols = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(cols < cfg.vocab_size, logits, -1e30)
    return logits


def forward_train(params, batch, cfg: ArchConfig, rules=None):
    """batch: tokens (B,T) [or embeds (B,T,F)], positions (B,T),
    optional positions3 (3,B,T). Returns (logits, aux_loss).

    ``rules`` (models.sharding.Rules) pins activation shardings: batch over
    'dp' at the embed output and at every layer boundary — without these,
    GSPMD can resolve the embed-gather sharding conflict by replicating the
    batch (observed: 3.5x per-device live memory on the dry-run)."""
    constrain = (
        (lambda t: rules.shard(t, "dp", None, None)) if rules is not None
        else (lambda t: t)
    )
    from repro.models.context import use_rules

    with use_rules(rules):
        return _forward_train_body(params, batch, cfg, constrain)


def _forward_train_body(params, batch, cfg: ArchConfig, constrain):
    x = constrain(embed_inputs(params, batch, cfg))
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1])[None], x.shape[:2]
        )
    positions3 = batch.get("positions3")

    if cfg.family == "hybrid":
        nb, pat, tail = hybrid_layout(cfg)

        def block_fwd(x, block_params):
            aux = jnp.float32(0.0)
            for i, kind in enumerate(pat):
                p = block_params[f"pos{i}_{kind}"]
                if kind == "rec":
                    x, a = _rec_layer_fwd(p, x, cfg)
                else:
                    x, a = _dense_layer_fwd(p, x, cfg, positions, positions3)
                aux = aux + a
            return x, aux

        body = _remat(block_fwd, cfg)
        x, auxs = lax.scan(
            lambda c, p: (lambda y, a: (constrain(y), a))(*body(c, p)),
            x, params["blocks"], unroll=cfg.unroll_loops,
        )
        aux = auxs.sum()
        for name, p in params["tail"].items():
            kind = name.split("_")[-1]
            if kind == "rec":
                x, a = _rec_layer_fwd(p, x, cfg)
            else:
                x, a = _dense_layer_fwd(p, x, cfg, positions, positions3)
            aux = aux + a
    else:
        if cfg.family == "ssm":
            layer = lambda p, x: _ssm_layer_fwd(p, x, cfg)
        else:
            layer = lambda p, x: _dense_layer_fwd(p, x, cfg, positions, positions3)
        body = _remat(lambda x, p: layer(p, x), cfg)
        x, auxs = lax.scan(
            lambda c, p: (lambda y, a: (constrain(y), a))(*body(c, p)),
            x, params["layers"], unroll=cfg.unroll_loops,
        )
        aux = auxs.sum()

    x = rms_norm(x, params["final"]["scale"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, aux


def loss_fn(params, batch, cfg: ArchConfig, aux_weight: float = 0.01, rules=None):
    logits, aux = forward_train(params, batch, cfg, rules=rules)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    nll = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + aux_weight * aux, (nll, aux)


# --------------------------------------------------------------- serving ----
def _attn_cache_shape(cfg, batch, max_len):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Empty per-layer cache pytree (stacked over scan where applicable)."""
    cache_len = min(max_len, cfg.window) if (
        cfg.family == "hybrid" and cfg.window
    ) else max_len

    def attn_c():
        return _attn_cache_shape(cfg, batch, max_len if cfg.family != "hybrid" else cache_len)

    def ssm_c():
        din, n = cfg.d_inner, cfg.ssm_state
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, din + 2 * n), jnp.float32),
            "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        }

    def rec_c():
        w = cfg.rnn_width or cfg.d_model
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
            "h": jnp.zeros((batch, w), jnp.float32),
        }

    if cfg.family == "hybrid":
        nb, pat, tail = hybrid_layout(cfg)
        stack = lambda mk: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (nb, *a.shape)), mk()
        )
        blocks = {
            f"pos{i}_{kind}": stack(rec_c if kind == "rec" else attn_c)
            for i, kind in enumerate(pat)
        }
        tail_c = {
            f"tail{i}_{kind}": (rec_c if kind == "rec" else attn_c)()
            for i, kind in enumerate(tail)
        }
        return {"blocks": blocks, "tail": tail_c}
    if cfg.family == "ssm":
        one = ssm_c()
        return {
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), one
            )
        }
    one = attn_c()
    return {
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), one
        )
    }


def _attn_decode(p, x1, cache, pos, cfg, positions3=None):
    """x1: (B, d); cache {'k','v'}: (B, S, KvH, hd); pos: () int32."""
    xn = rms_norm(x1[:, None, :], p["scale"], cfg.norm_eps)
    posb = jnp.broadcast_to(jnp.asarray(pos)[None, None], (x1.shape[0], 1))
    q, k, v = attention_qkv(p["attn"], xn, cfg, posb, positions3)
    S = cache["k"].shape[1]
    if cfg.family == "hybrid" and cfg.window:
        write = jnp.mod(pos, S)
    else:
        write = pos
    kc = cache["k"].at[:, write].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[:, write].set(v[:, 0].astype(cache["v"].dtype))
    if cfg.family == "hybrid" and cfg.window:
        clen = jnp.minimum(pos + 1, S)
        win = 0  # ring buffer already bounds the window
    else:
        clen = pos + 1
        win = 0
    attn = decode_attention(q[:, 0], kc, vc, clen, window=win)
    out = attention_out(p["attn"], attn[:, None])[:, 0]
    x1 = x1 + out
    xn2 = rms_norm(x1[:, None, :], p["scale2"], cfg.norm_eps)[:, 0]
    if cfg.family == "moe" and "moe" in p:
        ff = moe_mod.moe_layer(p["moe"], xn2[:, None, :], cfg)[:, 0]
        if cfg.dense_residual:
            ff = ff + gated_mlp(p["mlp"], xn2)
    else:
        ff = gated_mlp(p["mlp"], xn2)
    return x1 + ff, {"k": kc, "v": vc}


def _ssm_decode(p, x1, cache, cfg):
    xn = rms_norm(x1[:, None, :], p["scale"], cfg.norm_eps)[:, 0]
    out, new = ssm_mod.ssm_decode_step(p["ssm"], xn, cfg, cache)
    return x1 + out, new


def _rec_decode(p, x1, cache, cfg):
    xn = rms_norm(x1[:, None, :], p["scale"], cfg.norm_eps)[:, 0]
    out, new = rg_mod.recurrent_block_step(p["rec"], xn, cfg, cache)
    x1 = x1 + out
    xn2 = rms_norm(x1[:, None, :], p["scale2"], cfg.norm_eps)[:, 0]
    return x1 + gated_mlp(p["mlp"], xn2), new


def decode_step(params, cache, token, pos, cfg: ArchConfig, positions3=None):
    """One serving step: token (B,) int32 at position pos () int32.

    Returns (logits (B, V), new_cache). This is what ``decode_*`` /
    ``long_*`` shapes lower (serve_step), with the cache as input specs.
    """
    x1 = params["embed"][token] if cfg.embed_inputs else token  # (B, d)

    if cfg.family == "hybrid":
        nb, pat, tail = hybrid_layout(cfg)

        def block_step(x1, inp):
            bp, bc = inp
            new_c = {}
            for i, kind in enumerate(pat):
                key = f"pos{i}_{kind}"
                if kind == "rec":
                    x1, nc = _rec_decode(bp[key], x1, bc[key], cfg)
                else:
                    x1, nc = _attn_decode(bp[key], x1, bc[key], pos, cfg, positions3)
                new_c[key] = nc
            return x1, new_c

        x1, new_blocks = lax.scan(
            block_step, x1, (params["blocks"], cache["blocks"]),
            unroll=cfg.unroll_loops,
        )
        new_tail = {}
        for name, p in params["tail"].items():
            kind = name.split("_")[-1]
            if kind == "rec":
                x1, nc = _rec_decode(p, x1, cache["tail"][name], cfg)
            else:
                x1, nc = _attn_decode(p, x1, cache["tail"][name], pos, cfg, positions3)
            new_tail[name] = nc
        new_cache = {"blocks": new_blocks, "tail": new_tail}
    elif cfg.family == "ssm":
        def step(x1, inp):
            p, c = inp
            return _ssm_decode(p, x1, c, cfg)

        x1, new_layers = lax.scan(
            step, x1, (params["layers"], cache["layers"]), unroll=cfg.unroll_loops
        )
        new_cache = {"layers": new_layers}
    else:
        def step(x1, inp):
            p, c = inp
            return _attn_decode(p, x1, c, pos, cfg, positions3)

        x1, new_layers = lax.scan(
            step, x1, (params["layers"], cache["layers"]), unroll=cfg.unroll_loops
        )
        new_cache = {"layers": new_layers}

    x1 = rms_norm(x1, params["final"]["scale"], cfg.norm_eps)
    logits = unembed(params, x1, cfg)
    return logits, new_cache


def prefill(params, batch, cfg: ArchConfig, max_len: int):
    """Run the full prompt, build a cache, return last-position logits.

    For the ``prefill_32k`` cells we lower the *training-style* forward (no
    cache write) when the arch is encoder-only, else this function.
    """
    tokens = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
    B, T = tokens.shape[0], tokens.shape[1]
    logits, _ = forward_train(params, batch, cfg)
    cache = init_cache(cfg, B, max_len)
    # NOTE: for attention archs the cache would be written during the layer
    # pass in a fused implementation; the dry-run cost of the extra pass is
    # avoided by lowering forward_train for prefill cells (see launch/dryrun).
    return logits[:, -1], cache
