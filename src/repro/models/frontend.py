"""Input specs per (arch x shape): ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation.

Modality frontends are STUBS per the assignment: ``[audio]`` supplies
precomputed frame embeddings, ``[vlm]`` supplies M-RoPE position triples
(the dynamic-resolution encoding); both bypass the real CNN/ViT towers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import FRONTEND_DIM

SDS = jax.ShapeDtypeStruct


def train_input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    b, t = shape.global_batch, shape.seq_len
    specs: dict = {}
    if arch.embed_inputs:
        specs["tokens"] = SDS((b, t), jnp.int32)
    else:
        specs["embeds"] = SDS((b, t, FRONTEND_DIM), jnp.bfloat16)
    specs["labels"] = SDS((b, t), jnp.int32)
    specs["positions"] = SDS((b, t), jnp.int32)
    if arch.mrope:
        specs["positions3"] = SDS((3, b, t), jnp.int32)
    return specs


def decode_input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    """serve_step inputs: one new token against a seq_len KV cache."""
    b = shape.global_batch
    specs: dict = {"token": SDS((b,), jnp.int32), "pos": SDS((), jnp.int32)}
    if arch.mrope:
        specs["positions3"] = SDS((3, b, 1), jnp.int32)
    return specs


def cache_specs(arch: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree mirroring transformer.init_cache."""
    from repro.models.transformer import init_cache

    return jax.eval_shape(lambda: init_cache(arch, batch, max_len))


def make_train_batch(arch: ArchConfig, b: int, t: int, key) -> dict:
    """Concrete small batch for smoke tests."""
    ks = jax.random.split(key, 3)
    batch: dict = {
        "labels": jax.random.randint(ks[1], (b, t), 0, arch.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(t)[None], (b, t)).astype(jnp.int32),
    }
    if arch.embed_inputs:
        batch["tokens"] = jax.random.randint(ks[0], (b, t), 0, arch.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(
            ks[0], (b, t, FRONTEND_DIM), jnp.bfloat16
        )
    if arch.mrope:
        p = jnp.broadcast_to(jnp.arange(t)[None, None], (3, b, t)).astype(jnp.int32)
        batch["positions3"] = p
    return batch
