"""GSPMD sharding rules: logical axes -> mesh axes (MaxText-style).

Logical axes used by param/activation annotations:
  'fsdp'   — parameter sharding axis (ZeRO-3); maps to 'data' (+'pod' for
             the >=400B archs on the multi-pod mesh, see DESIGN §6)
  'tp'     — tensor-parallel axis: heads / ff / experts / vocab -> 'model'
  'dp'     — batch axis: ('pod','data') when the mesh has a pod axis
  'sp'     — sequence axis (long-context decode state) -> 'data'
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


REPLICATE_KV_NAMES = frozenset({"wk", "wv", "bk", "bv"})


class Rules:
    def __init__(
        self, mesh: Mesh, fsdp_over_pod: bool = False,
        replicate_kv: bool = False,
    ):
        # names whose misfit axes are dropped (replicated) instead of being
        # moved to another dim (avoids row-parallel KV all-reduces)
        self.no_reassign = REPLICATE_KV_NAMES if replicate_kv else frozenset()
        self._init_axes(mesh, fsdp_over_pod)

    def _init_axes(self, mesh: Mesh, fsdp_over_pod: bool):
        names = mesh.axis_names
        self.has_pod = "pod" in names
        self.dp = ("pod", "data") if self.has_pod else ("data",)
        self.fsdp = (
            ("pod", "data") if (self.has_pod and fsdp_over_pod) else ("data",)
        )
        self.tp = "model"
        self.sp = "data"
        self.mesh = mesh

    def spec(self, *logical) -> P:
        out = []
        for ax in logical:
            if ax is None:
                out.append(None)
            elif ax == "fsdp":
                if not self.fsdp:          # ZeRO-1 mode: params not sharded
                    out.append(None)
                else:
                    out.append(
                        self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]
                    )
            elif ax == "dp":
                out.append(self.dp if len(self.dp) > 1 else self.dp[0])
            elif ax == "tp":
                out.append(self.tp)
            elif ax == "sp":
                out.append(self.sp)
            else:
                raise ValueError(f"unknown logical axis {ax}")
        return P(*out)

    def shard(self, x, *logical):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical))
        )


# ---------------------------------------------------------------- param rules
# Param-name suffix -> logical axes for its trailing dims. When a param is
# scan-stacked it has a leading layer dim, padded with None automatically.
PARAM_RULES: dict[str, tuple] = {
    "embed": ("tp", "fsdp"),          # (V, d)
    "unembed": ("fsdp", "tp"),        # (d, V)
    "pos_embed": (None, "fsdp"),      # (T, d)
    "in_proj_frontend": (None, "fsdp"),
    "wq": ("fsdp", "tp", None),       # (d, H, hd)
    "wk": ("fsdp", "tp", None),       # (d, KvH, hd)
    "wv": ("fsdp", "tp", None),
    "wo": ("tp", None, "fsdp"),       # (H, hd, d)
    "bq": ("tp", None),               # (H, hd)
    "bk": ("tp", None),
    "bv": ("tp", None),
    "w_gate": ("fsdp", "tp"),         # (d, ff)
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),         # (ff, d)
    "router": ("fsdp", "tp"),         # (d, E)
    "we_gate": ("tp", "fsdp", None),  # (E, d, ff) — experts over 'model'
    "we_up": ("tp", "fsdp", None),
    "we_down": ("tp", None, "fsdp"),  # (E, ff, d)
    "scale": (None,),                 # norms
    "scale2": (None,),
    "scale3": (None,),
    "scale4": (None,),
    # ssm (mamba2)
    "ssm_in": ("fsdp", "tp"),         # (d, 2*din + 2*n + heads)
    "ssm_out": ("tp", "fsdp"),        # (din, d)
    "conv_w": (None, "tp"),           # (width, din + 2n)
    "conv_b": ("tp",),
    "A_log": ("tp",),                 # (heads,)
    "D": ("tp",),
    "dt_bias": ("tp",),
    "ssm_norm": ("tp",),
    # rg-lru (recurrentgemma)
    "rg_in": ("fsdp", "tp"),          # (d, 2w)
    "rg_out": ("tp", "fsdp"),         # (w, d)
    "rg_conv_w": (None, "tp"),
    "rg_conv_b": ("tp",),
    "rg_a_param": ("tp",),            # (w,)
    "rg_gate_in": ("fsdp", "tp"),     # (d, 2w) input+recurrence gates... (w,2)
    "rg_wa": ("tp",),                 # (w,) gates
    "rg_wx": ("tp",),
}


def fix_spec(spec: P, shape, mesh: Mesh, reassign: bool = True) -> P:
    """Make a PartitionSpec legal for ``shape``: every dim's sharded size
    must divide the dim. Axes that don't fit are moved to the rightmost
    other dim where they do (e.g. vocab 49155 can't split 16-way, so the
    'model' axis moves to the d_model dim), else dropped (replicated)."""
    sizes = dict(mesh.shape)
    entries: list[tuple] = []
    for e in tuple(spec) + (None,) * (len(shape) - len(tuple(spec))):
        if e is None:
            entries.append(())
        elif isinstance(e, (tuple, list)):
            entries.append(tuple(e))
        else:
            entries.append((e,))

    def factor(axes):
        f = 1
        for a in axes:
            f *= sizes[a]
        return f

    # only dims the rule already shards may receive reassigned axes: never
    # spill onto a scan/layer dim or head_dim (provokes involuntary SPMD
    # rematerialization around RoPE/GQA reshapes).
    candidates = [i for i, e in enumerate(entries) if e] if reassign else []
    dropped: list[str] = []
    for i, dim in enumerate(shape):
        keep: list[str] = []
        for a in entries[i]:
            if dim % (factor(keep) * sizes[a]) == 0:
                keep.append(a)
            else:
                dropped.append(a)
        entries[i] = tuple(keep)
    for a in dropped:
        # left-to-right: prefer moving a misfit axis onto a leading (d_model
        # / row) dim — row-parallel layouts keep downstream reshapes shardable.
        for i in candidates:
            if a in entries[i]:
                continue
            if shape[i] % (factor(entries[i]) * sizes[a]) == 0:
                entries[i] = entries[i] + (a,)
                break
        # unplaced axes are simply dropped (replicated)
    out = tuple(
        None if not e else (e[0] if len(e) == 1 else e) for e in entries
    )
    return P(*out)


def param_specs(params, rules: Rules):
    """Build a PartitionSpec tree matching ``params`` by leaf name."""

    def leaf_spec(path, leaf):
        name = None
        for p in reversed(path):
            key = getattr(p, "key", None) or getattr(p, "name", None)
            if isinstance(key, str):
                name = key
                break
        if name not in PARAM_RULES:
            raise KeyError(f"no sharding rule for param '{name}' ({path})")
        logical = PARAM_RULES[name]
        shape = tuple(leaf.shape)
        ndim = len(shape)
        pad = ndim - len(logical)
        assert pad >= 0, f"{name}: rule longer than rank {ndim}"
        spec = rules.spec(*((None,) * pad + tuple(logical)))
        return fix_spec(
            spec, shape, rules.mesh,
            reassign=name not in getattr(rules, "no_reassign", frozenset()),
        )

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params, rules: Rules):
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), param_specs(params, rules)
    )
