"""Shared transformer layers: RMSNorm, RoPE / M-RoPE, blockwise (flash-style)
GQA attention, decode attention, gated MLP.

All functions are pure; params are plain dicts so layer stacks can be
``lax.scan``-ed (HLO size O(1) in depth) and sharded by name via
``models.sharding.PARAM_RULES``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ------------------------------------------------------------------- RoPE ---
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (B, T, H, hd); positions: (B, T) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Multimodal RoPE (Qwen2-VL): three position streams (t, h, w) rotate
    disjoint sections of the head dim. positions3: (3, B, T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    # build a per-frequency position by selecting the stream for its section
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])                                                     # (hd/2,)
    pos = positions3.astype(jnp.float32)                   # (3, B, T)
    pos_per_freq = pos[sec]                                # (hd/2, B, T)
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * freqs        # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention ---
def blockwise_attention(
    q, k, v, *, causal: bool, window: int = 0,
    q_chunk: int = 512, kv_chunk: int = 1024, q_offset=0, fwd_only: bool = False,
    unroll: bool = False,
):
    """Flash-style online-softmax attention with GQA and optional local
    window. Memory is O(q_chunk x kv_chunk) per step instead of O(T^2):
    mandatory for the 32k prefill cells (DESIGN §5).

    q: (B, Tq, H, hd); k, v: (B, Tk, KvH, hd). Returns (B, Tq, H, hd).
    Causal masking assumes q positions are ``q_offset + [0, Tq)`` against
    k positions ``[0, Tk)``.
    """
    B, Tq, H, hd = q.shape
    Tk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    # pad T dims to chunk multiples
    pq = -Tq % q_chunk
    pk = -Tk % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Tqp, Tkp = Tq + pq, Tk + pk
    nq, nk = Tqp // q_chunk, Tkp // kv_chunk

    scale = hd ** -0.5
    qr = (q * scale).reshape(B, Tqp, KvH, G, hd).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)          # (B, KvH, Tkp, hd)
    vr = v.transpose(0, 2, 1, 3)

    def q_block(iq):
        qi = lax.dynamic_slice_in_dim(qr, iq * q_chunk, q_chunk, axis=3)
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        m0 = jnp.full((B, KvH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KvH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KvH, G, q_chunk, hd), jnp.float32)

        def kv_step(ik, carry):
            m, l, acc = carry
            kj = lax.dynamic_slice_in_dim(kr, ik * kv_chunk, kv_chunk, axis=2)
            vj = lax.dynamic_slice_in_dim(vr, ik * kv_chunk, kv_chunk, axis=2)
            s = jnp.einsum(
                "bkgqh,bkch->bkgqc", qi, kj,
                preferred_element_type=jnp.float32,
            )
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] < Tk
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            new_m = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - new_m[..., None])
            p = jnp.where(s <= NEG_INF / 2, 0.0, p)
            corr = jnp.exp(m - new_m)
            corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
            l2 = l * corr + p.sum(-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, vj.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return new_m, l2, acc2

        if causal and fwd_only:
            # forward-only fast path (prefill): skip kv chunks that are
            # entirely masked for this q chunk. Dynamic loop bounds are not
            # reverse-differentiable, so training uses the static loop below
            # (masked contributions are exact zeros either way).
            hi_pos = q_offset + (iq + 1) * q_chunk
            hi = jnp.minimum((hi_pos + kv_chunk - 1) // kv_chunk, nk)
            if window:
                lo_pos = q_offset + iq * q_chunk - (window - 1)
                lo = jnp.maximum(jnp.maximum(lo_pos, 0) // kv_chunk, 0)
            else:
                lo = jnp.int32(0)
            m, l, acc = lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        else:
            m, l, acc = lax.fori_loop(
                0, nk, kv_step, (m0, l0, a0), unroll=True if unroll else None
            )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                  # (B, KvH, G, qc, hd)

    _, blocks = lax.scan(
        lambda c, iq: (c, q_block(iq)), None, jnp.arange(nq), unroll=unroll
    )                                               # (nq, B, KvH, G, qc, hd)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, KvH, G, Tqp, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tqp, H, hd)
    return out[:, :Tq].astype(q.dtype)


def decode_attention(q1, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token attention against a KV cache.

    q1: (B, H, hd); caches: (B, S, KvH, hd); cache_len: () or (B,) valid
    length (the new token's position is cache_len - 1 after append).
    """
    B, H, hd = q1.shape
    S, KvH = k_cache.shape[1], k_cache.shape[2]
    G = H // KvH
    scale = hd ** -0.5
    qr = (q1 * scale).reshape(B, KvH, G, hd)
    s = jnp.einsum(
        "bkgh,bskh->bkgs", qr, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len).reshape(-1, 1)       # (B or 1, 1)
    mask = pos[None, :] < cl
    if window:
        mask &= pos[None, :] >= cl - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskh->bkgh", p, v_cache, preferred_element_type=jnp.float32
    )
    return out.reshape(B, H, hd).astype(q1.dtype)


def pairscan_attention(
    q, k, v, *, causal: bool, window: int = 0,
    q_chunk: int = 512, kv_chunk: int = 1024, q_offset: int = 0,
    unroll: bool = False,
):
    """Triangular pair-scan attention (§Perf lever `attn_pairs`).

    The masked blockwise loop above computes every (q_chunk x kv_chunk)
    pair and zeroes the fully-masked ones — ~2x attention FLOP waste under
    causal masking. Here the needed (iq, ik) pairs are enumerated
    *statically* and a single scan walks them, updating the online-softmax
    state of q-chunk iq in place. Exact causal FLOPs, fixed trip count
    (reverse-differentiable), same numerics.
    """
    B, Tq, H, hd = q.shape
    Tk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    pq_ = -Tq % q_chunk
    pk_ = -Tk % kv_chunk
    if pq_:
        q = jnp.pad(q, ((0, 0), (0, pq_), (0, 0), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, pk_), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk_), (0, 0), (0, 0)))
    Tqp, Tkp = Tq + pq_, Tk + pk_
    nq, nk = Tqp // q_chunk, Tkp // kv_chunk

    pairs = []
    for iq in range(nq):
        if causal:
            hi = min(
                -(-(q_offset + (iq + 1) * q_chunk) // kv_chunk), nk
            )
        else:
            hi = nk
        lo = 0
        if window:
            lo = max(0, (q_offset + iq * q_chunk - (window - 1)) // kv_chunk)
        for ik in range(lo, hi):
            pairs.append((iq, ik))
    pair_arr = jnp.asarray(pairs, jnp.int32)          # (P, 2)

    scale = hd ** -0.5
    qr = (q * scale).reshape(B, Tqp, KvH, G, hd).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)

    m0 = jnp.full((nq, B, KvH, G, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, KvH, G, q_chunk), jnp.float32)
    a0 = jnp.zeros((nq, B, KvH, G, q_chunk, hd), jnp.float32)

    def step(carry, pair):
        m, l, acc = carry
        iq, ik = pair[0], pair[1]
        qi = lax.dynamic_slice_in_dim(qr, iq * q_chunk, q_chunk, axis=3)
        kj = lax.dynamic_slice_in_dim(kr, ik * kv_chunk, kv_chunk, axis=2)
        vj = lax.dynamic_slice_in_dim(vr, ik * kv_chunk, kv_chunk, axis=2)
        s = jnp.einsum(
            "bkgqh,bkch->bkgqc", qi, kj, preferred_element_type=jnp.float32
        )
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
        kpos = ik * kv_chunk + jnp.arange(kv_chunk)
        mask = kpos[None, :] < Tk
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        mi = m[iq]
        new_m = jnp.maximum(mi, s.max(-1))
        p = jnp.exp(s - new_m[..., None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(mi - new_m)
        corr = jnp.where(mi <= NEG_INF / 2, 0.0, corr)
        li = l[iq] * corr + p.sum(-1)
        ai = acc[iq] * corr[..., None] + jnp.einsum(
            "bkgqc,bkch->bkgqh", p, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m.at[iq].set(new_m), l.at[iq].set(li), acc.at[iq].set(ai)), None

    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), pair_arr, unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # (nq, B, KvH, G, qc, hd)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, KvH, G, Tqp, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tqp, H, hd)
    return out[:, :Tq].astype(q.dtype)


# ------------------------------------------------------------------- MLP ---
def gated_mlp(params, x):
    """SwiGLU MLP. x: (..., d)."""
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ------------------------------------------------------------------ inits ---
def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def init_attention(key, cfg, dtype):
    d, H, KvH = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), 0, dtype),
        "wk": dense_init(ks[1], (d, KvH, hd), 0, dtype),
        "wv": dense_init(ks[2], (d, KvH, hd), 0, dtype),
        "wo": dense_init(ks[3], (H, hd, d), 0, dtype) / (2 * cfg.num_layers) ** 0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KvH, hd), dtype)
        p["bv"] = jnp.zeros((KvH, hd), dtype)
    return p


def init_mlp(key, d, ff, dtype, num_layers=1):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), 0, dtype),
        "w_up": dense_init(ks[1], (d, ff), 0, dtype),
        "w_down": dense_init(ks[2], (ff, d), 0, dtype) / (2 * num_layers) ** 0.5,
    }


def attention_qkv(params, x, cfg, positions=None, positions3=None):
    """Project + rotate. Returns q (B,T,H,hd), k, v (B,T,KvH,hd)."""
    q = jnp.einsum("btd,dhx->bthx", x, params["wq"])
    k = jnp.einsum("btd,dhx->bthx", x, params["wk"])
    v = jnp.einsum("btd,dhx->bthx", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.mrope and positions3 is not None:
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_out(params, attn):
    return jnp.einsum("bthx,hxd->btd", attn, params["wo"])
