"""train_step / serve_step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function with:
  * microbatch gradient accumulation (lax.scan) — bounds live activation
    memory AND lets XLA overlap each microbatch's reduce-scatters with the
    next microbatch's compute (DESIGN §6 'overlap');
  * configurable accumulation dtype (bf16 = compressed cross-replica
    reduction payload);
  * activation sharding constraints on batch entry (GSPMD propagates the
    rest from the param shardings in models.sharding.PARAM_RULES).

``make_serve_step`` returns the decode-one-token function the ``decode_*``
and ``long_*`` shapes lower.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.sharding import Rules
from repro.optim import make_optimizer


class TrainState(NamedTuple):
    params: dict
    opt_state: object
    step: jnp.ndarray


def init_train_state(cfg: ArchConfig, key, lr: float | None = None) -> TrainState:
    params = tf.init_params(cfg, key)
    opt = make_optimizer(cfg.optimizer, lr)
    return TrainState(
        params=params, opt_state=opt.init(params), step=jnp.zeros((), jnp.int32)
    )


def _shard_batch(batch: dict, rules: Rules | None) -> dict:
    if rules is None:
        return batch
    out = {}
    for k, v in batch.items():
        if k == "positions3":
            out[k] = rules.shard(v, None, "dp", None)
        elif v.ndim >= 2:
            out[k] = rules.shard(v, "dp", *([None] * (v.ndim - 1)))
        else:
            out[k] = v
    return out


def effective_microbatches(shape: ShapeConfig, rules: Rules | None) -> int:
    """Per-microbatch batch must stay divisible by the dp degree, or GSPMD
    pads and part of the mesh idles (observed on the 2-pod mesh)."""
    num_mb = shape.num_microbatches
    if rules is None:
        return num_mb
    import numpy as _np

    dp_size = int(_np.prod([rules.mesh.shape[a] for a in rules.dp]))
    while num_mb > 1 and (shape.global_batch // num_mb) % dp_size != 0:
        num_mb //= 2
    return num_mb


def make_train_step(
    cfg: ArchConfig,
    shape: ShapeConfig | None = None,
    rules: Rules | None = None,
    *,
    accum_dtype=None,
    lr: float | None = None,
    zero1: bool = False,
):
    """``zero1``: hoist the FSDP parameter all-gather out of the microbatch
    loop (ZeRO-1). FSDP re-gathers every weight in every microbatch's fwd
    AND bwd (~3 x param_bytes x num_microbatches of all-gather per step);
    ZeRO-1 gathers once, computes all microbatches against the gathered
    copy (accumulating grads in the gathered layout, bf16), and
    reduce-scatters once into the fsdp-sharded optimizer. Collective bytes
    drop ~num_microbatches-fold at the cost of one replicated bf16
    param+grad copy per device — the §Perf granite iteration."""
    opt = make_optimizer(cfg.optimizer, lr)
    num_mb = effective_microbatches(shape, rules) if shape else 1
    if accum_dtype is None:
        # bf16 accumulation when params are bf16 (1T arch) or when ZeRO-1
        # keeps a replicated accumulation copy: halves the accumulate
        # buffer and the cross-replica reduce payload
        accum_dtype = (
            jnp.bfloat16
            if (cfg.param_dtype == "bfloat16" or zero1)
            else jnp.float32
        )

    if zero1 and rules is not None:
        nofsdp_rules = Rules(rules.mesh)
        nofsdp_rules.fsdp = ()
    else:
        nofsdp_rules = None

    def train_step(state: TrainState, batch: dict):
        batch = _shard_batch(batch, rules)

        if nofsdp_rules is not None:
            from repro.models.sharding import param_shardings

            gathered_sh = param_shardings(state.params, nofsdp_rules)
            compute_params = jax.tree.map(
                jax.lax.with_sharding_constraint, state.params, gathered_sh
            )
        else:
            compute_params = state.params

        def loss(params, mb):
            l, (nll, aux) = tf.loss_fn(params, mb, cfg, rules=rules)
            return l, (nll, aux)

        if num_mb == 1:
            (l, (nll, aux)), grads = jax.value_and_grad(loss, has_aux=True)(
                compute_params, batch
            )
        else:
            def split(v, k):
                # constrain: microbatch dim replicated, batch dim over dp —
                # otherwise GSPMD may shard the scan (mb) axis and replicate
                # the per-step batch across the whole mesh.
                if v.ndim == 0:
                    return jnp.broadcast_to(v, (num_mb,))
                if k == "positions3":  # (3, B, T)
                    b = v.shape[1]
                    out = v.reshape(
                        3, num_mb, b // num_mb, *v.shape[2:]
                    ).transpose(1, 0, *range(2, v.ndim + 1))
                    if rules is not None:
                        out = rules.shard(
                            out, None, None, "dp", *([None] * (out.ndim - 3))
                        )
                    return out
                b = v.shape[0]
                out = v.reshape(num_mb, b // num_mb, *v.shape[1:])
                if rules is not None:
                    out = rules.shard(
                        out, None, "dp", *([None] * (out.ndim - 2))
                    )
                return out

            mbs = {k: split(v, k) for k, v in batch.items()}
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params
            )
            if nofsdp_rules is not None:
                # accumulate in the gathered layout (bf16) — reduce-scatter
                # happens once, below
                zero_g = jax.tree.map(
                    jax.lax.with_sharding_constraint, zero_g, gathered_sh
                )

            def mb_step(carry, mb):
                g_acc, l_acc, nll_acc, aux_acc = carry
                (l, (nll, aux)), g = jax.value_and_grad(loss, has_aux=True)(
                    compute_params, mb
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g
                )
                return (g_acc, l_acc + l, nll_acc + nll, aux_acc + aux), None

            (grads, l, nll, aux), _ = jax.lax.scan(
                mb_step, (zero_g, 0.0, 0.0, 0.0), mbs, unroll=cfg.unroll_loops
            )
            inv = 1.0 / num_mb
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
            l, nll, aux = l * inv, nll * inv, aux * inv

        if nofsdp_rules is not None:
            # one reduce-scatter back into the fsdp-sharded optimizer layout
            from repro.models.sharding import param_shardings

            grads = jax.tree.map(
                jax.lax.with_sharding_constraint,
                grads,
                param_shardings(state.params, rules),
            )

        new_params, new_opt, gnorm = opt.update(grads, state.opt_state, state.params)
        metrics = {"loss": l, "nll": nll, "aux": aux, "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_serve_step(cfg: ArchConfig):
    """decode one token: (params, cache, token, pos[, positions3])."""

    def serve_step(params, cache, token, pos, positions3=None):
        return tf.decode_step(params, cache, token, pos, cfg, positions3)

    return serve_step


def make_prefill(cfg: ArchConfig):
    def prefill_step(params, batch):
        logits, aux = tf.forward_train(params, batch, cfg)
        return logits

    return prefill_step
