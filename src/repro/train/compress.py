"""Gradient compression for cross-pod reduction (DESIGN §6).

Two pieces:
  * bf16 microbatch accumulation (in ``train.step``) — halves the
    accumulate-buffer bytes and the cross-replica reduce payload.
  * int8 error-feedback compressor — per-tensor symmetric quantization with
    a residual carried to the next step, so compression error is fed back
    rather than lost (1-bit/8-bit SGD style). Used on the 'pod' axis where
    ICI links are the scarce resource.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # same structure as grads, f32


def init_ef(grads_like) -> EFState:
    return EFState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def compress(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """f32 -> (int8 codes, scale). Symmetric per-tensor quantization."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, ef: EFState):
    """Apply error feedback, compress every leaf. Returns (codes, scales,
    new EFState) — codes are what crosses the pod links."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = compress(corrected)
        back = decompress(q, s)
        return q, s, corrected - back

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    codes = tdef.unflatten([o[0] for o in out])
    scales = tdef.unflatten([o[1] for o in out])
    new_ef = EFState(residual=tdef.unflatten([o[2] for o in out]))
    return codes, scales, new_ef


def ef_decompress_tree(codes, scales):
    return jax.tree.map(decompress, codes, scales)
