"""PageANN reproduction on the JAX/Pallas substrate (see README.md)."""
