"""Checkpointing: sharded save/restore with atomic commit, an async writer
thread, and elastic restore (re-shard onto a different mesh).

Layout on disk:
  <dir>/step_<N>.tmp/   leaf files while writing
  <dir>/step_<N>/       renamed atomically on commit
    MANIFEST.json       {step, leaf paths, shapes, dtypes}
    <leaf>.npy          one file per pytree leaf (full array; on a real
                        multi-host cluster each host writes its shard files
                        — the manifest format already carries the pieces)

Restore accepts target shardings, so a checkpoint written on one mesh can
be loaded onto another (elastic scaling / failure-shrunk mesh): leaves are
device_put with the *new* sharding, letting the runtime lay them out.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np

_SEP = "::"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            key = getattr(p, "key", None)
            if key is None:
                key = getattr(p, "idx", None)
            if key is None:
                key = getattr(p, "name", "x")
            parts.append(str(key))
        names.append(_SEP.join(parts))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    names, leaves, _ = _flatten_with_names(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "_") + ".npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype == "bfloat16":  # numpy can't natively (de)serialize bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": logical_dtype}
        )
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                steps.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``. ``shardings`` (same
    structure, or None) re-shards elastically onto the current mesh."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(final, "MANIFEST.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    names, leaves, treedef = _flatten_with_names(target_tree)
    if shardings is not None:
        _, shard_leaves, _ = _flatten_with_names(shardings)
    else:
        shard_leaves = [None] * len(leaves)
    out = []
    for name, leaf, shd in zip(names, leaves, shard_leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf '{name}'")
        entry = by_name[name]
        arr = np.load(os.path.join(final, entry["file"]))
        if entry["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{name}: ckpt {arr.shape} vs target {want}")
        dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.device_put(arr))
    return treedef.unflatten(out)


class AsyncCheckpointer:
    """Background writer: ``submit`` snapshots to host memory immediately
    (so training can mutate buffers) and a daemon thread serializes."""

    def __init__(self, ckpt_dir: str, max_queue: int = 2):
        self.ckpt_dir = ckpt_dir
        self._q: queue.Queue = queue.Queue(maxsize=max_queue)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save(self.ckpt_dir, step, host_tree)
            except Exception as e:  # surfaced on next submit/close
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()
