"""Fault-tolerance utilities for the train/serve drivers.

On a real cluster these wrap jax.distributed + the platform's preemption
notice; the logic (deadlines, restart decisions, elastic re-mesh) is
host-side Python and is exercised by unit tests here.

  * PreemptionGuard — converts SIGTERM into a 'checkpoint then exit' flag
    checked once per step (standard TPU preemption contract).
  * StragglerMonitor — per-step deadline tracking with an EWMA baseline;
    marks steps exceeding ``threshold x`` the moving average, and exposes
    a should_rebalance() signal after K consecutive slow steps (the driver
    responds by shrinking the mesh / excluding the slow host).
  * RestartManager — bounded-retry restore-from-latest loop around a step
    function; used by launch/train.py.
  * elastic_remesh — recompute mesh + shardings for a smaller/larger
    device set (restore path re-shards via checkpoint.restore).
"""
from __future__ import annotations

import dataclasses
import signal
import time


class PreemptionGuard:
    def __init__(self, sig=signal.SIGTERM):
        self._requested = False
        try:
            self._prev = signal.signal(sig, self._handler)
        except ValueError:  # not in main thread (tests)
            self._prev = None

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self):  # for tests / manual drills
        self._requested = True


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0      # step is 'slow' if > threshold * ewma
    ewma_alpha: float = 0.1
    rebalance_after: int = 3    # consecutive slow steps before remesh signal

    def __post_init__(self):
        self._ewma: float | None = None
        self._consecutive_slow = 0
        self.slow_steps: list[tuple[int, float]] = []
        self._t0: float | None = None
        self._step = 0

    def start_step(self, step: int):
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.perf_counter() - self._t0
        slow = self._ewma is not None and dt > self.threshold * self._ewma
        if self._ewma is None:
            self._ewma = dt
        else:
            self._ewma = (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * dt
        if slow:
            self.slow_steps.append((self._step, dt))
            self._consecutive_slow += 1
        else:
            self._consecutive_slow = 0
        return slow

    def observe(self, step: int, duration_s: float) -> bool:
        """Deterministic variant for tests / offline traces."""
        self._step = step
        slow = self._ewma is not None and duration_s > self.threshold * self._ewma
        if self._ewma is None:
            self._ewma = duration_s
        else:
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * duration_s
        if slow:
            self.slow_steps.append((step, duration_s))
            self._consecutive_slow += 1
        else:
            self._consecutive_slow = 0
        return slow

    def should_rebalance(self) -> bool:
        return self._consecutive_slow >= self.rebalance_after


class RestartManager:
    """Retry loop: run step_fn; on failure restore from latest checkpoint
    and continue, up to max_restarts."""

    def __init__(self, max_restarts: int = 3):
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, total_steps: int, step_fn, restore_fn, start_step: int = 0):
        """step_fn(step) -> None may raise; restore_fn() -> resume step."""
        step = start_step
        while step < total_steps:
            try:
                step_fn(step)
                step += 1
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                step = restore_fn()
        return step


def elastic_remesh(num_devices: int, *, multi_pod: bool | None = None):
    """Largest (data, model) mesh <= num_devices with model axis fixed at
    min(16, devices): the shrink-after-failure policy. Returns mesh shape."""
    import math

    model = min(16, num_devices)
    data = num_devices // model
    if multi_pod and data >= 32:
        return (data // 16, 16, model)
    return (data, model)
