"""Deterministic, shardable synthetic data pipelines.

Token pipeline: seeded per (host, step) so every host materializes only its
slice of the global batch — the standard multi-pod input pattern (no host
ever holds the full batch). Vector pipeline: clustered Gaussians that mimic
SIFT-like local structure for the ANN benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import FRONTEND_DIM


@dataclasses.dataclass
class TokenPipeline:
    arch: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.shape.global_batch % self.num_hosts == 0
        self.local_batch = self.shape.global_batch // self.num_hosts

    def batch(self, step: int) -> dict:
        """The host-local slice of global batch ``step`` (deterministic)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id
        )
        b, t = self.local_batch, self.shape.seq_len
        v = self.arch.vocab_size
        out: dict = {}
        if self.arch.embed_inputs:
            toks = rng.integers(0, v, (b, t + 1), dtype=np.int32)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        else:
            out["embeds"] = rng.standard_normal((b, t, FRONTEND_DIM)).astype(
                np.float32
            )
            out["labels"] = rng.integers(0, v, (b, t), dtype=np.int32)
        out["positions"] = np.broadcast_to(
            np.arange(t, dtype=np.int32)[None], (b, t)
        ).copy()
        if self.arch.mrope:
            out["positions3"] = np.broadcast_to(
                np.arange(t, dtype=np.int32)[None, None], (3, b, t)
            ).copy()
        return out


def clustered_vectors(
    n: int, dim: int, num_clusters: int = 64, seed: int = 0, scale: float = 0.15
) -> np.ndarray:
    """SIFT-like clustered vector dataset for the ANN benchmarks."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((num_clusters, dim)).astype(np.float32)
    assign = rng.integers(0, num_clusters, n)
    x = centers[assign] + scale * rng.standard_normal((n, dim)).astype(np.float32)
    return np.ascontiguousarray(x, np.float32)


def query_vectors(
    x: np.ndarray, q: int, seed: int = 1, noise: float = 0.1
) -> np.ndarray:
    """Queries near data points (realistic ANN workload)."""
    rng = np.random.default_rng(seed)
    base = x[rng.integers(0, len(x), q)]
    return (base + noise * rng.standard_normal(base.shape)).astype(np.float32)
