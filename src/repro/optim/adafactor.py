"""Adafactor (Shazeer & Stern 2018) with factored second moments and no
first moment — the optimizer-state footprint that lets the 480B/1T archs
fit 16 GB/chip HBM (DESIGN §6): state is O(rows + cols) per matrix.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: dict   # row second moments (or full v for rank<2 leaves)
    vc: dict   # col second moments (zeros for rank<2 leaves)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: float = 1e-3
    decay: float = 0.8       # beta2_t = 1 - step^-decay
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0

    def _factored(self, x) -> bool:
        return x.ndim >= 2

    def init(self, params) -> AdafactorState:
        def vr(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)   # reduce last dim
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(vr, params),
            vc=jax.tree.map(vc, params),
        )

    def update(self, grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps1
            if self._factored(p):
                vr2 = beta2 * vr + (1 - beta2) * g2.mean(-1)
                vc2 = beta2 * vc + (1 - beta2) * g2.mean(-2)
                denom = vr2.mean(-1, keepdims=True)[..., None]
                vhat = (vr2[..., None] * vc2[..., None, :]) / jnp.maximum(
                    denom, self.eps1
                )
                u = g * jax.lax.rsqrt(jnp.maximum(vhat, self.eps1))
            else:
                vr2 = beta2 * vr + (1 - beta2) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(jnp.maximum(vr2, self.eps1))
            # update clipping (RMS-based)
            rms_u = jnp.sqrt(jnp.mean(u * u) + self.eps1)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            scale = jnp.maximum(
                self.eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32))))
            )
            new_p = p.astype(jnp.float32) - self.lr * scale * u
            return new_p.astype(p.dtype), vr2, vc2

        def upd_leaf(p, g, vr, vc):
            # scan-stacked leaves (leading layer dim) update layer-by-layer:
            # bounds the f32 transients (g^2, vhat, u) to one layer's slice
            # instead of the whole stack (observed: ~20 GiB at 1T params).
            if p.ndim >= 3 and p.shape[0] > 1:
                def body(_, args):
                    out = upd(*args)
                    return None, out

                _, (np_, nvr, nvc) = jax.lax.scan(body, None, (p, g, vr, vc))
                return np_, nvr, nvc
            return upd(p, g, vr, vc)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_vr = tdef.flatten_up_to(state.vr)
        flat_vc = tdef.flatten_up_to(state.vc)
        out = [upd_leaf(p, g, vr, vc) for p, g, vr, vc in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_vr = tdef.unflatten([o[1] for o in out])
        new_vc = tdef.unflatten([o[2] for o in out])
        from repro.optim.adamw import global_norm

        return new_params, AdafactorState(step, new_vr, new_vc), global_norm(grads)


def make_optimizer(name: str, lr: float | None = None):
    if name == "adamw":
        return AdamW(lr=lr or 3e-4)
    if name == "adafactor":
        return Adafactor(lr=lr or 1e-3)
    raise ValueError(name)


from repro.optim.adamw import AdamW  # noqa: E402  (factory above)
