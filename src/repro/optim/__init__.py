from repro.optim.adafactor import Adafactor, AdafactorState, make_optimizer
from repro.optim.adamw import AdamW, AdamWState, global_norm

__all__ = [
    "Adafactor", "AdafactorState", "AdamW", "AdamWState",
    "global_norm", "make_optimizer",
]
