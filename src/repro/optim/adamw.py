"""AdamW as a pure pytree transform (no external deps)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p
        )
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))

    def update(self, grads, state: AdamWState, params):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        step = state.step + 1
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state.m, grads)
        v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, state.v, grads)

        def upd(p, m, v):
            mh, vh = m / b1c, v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), gnorm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
