"""qwen1.5-32b [dense] — Qwen1.5 with QKV bias: 64L d_model=5120 40H
(GQA kv=40, i.e. MHA) ff=27392 vocab=152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    optimizer="adamw",
    remat="full",
)

SMOKE = ArchConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
    remat="none",
)
