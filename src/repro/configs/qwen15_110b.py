"""qwen1.5-110b [dense] — Qwen1.5 architecture with QKV bias
(hf:Qwen/Qwen1.5-0.5B family): 80L d_model=8192 64H (GQA kv=8) ff=49152
vocab=152064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    optimizer="adafactor",
    remat="full",
)

SMOKE = ArchConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=128,
    qkv_bias=True,
    remat="none",
)
