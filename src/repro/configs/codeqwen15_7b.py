"""codeqwen1.5-7b [dense] — CodeQwen1.5-7B (hf:Qwen/CodeQwen1.5-7B):
32L d_model=4096 32H (kv=32) ff=13440 vocab=92416, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    optimizer="adamw",
    remat="dots",
)

SMOKE = ArchConfig(
    name="codeqwen1.5-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
    remat="none",
)
