"""mamba2-370m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1024, attention-free, vocab 50280, ssm_state=128, headdim 64
(d_inner = 2048 -> 32 ssm heads), tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=16,          # unused (attention-free)
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    optimizer="adamw",
    remat="full",
)

SMOKE = ArchConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=32,
    tie_embeddings=True,
    remat="none",
)
