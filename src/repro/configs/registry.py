"""Registry: --arch <id> -> (full CONFIG, reduced SMOKE)."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable

_MODULES = {
    "mamba2-370m": "repro.configs.mamba2_370m",
    "arctic-480b": "repro.configs.arctic_480b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def iter_cells():
    """Yield every runnable (arch, shape) dry-run cell + skip records."""
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(arch, shape)
            yield aid, sname, ok, why
