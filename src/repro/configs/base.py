"""Architecture + shape schema for the assigned LM zoo.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG`` (full size, dry-run only) and ``SMOKE`` (reduced, runs on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False    # arctic: dense MLP residual next to MoE
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    tail_pattern: tuple[str, ...] = ()    # leftover layers after full blocks
    rnn_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    window: int = 0                  # local-attention window
    # --- positional / misc ---
    qkv_bias: bool = False
    mrope: bool = False
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    rope_theta: float = 10000.0
    causal: bool = True
    is_decoder: bool = True          # False: encoder-only (no decode shapes)
    embed_inputs: bool = True        # False: inputs are precomputed embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- training-scale knobs ---
    param_dtype: str = "float32"     # 'bfloat16' for the 1T arch (DESIGN §6)
    activation_dtype: str = "float32"  # 'bfloat16': §Perf memory-term lever
    optimizer: str = "adamw"         # 'adafactor' for >=100B params
    remat: str = "full"              # 'none' | 'dots' | 'full'
    # attention chunking (blockwise/flash); 0 -> plain attention
    q_chunk: int = 512
    kv_chunk: int = 1024
    # forward-only causal chunk skipping (prefill/serve paths set this via
    # dataclasses.replace; it is not reverse-differentiable)
    attn_fwd_only: bool = False
    # triangular pair-scan attention: exact causal FLOPs, differentiable
    # (§Perf lever; see models.layers.pairscan_attention)
    attn_pairs: bool = False
    # replicate KV projections when kv_heads < TP degree instead of
    # row-paralleling them (kills the per-layer k/v all-reduce; §Perf lever)
    replicate_kv: bool = False
    # fully unroll every scan/loop so cost_analysis sees true trip counts.
    # Used ONLY by the dry-run's roofline calibration lowerings (XLA's
    # HloCostAnalysis counts while-loop bodies once).
    unroll_loops: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 256 multiple so embed/unembed shard cleanly on
        any production mesh (padded logit columns are masked to -inf)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM / hybrid-local only)"""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.window > 0
        )

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS and optimizer pick)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if not self.embed_inputs:
            emb = self.vocab_size * d  # output head only
        att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        mlp = 3 * d * self.d_ff
        per_layer = 0
        if self.family == "moe":
            moe = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
            per_layer = att + moe + (3 * d * self.d_ff if self.dense_residual else 0)
        elif self.family == "ssm":
            din = self.d_inner
            n = self.ssm_state
            per_layer = d * (2 * din + 2 * n + self.ssm_heads) \
                + din * d + self.conv_width * (din + 2 * n)
        elif self.family == "hybrid":
            w = self.rnn_width or d
            rec = d * w * 2 + w * d + 2 * w * (self.conv_width + 2) + mlp
            attn_l = att + mlp
            pat = self.block_pattern * (self.num_layers // max(len(self.block_pattern), 1)) \
                + self.tail_pattern
            n_rec = sum(1 for t in pat[: self.num_layers] if t == "rec")
            n_att = self.num_layers - n_rec
            return emb + n_rec * rec + n_att * attn_l
        else:
            per_layer = att + mlp
        return emb + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        act_moe = self.experts_per_token * 3 * d * self.d_ff \
            + d * self.num_experts
        dense = 3 * d * self.d_ff if self.dense_residual else 0
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (att + act_moe + dense)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]
    num_microbatches: int = 1


# The assigned shape set (LM-family: seq_len x global_batch).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", num_microbatches=16),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason if not."""
    if shape.kind == "decode" and not arch.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
