"""qwen2-vl-72b [vlm] — Qwen2-VL backbone with M-RoPE (arXiv:2409.12191):
80L d_model=8192 64H (GQA kv=8) ff=29568 vocab=152064.

Backbone only: the vision frontend is a STUB — ``input_specs`` provides
M-RoPE position triples (3, B, T) and (for multimodal batches) precomputed
patch embeddings; dynamic resolution is represented by the position ids.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    optimizer="adamw",
    remat="full",
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(2, 3, 3),
    remat="none",
)
