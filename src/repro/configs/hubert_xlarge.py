"""hubert-xlarge [audio] — encoder-only transformer, same backbone as
wav2vec2 (arXiv:2106.07447): 48L d_model=1280 16H (kv=16) ff=5120 vocab=504.

Modality frontend (CNN feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings (B, T, 512). Encoder-only: no decode
shapes (see DESIGN §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    is_decoder=False,
    embed_inputs=False,
    optimizer="adamw",
    remat="dots",
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    causal=False,
    is_decoder=False,
    embed_inputs=False,
    remat="none",
)
