"""kimi-k2-1t-a32b [moe] — Kimi K2 trillion-parameter MoE (paper-table,
arXiv:2501.kimi2): 61L d_model=7168 64H (GQA kv=8) expert ff=2048
vocab=163840, 384 experts top-8 (~32B active).

Scale notes (DESIGN §6): params are kept in bfloat16 and optimized with
Adafactor (factored second moment, no first moment) so the 1T parameter
state fits 16 GB/chip HBM on the 16x16 pod.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
)

SMOKE = ArchConfig(
    name="kimi-k2-1t-a32b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=128,
    num_experts=8,
    experts_per_token=4,
    param_dtype="bfloat16",
    remat="none",
)
