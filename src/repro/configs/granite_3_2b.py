"""granite-3-2b [dense] — IBM Granite 3.0 2B base, GQA
(hf:ibm-granite/granite-3.0-2b-base): 40L d_model=2048 32H (kv=8) ff=8192
vocab=49155.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    optimizer="adamw",
    remat="dots",
)

SMOKE = ArchConfig(
    name="granite-3-2b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    remat="none",
)
