"""arctic-480b [moe] — Snowflake Arctic: 128-expert top-2 MoE with a dense
residual MLP in every layer (hf:Snowflake/snowflake-arctic-base).

35L d_model=7168 56H (GQA kv=8) ff=4864 vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    dense_residual=True,
    optimizer="adafactor",
    remat="full",
)

SMOKE = ArchConfig(
    name="arctic-480b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=128,
    num_experts=8,
    experts_per_token=2,
    dense_residual=True,
    remat="none",
)
