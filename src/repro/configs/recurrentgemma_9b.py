"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 pattern
(arXiv:2402.19427): 38L d_model=4096 16H (MQA kv=1) ff=12288 vocab=256000,
local window 2048. 12 x (rec, rec, attn) blocks + (rec, rec) tail.

Sub-quadratic: the ``long_500k`` decode cell runs (O(1) recurrent state +
ring-buffered 2048-window KV).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    tail_pattern=("rec", "rec"),
    rnn_width=4096,
    window=2048,
    optimizer="adamw",
    remat="full",
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    block_pattern=("rec", "rec", "attn"),
    tail_pattern=("rec", "rec"),
    rnn_width=64,
    window=16,
    remat="none",
)
