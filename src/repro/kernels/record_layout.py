"""Packed page-record geometry — the ONE authoritative copy.

``core.layout.pack_page_records`` (producer), the ``page_scan`` Pallas
kernel, and the ``ref.page_scan_ref`` oracle (consumers) must agree on
where member vectors and neighbor-code rows live inside the (rows, 128)
record tile. This leaf module (no jax, no package imports — safe on both
sides of the core <-> kernels boundary) owns that arithmetic so the layout
can never silently desync from the kernels that read it.
"""
from __future__ import annotations

PAGE_LANES = 128  # f32 lane width of one record row (TPU tile minor dim)


def vectors_per_row(dim: int) -> int:
    """Member vectors packed side by side in one 128-lane record row
    (1 when a vector itself spans multiple rows, i.e. dim > 128)."""
    return max(1, PAGE_LANES // dim)


def rows_per_vector(dim: int) -> int:
    """Record rows one member vector spans (1 unless dim > 128)."""
    return -(-dim // PAGE_LANES)


def member_rows(capacity: int, dim: int) -> int:
    """Rows of the member-vector block of one packed page record."""
    if dim <= PAGE_LANES:
        return -(-capacity // vectors_per_row(dim))
    return capacity * rows_per_vector(dim)


def record_rows(capacity: int, dim: int, m_disk: int) -> int:
    """Row count of one packed page record: densely packed member vectors +
    M_disk transposed code rows, padded to the (8, 128) f32 tile."""
    return -(-(member_rows(capacity, dim) + m_disk) // 8) * 8
