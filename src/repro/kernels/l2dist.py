"""Pallas TPU kernel: batched squared-L2 distance via the MXU.

dist(q, x) = |q|^2 - 2 q.x + |x|^2 — the -2qx term is a (bq, d) x (d, bx)
matmul that lands on the MXU; the norms are VPU reductions. Tiles are
(block_q, d) and (block_x, d) VMEM blocks; d stays unblocked (ANN dims are
<= 1024, well within VMEM at f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l2_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)        # (bq, d)
    x = x_ref[...].astype(jnp.float32)        # (bx, d)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)          # (bq, 1)
    xx = jnp.sum(x * x, axis=-1, keepdims=True).T        # (1, bx)
    qx = jax.lax.dot_general(
        q, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (bq, bx)
    o_ref[...] = qq - 2.0 * qx + xx


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_x", "interpret")
)
def l2_distance(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_q: int = 128,
    block_x: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (Bq, d), x: (Nx, d) -> (Bq, Nx) f32. Pads to block multiples."""
    bq0, d = q.shape
    nx0, _ = x.shape
    bq = -(-bq0 // block_q) * block_q
    nx = -(-nx0 // block_x) * block_x
    qp = jnp.pad(q, ((0, bq - bq0), (0, 0)))
    xp = jnp.pad(x, ((0, nx - nx0), (0, 0)))
    out = pl.pallas_call(
        _l2_kernel,
        grid=(bq // block_q, nx // block_x),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_x, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_x), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bq, nx), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:bq0, :nx0]
