"""Pallas TPU kernel: page-aligned gather + member scoring — the paper's core
mechanism, TPU-native.

PageANN's insight is that one graph hop must equal one aligned unit of bulk
data movement. On SSD that unit is a 4 KB page; on TPU it is an HBM->VMEM DMA
of one page record. This kernel realizes it with *scalar-prefetched* page
ids: the (b,) batch of page ids selected by Alg. 2 lives in SMEM before the
grid runs, and the BlockSpec index_map uses it to DMA exactly page
``ids[i]``'s (cap, d) record into VMEM for grid step i — one page node ==
one aligned DMA burst, zero gather amplification. Member distances to the
query are then an MXU/VPU reduction over the resident block.

Double buffering of the next page's DMA against the current block's compute
is what Pallas' pipeline emitter does for this grid automatically — the TPU
equivalent of the paper's Linux-AIO I/O-computation pipeline (Sec 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _page_l2_kernel(ids_ref, pages_ref, q_ref, o_ref):
    del ids_ref  # consumed by the index_map (scalar prefetch)
    page = pages_ref[...].astype(jnp.float32)     # (1, cap, d)
    q = q_ref[...].astype(jnp.float32)            # (1, d)
    diff = page[0] - q                            # (cap, d)
    o_ref[...] = jnp.sum(diff * diff, axis=-1, keepdims=False)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather_l2(
    pages: jnp.ndarray,
    page_ids: jnp.ndarray,
    q: jnp.ndarray,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """pages: (P, cap, d), page_ids: (b,) int32 in [0, P), q: (d,)
    -> (b, cap) squared L2 member distances."""
    p, cap, d = pages.shape
    b = page_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cap, d), lambda i, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1, d), lambda i, ids: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, cap), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _page_l2_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, cap), jnp.float32),
        interpret=interpret,
    )(page_ids.astype(jnp.int32), pages, q[None, :])
