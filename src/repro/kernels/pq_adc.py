"""Pallas TPU kernel: PQ asymmetric distance computation (ADC).

TPU adaptation (DESIGN.md §2): the CPU-idiomatic per-code LUT *gather* is
replaced by a one-hot contraction — codes (bn, M) select rows of the LUT
(M, K) by building a (bn, M*K) one-hot mask and contracting against the
flattened LUT on the MXU. For M*K = 16*256 = 4K lanes this is a single
(bn x 4K) x (4K,) matvec per block: gather-free, systolic-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(codes_ref, lut_ref, o_ref):
    codes = codes_ref[...].astype(jnp.int32)     # (bn, M)
    lut = lut_ref[...].astype(jnp.float32)       # (M, K)
    m, k = lut.shape
    # one-hot over the K axis, keyed by code value
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], m, k), 2)
    onehot = (codes[:, :, None] == iota_k).astype(jnp.float32)  # (bn, M, K)
    flat = onehot.reshape(codes.shape[0], m * k)
    o_ref[...] = jax.lax.dot_general(
        flat, lut.reshape(m * k, 1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (bn, 1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_adc(
    codes: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """codes: (N, M) uint8, lut: (M, K) f32 -> (N,) f32 ADC distances."""
    n0, m = codes.shape
    n = -(-n0 // block_n) * block_n
    cp = jnp.pad(codes, ((0, n - n0), (0, 0)))
    out = pl.pallas_call(
        _adc_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(cp, lut)
    return out[:n0, 0]
