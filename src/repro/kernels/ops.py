"""Dispatching wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The search path calls these; on this CPU container they resolve to the
oracles (fast under XLA:CPU), while tests force ``impl='pallas'`` with
interpret=True to validate the TPU kernels themselves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import hamming as hamming_k
from repro.kernels import l2dist as l2_k
from repro.kernels import page_gather as pg_k
from repro.kernels import pq_adc as adc_k
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def l2_distance(q, x, *, impl: str | None = None, interpret: bool = False):
    use = impl or ("pallas" if _on_tpu() else "ref")
    if use == "pallas":
        return l2_k.l2_distance(q, x, interpret=interpret or not _on_tpu())
    return ref.l2_distance_ref(q, x)


def pq_adc(codes, lut, *, impl: str | None = None, interpret: bool = False):
    use = impl or ("pallas" if _on_tpu() else "ref")
    if use == "pallas":
        return adc_k.pq_adc(codes, lut, interpret=interpret or not _on_tpu())
    return ref.pq_adc_ref(codes, lut)


def hamming(codes, qcode, *, impl: str | None = None, interpret: bool = False):
    use = impl or ("pallas" if _on_tpu() else "ref")
    if use == "pallas":
        return hamming_k.hamming(
            codes, qcode, interpret=interpret or not _on_tpu()
        )
    return ref.hamming_ref(codes, qcode)


def page_gather_l2(pages, page_ids, q, *, impl: str | None = None,
                   interpret: bool = False):
    use = impl or ("pallas" if _on_tpu() else "ref")
    if use == "pallas":
        return pg_k.page_gather_l2(
            pages, page_ids, q, interpret=interpret or not _on_tpu()
        )
    return ref.page_gather_l2_ref(pages, page_ids, q)
