"""Dispatching wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The search path calls these; on this CPU container they resolve to the
oracles (fast under XLA:CPU), while tests force ``impl='pallas'`` with
interpret=True to validate the TPU kernels themselves. The backend probe
is resolved once per process (``_backend``) instead of re-querying
``jax.default_backend()`` on every hot-path dispatch; tests still override
the choice explicitly via ``impl=``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import hamming as hamming_k
from repro.kernels import l2dist as l2_k
from repro.kernels import page_gather as pg_k
from repro.kernels import page_scan as ps_k
from repro.kernels import pq_adc as adc_k
from repro.kernels import ref


@functools.cache
def _backend() -> str:
    return jax.default_backend()


def _on_tpu() -> bool:
    return _backend() == "tpu"


def _resolve(impl: str | None) -> str:
    return impl or ("pallas" if _on_tpu() else "ref")


def l2_distance(q, x, *, impl: str | None = None, interpret: bool = False):
    if _resolve(impl) == "pallas":
        return l2_k.l2_distance(q, x, interpret=interpret or not _on_tpu())
    return ref.l2_distance_ref(q, x)


def pq_adc(codes, lut, *, impl: str | None = None, interpret: bool = False):
    if _resolve(impl) == "pallas":
        return adc_k.pq_adc(codes, lut, interpret=interpret or not _on_tpu())
    return ref.pq_adc_ref(codes, lut)


def hamming(codes, qcode, *, impl: str | None = None, interpret: bool = False):
    if _resolve(impl) == "pallas":
        return hamming_k.hamming(
            codes, qcode, interpret=interpret or not _on_tpu()
        )
    return ref.hamming_ref(codes, qcode)


def page_gather_l2(pages, page_ids, q, *, impl: str | None = None,
                   interpret: bool = False):
    if _resolve(impl) == "pallas":
        return pg_k.page_gather_l2(
            pages, page_ids, q, interpret=interpret or not _on_tpu()
        )
    return ref.page_gather_l2_ref(pages, page_ids, q)


def delta_scan(q, vecs, live, k: int, *, mask=None, impl: str | None = None,
               interpret: bool = False):
    """Brute-force scan of the mutable index's in-memory delta tier.

    q: (Q, d) f32 queries, vecs: (C, d) f32 delta buffer (C a power of
    two), live: (C,) bool row-validity mask. Routes the distance matrix
    through the batched L2 kernel path (``l2dist`` on TPU, jnp oracle
    elsewhere), masks dead/padded rows to INF, and selects the per-query
    ascending top-k with ``lax.top_k``. ``mask`` (C,) bool is the
    filtered-search predicate over delta rows — rows failing it score INF
    exactly like dead rows (None leaves the program unchanged). Returns
    (dists (Q, k) f32, slots (Q, k) int32 row indices into ``vecs``);
    non-finite entries mean fewer than k live rows.
    """
    d = l2_distance(q, vecs, impl=impl, interpret=interpret)
    keep = live if mask is None else live & mask
    d = jnp.where(keep[None, :], d, jnp.inf)
    neg, slots = jax.lax.top_k(-d, k)
    return -neg, slots.astype(jnp.int32)


def page_scan(recs, page_ids, q, lut, *, capacity: int, dim: int, rp: int,
              compute_adc: bool = True, member_mask=None,
              impl: str | None = None, interpret: bool = False):
    """Fused per-page scan: one record DMA -> (member L2, neighbor ADC).

    ``member_mask`` (b, capacity) f32 pushes a filter predicate into the
    scan — members with mask <= 0 score +inf (None: unmasked program,
    unchanged)."""
    if _resolve(impl) == "pallas":
        return ps_k.page_scan(
            recs, page_ids, q, lut,
            capacity=capacity, dim=dim, rp=rp, compute_adc=compute_adc,
            member_mask=member_mask,
            interpret=interpret or not _on_tpu(),
        )
    return ref.page_scan_ref(
        recs, page_ids, q, lut,
        capacity=capacity, dim=dim, rp=rp, compute_adc=compute_adc,
        member_mask=member_mask,
    )


def page_scan_recs(recs_b, q, lut, *, capacity: int, dim: int, rp: int,
                   compute_adc: bool = True, member_mask=None,
                   impl: str | None = None, interpret: bool = False):
    """Fused scan on an already-staged (b, rows, 128) record batch — the
    streaming tier's scoring half (resident gathers + host-fetched misses
    merged upstream). Scores match ``page_scan`` bit for bit; the same
    ``member_mask`` applies (the mask is per page, not per origin)."""
    if _resolve(impl) == "pallas":
        return ps_k.page_scan_recs(
            recs_b, q, lut,
            capacity=capacity, dim=dim, rp=rp, compute_adc=compute_adc,
            member_mask=member_mask,
            interpret=interpret or not _on_tpu(),
        )
    return ref.page_scan_recs_ref(
        recs_b, q, lut,
        capacity=capacity, dim=dim, rp=rp, compute_adc=compute_adc,
        member_mask=member_mask,
    )
