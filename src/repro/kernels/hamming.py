"""Pallas TPU kernel: XOR + popcount Hamming sweep for the LSH router.

codes are packed 32-bit words; popcount is the classic SWAR bit-twiddle on
the VPU (no popcount intrinsic needed). One block = (block_s, W) codes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(codes_ref, q_ref, o_ref):
    c = codes_ref[...].astype(jnp.uint32)            # (bs, W)
    q = q_ref[...].astype(jnp.uint32)                # (1, W)
    v = jnp.bitwise_xor(c, q)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    pc = ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    o_ref[...] = pc.sum(-1, keepdims=True)           # (bs, 1)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def hamming(
    codes: jnp.ndarray,
    qcode: jnp.ndarray,
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """codes: (S, W) uint32, qcode: (W,) uint32 -> (S,) int32."""
    s0, w = codes.shape
    s = -(-s0 // block_s) * block_s
    cp = jnp.pad(codes, ((0, s - s0), (0, 0)))
    out = pl.pallas_call(
        _hamming_kernel,
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, 1), jnp.int32),
        interpret=interpret,
    )(cp, qcode[None, :])
    return out[:s0, 0]
