"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; tests sweep shapes/dtypes and
assert_allclose the kernel (interpret=True on CPU) against these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import record_layout


def l2_distance_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distances. q: (Bq, d), x: (Nx, d) -> (Bq, Nx) f32."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    return (
        (q * q).sum(-1)[:, None]
        - 2.0 * q @ x.T
        + (x * x).sum(-1)[None, :]
    )


def pq_adc_ref(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """ADC distance. codes: (N, M) uint8, lut: (M, K) f32 -> (N,) f32."""
    idx = codes.astype(jnp.int32)                     # (N, M)
    m = lut.shape[0]
    rows = jnp.arange(m)[None, :]                     # (1, M)
    return lut[rows, idx].astype(jnp.float32).sum(-1)


def hamming_ref(codes: jnp.ndarray, qcode: jnp.ndarray) -> jnp.ndarray:
    """Hamming distance between packed uint32 codes.

    codes: (S, W) uint32, qcode: (W,) uint32 -> (S,) int32.
    """
    v = jnp.bitwise_xor(codes, qcode[None, :]).astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    pc = ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
    return pc.sum(-1)


def page_gather_l2_ref(
    pages: jnp.ndarray, page_ids: jnp.ndarray, q: jnp.ndarray
) -> jnp.ndarray:
    """Gather page records and score members against the query.

    pages: (P, cap, d) f32, page_ids: (b,) int32 (>=0), q: (d,)
    -> (b, cap) squared L2 distances.
    """
    gathered = pages[page_ids]                         # (b, cap, d)
    diff = gathered.astype(jnp.float32) - q.astype(jnp.float32)[None, None, :]
    return (diff * diff).sum(-1)


def page_scan_recs_ref(
    recs_b: jnp.ndarray,
    q: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    capacity: int,
    dim: int,
    rp: int,
    compute_adc: bool = True,
    member_mask: jnp.ndarray | None = None,
):
    """``page_scan_ref`` on records that are ALREADY gathered/staged.

    recs_b: (b, rows, 128) f32 packed page records — the hop's batch as a
    dense array rather than (full store, ids). This is the scoring half of
    the fused scan, split out so the streaming page tier (resident subset
    on device + host-fetched misses) can score a mixed-origin batch.
    ``page_scan_ref`` routes through here, so the two are bit-identical by
    construction — the streaming path's guarantee.

    ``member_mask`` (b, capacity) f32: members with mask <= 0 score
    ``+inf`` — filtered search pushes its predicate into the scan here.
    Neighbor ADC is never masked: the graph must stay traversable
    through filtered-out regions.
    -> (member_d (b, capacity) f32, nbr_d (b, rp) f32 or None).
    """
    b = recs_b.shape[0]
    rv = record_layout.member_rows(capacity, dim)
    if dim <= record_layout.PAGE_LANES:
        vpr = record_layout.vectors_per_row(dim)
        block = recs_b[:, :rv, : vpr * dim]            # (b, Rv, vpr*d)
        vecs = block.reshape(b, rv * vpr, dim)[:, :capacity]
    else:
        rpv = record_layout.rows_per_vector(dim)
        block = recs_b[:, :rv, :]                      # (b, cap*rpv, 128)
        vecs = block.reshape(b, capacity, rpv * record_layout.PAGE_LANES)[
            :, :, :dim
        ]
    diff = vecs.astype(jnp.float32) - q.astype(jnp.float32)[None, None, :]
    member_d = (diff * diff).sum(-1)
    if member_mask is not None:
        member_d = jnp.where(member_mask > 0, member_d, jnp.inf)
    if not compute_adc:
        return member_d, None
    m = lut.shape[0]
    # subspace-major code rows: row Rv+j holds code j of every neighbor
    codes = recs_b[:, rv:rv + m, :rp].astype(jnp.int32)
    rows = jnp.arange(m)[None, :, None]                # (1, M, 1)
    nbr_d = lut[rows, codes].astype(jnp.float32).sum(1)  # (b, rp)
    return member_d, nbr_d


def page_scan_ref(
    recs: jnp.ndarray,
    page_ids: jnp.ndarray,
    q: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    capacity: int,
    dim: int,
    rp: int,
    compute_adc: bool = True,
    member_mask: jnp.ndarray | None = None,
):
    """Fused page scan: one packed-record gather, both score sets.

    recs: (P, rows, 128) f32 packed page records (see
    ``core.layout.pack_page_records``), page_ids: (b,) int32 (>=0),
    q: (d,), lut: (M_disk, K) f32, member_mask: optional (b, capacity)
    f32 filter mask (<= 0 scores +inf; see ``page_scan_recs_ref``).
    -> (member_d (b, capacity) f32, nbr_d (b, rp) f32 or None).
    """
    return page_scan_recs_ref(
        recs[page_ids], q, lut,
        capacity=capacity, dim=dim, rp=rp, compute_adc=compute_adc,
        member_mask=member_mask,
    )
