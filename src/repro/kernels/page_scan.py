"""Pallas TPU kernel: fused page scan — one DMA per page record, both score
sets.

PageANN's contract is that one graph hop costs exactly one aligned unit of
bulk data movement per page. The seed loop honored that on paper but read
each page record twice: ``page_gather_l2`` DMA'd the member vectors, then a
separate jnp gather re-fetched the same pages' neighbor PQ codes. This
kernel restores the invariant literally: the (b,) page-id batch selected by
Alg. 2 is *scalar-prefetched* into SMEM, and grid step i DMAs the whole
packed record of page ``ids[i]`` — member vectors, transposed neighbor
codes, and counts, one (rows, 128)-lane tile built by
``core.layout.pack_page_records`` mirroring the paper's on-page layout —
HBM->VMEM exactly once. From that single resident block it emits

  * exact member L2 distances  (VPU reduction over the member rows), and
  * neighbor ADC distances     (per-subspace one-hot MXU contraction against
    the query LUT, the gather-free trick from ``pq_adc.py``),

so one page == one DMA == both score sets. Double buffering of the next
record against the current block's compute falls out of Pallas' pipeline
emitter — the TPU analogue of the paper's Linux-AIO I/O-computation overlap.

Record layout (f32 lanes; arithmetic owned by ``kernels.record_layout``,
packed by ``core.layout.pack_page_records`` — ``vpr = 128 // d`` member
vectors per row for d <= 128, ``rpv = ceil(d / 128)`` rows per vector for
d > 128):
  rows [0, Rv)         member vectors, densely packed; Rv = member_rows
  rows [Rv, Rv+M)      neighbor PQ codes, subspace-major: row Rv+j holds
                       code j of neighbors 0..Rp-1 in cols [0, Rp)
  (rows padded to a multiple of 8 so the tile is (8, 128)-aligned)

Neighbor *ids* and the member/neighbor counts are not scored, so they ride
small int side arrays in ``SearchData`` rather than wasting f32 lanes here.

The transposed code block is what makes the ADC MXU-friendly: subspace j's
codes sit in one lane vector, so each of the M one-hot contractions is a
(1, K) x (K, 128) matmul with no in-kernel transpose or sub-lane gather.
The member rows score against a vpr-times-tiled query, so the dense packing
costs one segment-sum reshape, not a gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.record_layout import (
    PAGE_LANES as LANES,
    member_rows as _member_rows,
    rows_per_vector as _rpv,
    vectors_per_row as _vpr,
)


def _member_l2(rec, qt, cap, dim):
    """(1, rows, 128) record + (qrows, 128) tiled query -> (1, cap).

    d <= 128: vpr vectors per row, qt is the query tiled vpr times across
    one row's lanes. d > 128: each vector spans rpv rows, qt is the query
    laid out over rpv rows; the segment sum folds rows back per vector.
    """
    rv = _member_rows(cap, dim)
    if dim <= LANES:
        vpr = _vpr(dim)
        diff = rec[0, :rv, :] - qt                     # (Rv, 128)
        sq = diff * diff
        seg = sq[:, : vpr * dim].reshape(rv, vpr, dim).sum(-1)  # (Rv, vpr)
        return seg.reshape(rv * vpr)[:cap][None, :]
    rpv = _rpv(dim)
    diff = rec[0, :rv, :] - jnp.tile(qt, (cap, 1))     # (cap*rpv, 128)
    sq = (diff * diff).sum(-1)                         # (cap*rpv,)
    return sq.reshape(cap, rpv).sum(-1)[None, :]


def _neighbor_adc(rec, lut, row0, m):
    """One-hot MXU contraction over the transposed code rows -> (1, 128)."""
    ksub = lut.shape[1]
    acc = jnp.zeros((1, LANES), jnp.float32)
    for j in range(m):
        codes_j = rec[0, row0 + j : row0 + j + 1, :].astype(jnp.int32)  # (1,128)
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (ksub, LANES), 0)
        onehot = (iota_k == codes_j).astype(jnp.float32)                # (K,128)
        acc = acc + jax.lax.dot_general(
            lut[j : j + 1, :], onehot,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return acc


def _page_scan_kernel(ids_ref, recs_ref, q_ref, lut_ref, md_ref, nd_ref,
                      *, cap, dim, m):
    del ids_ref  # consumed by the index_map (scalar prefetch)
    rec = recs_ref[...].astype(jnp.float32)
    qt = q_ref[...].astype(jnp.float32)
    md_ref[...] = _member_l2(rec, qt, cap, dim)
    nd_ref[...] = _neighbor_adc(
        rec, lut_ref[...].astype(jnp.float32), _member_rows(cap, dim), m
    )


def _page_scan_members_kernel(ids_ref, recs_ref, q_ref, md_ref, *, cap, dim):
    del ids_ref
    rec = recs_ref[...].astype(jnp.float32)
    md_ref[...] = _member_l2(rec, q_ref[...].astype(jnp.float32), cap, dim)


def _page_scan_recs_kernel(recs_ref, q_ref, lut_ref, md_ref, nd_ref,
                           *, cap, dim, m):
    rec = recs_ref[...].astype(jnp.float32)
    qt = q_ref[...].astype(jnp.float32)
    md_ref[...] = _member_l2(rec, qt, cap, dim)
    nd_ref[...] = _neighbor_adc(
        rec, lut_ref[...].astype(jnp.float32), _member_rows(cap, dim), m
    )


def _page_scan_recs_members_kernel(recs_ref, q_ref, md_ref, *, cap, dim):
    rec = recs_ref[...].astype(jnp.float32)
    md_ref[...] = _member_l2(rec, q_ref[...].astype(jnp.float32), cap, dim)


# Masked variants — the filtered-search path. A (1, capacity) f32 mask row
# rides the same grid step as its record; members with mask <= 0 score
# +inf IN the kernel, so the hop's running top-k only ever holds passing
# candidates. Neighbor ADC is untouched: traversal must pass through
# filtered-out regions. Separate kernels (not a flag on the plain ones)
# keep the no-filter program byte-identical to the pre-filter build.
def _mask_inf(md, mask):
    return jnp.where(mask > 0, md, jnp.float32(jnp.inf))


def _page_scan_masked_kernel(ids_ref, recs_ref, q_ref, lut_ref, mask_ref,
                             md_ref, nd_ref, *, cap, dim, m):
    del ids_ref
    rec = recs_ref[...].astype(jnp.float32)
    qt = q_ref[...].astype(jnp.float32)
    md_ref[...] = _mask_inf(_member_l2(rec, qt, cap, dim), mask_ref[...])
    nd_ref[...] = _neighbor_adc(
        rec, lut_ref[...].astype(jnp.float32), _member_rows(cap, dim), m
    )


def _page_scan_members_masked_kernel(ids_ref, recs_ref, q_ref, mask_ref,
                                     md_ref, *, cap, dim):
    del ids_ref
    rec = recs_ref[...].astype(jnp.float32)
    md_ref[...] = _mask_inf(
        _member_l2(rec, q_ref[...].astype(jnp.float32), cap, dim),
        mask_ref[...],
    )


def _page_scan_recs_masked_kernel(recs_ref, q_ref, lut_ref, mask_ref,
                                  md_ref, nd_ref, *, cap, dim, m):
    rec = recs_ref[...].astype(jnp.float32)
    qt = q_ref[...].astype(jnp.float32)
    md_ref[...] = _mask_inf(_member_l2(rec, qt, cap, dim), mask_ref[...])
    nd_ref[...] = _neighbor_adc(
        rec, lut_ref[...].astype(jnp.float32), _member_rows(cap, dim), m
    )


def _page_scan_recs_members_masked_kernel(recs_ref, q_ref, mask_ref, md_ref,
                                          *, cap, dim):
    rec = recs_ref[...].astype(jnp.float32)
    md_ref[...] = _mask_inf(
        _member_l2(rec, q_ref[...].astype(jnp.float32), cap, dim),
        mask_ref[...],
    )


@functools.partial(
    jax.jit, static_argnames=("capacity", "dim", "rp", "compute_adc", "interpret")
)
def page_scan_recs(
    recs_b: jnp.ndarray,
    q: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    capacity: int,
    dim: int,
    rp: int,
    compute_adc: bool = True,
    interpret: bool = False,
    member_mask: jnp.ndarray | None = None,
):
    """``page_scan`` on an ALREADY-staged record batch: recs_b (b, rows,
    128) f32, q: (d,), lut: (M_disk, K) f32, member_mask: optional
    (b, capacity) f32 filter mask (<= 0 members score +inf in-kernel;
    None dispatches the unmasked kernels, whose program is unchanged).

    The scoring half of the fused scan for the streaming page tier: the
    hop's records arrive as a dense batch (resident gathers merged with
    host-fetched misses), so the grid walks them in order — no scalar
    prefetch, grid step i DMAs record i. Same per-record compute as the
    fused kernel (``_member_l2`` / ``_neighbor_adc``), so scores match the
    id-indexed path bit for bit.
    -> (member_d (b, capacity) f32, nbr_d (b, rp) f32 or None)
    """
    b, rows, lanes = recs_b.shape
    assert lanes == LANES and rp <= LANES
    m = lut.shape[0]
    if dim <= LANES:
        vpr = _vpr(dim)
        qt = jnp.zeros((1, LANES), jnp.float32).at[0, : vpr * dim].set(
            jnp.tile(q.astype(jnp.float32), vpr)
        )
    else:
        rpv = _rpv(dim)
        qt = (
            jnp.zeros((rpv * LANES,), jnp.float32)
            .at[:dim].set(q.astype(jnp.float32))
            .reshape(rpv, LANES)
        )
    rec_spec = pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))
    q_spec = pl.BlockSpec(qt.shape, lambda i: (0, 0))
    mask_spec = pl.BlockSpec((1, capacity), lambda i: (i, 0))
    if member_mask is not None:
        member_mask = member_mask.astype(jnp.float32)
    if not compute_adc:
        if member_mask is not None:
            md = pl.pallas_call(
                functools.partial(
                    _page_scan_recs_members_masked_kernel,
                    cap=capacity, dim=dim,
                ),
                grid=(b,),
                in_specs=[rec_spec, q_spec, mask_spec],
                out_specs=pl.BlockSpec((1, capacity), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((b, capacity), jnp.float32),
                interpret=interpret,
            )(recs_b, qt, member_mask)
            return md, None
        md = pl.pallas_call(
            functools.partial(
                _page_scan_recs_members_kernel, cap=capacity, dim=dim
            ),
            grid=(b,),
            in_specs=[rec_spec, q_spec],
            out_specs=pl.BlockSpec((1, capacity), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((b, capacity), jnp.float32),
            interpret=interpret,
        )(recs_b, qt)
        return md, None
    if member_mask is not None:
        md, nd = pl.pallas_call(
            functools.partial(
                _page_scan_recs_masked_kernel, cap=capacity, dim=dim, m=m
            ),
            grid=(b,),
            in_specs=[
                rec_spec,
                q_spec,
                pl.BlockSpec(lut.shape, lambda i: (0, 0)),
                mask_spec,
            ],
            out_specs=[
                pl.BlockSpec((1, capacity), lambda i: (i, 0)),
                pl.BlockSpec((1, LANES), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((b, capacity), jnp.float32),
                jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            ],
            interpret=interpret,
        )(recs_b, qt, lut.astype(jnp.float32), member_mask)
        return md, nd[:, :rp]
    md, nd = pl.pallas_call(
        functools.partial(_page_scan_recs_kernel, cap=capacity, dim=dim, m=m),
        grid=(b,),
        in_specs=[
            rec_spec,
            q_spec,
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity), lambda i: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, capacity), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(recs_b, qt, lut.astype(jnp.float32))
    return md, nd[:, :rp]


@functools.partial(
    jax.jit, static_argnames=("capacity", "dim", "rp", "compute_adc", "interpret")
)
def page_scan(
    recs: jnp.ndarray,
    page_ids: jnp.ndarray,
    q: jnp.ndarray,
    lut: jnp.ndarray,
    *,
    capacity: int,
    dim: int,
    rp: int,
    compute_adc: bool = True,
    interpret: bool = False,
    member_mask: jnp.ndarray | None = None,
):
    """recs: (P, rows, 128) packed page records, page_ids: (b,) int32 in
    [0, P), q: (d,), lut: (M_disk, K) f32 query LUT, member_mask:
    optional (b, capacity) f32 filter mask — per BATCH position (already
    gathered for the hop's pages), not per store page; <= 0 members
    score +inf in-kernel. None dispatches the unmasked kernels.

    -> (member_d (b, capacity) f32, nbr_d (b, rp) f32 or None)

    ``compute_adc=False`` (MEM_ALL mode: neighbor codes live in the memory
    tier) skips the ADC contraction entirely and returns ``nbr_d=None``.
    """
    p, rows, lanes = recs.shape
    assert lanes == LANES and rp <= LANES
    b = page_ids.shape[0]
    m = lut.shape[0]
    if dim <= LANES:
        vpr = _vpr(dim)
        qt = jnp.zeros((1, LANES), jnp.float32).at[0, : vpr * dim].set(
            jnp.tile(q.astype(jnp.float32), vpr)
        )
    else:
        rpv = _rpv(dim)
        qt = (
            jnp.zeros((rpv * LANES,), jnp.float32)
            .at[:dim].set(q.astype(jnp.float32))
            .reshape(rpv, LANES)
        )
    rec_spec = pl.BlockSpec((1, rows, lanes), lambda i, ids: (ids[i], 0, 0))
    q_spec = pl.BlockSpec(qt.shape, lambda i, ids: (0, 0))
    mask_spec = pl.BlockSpec((1, capacity), lambda i, ids: (i, 0))
    if member_mask is not None:
        member_mask = member_mask.astype(jnp.float32)
    if not compute_adc:
        if member_mask is not None:
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(b,),
                in_specs=[rec_spec, q_spec, mask_spec],
                out_specs=pl.BlockSpec((1, capacity), lambda i, ids: (i, 0)),
            )
            md = pl.pallas_call(
                functools.partial(
                    _page_scan_members_masked_kernel, cap=capacity, dim=dim
                ),
                grid_spec=grid_spec,
                out_shape=jax.ShapeDtypeStruct((b, capacity), jnp.float32),
                interpret=interpret,
            )(page_ids.astype(jnp.int32), recs, qt, member_mask)
            return md, None
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[rec_spec, q_spec],
            out_specs=pl.BlockSpec((1, capacity), lambda i, ids: (i, 0)),
        )
        md = pl.pallas_call(
            functools.partial(_page_scan_members_kernel, cap=capacity, dim=dim),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, capacity), jnp.float32),
            interpret=interpret,
        )(page_ids.astype(jnp.int32), recs, qt)
        return md, None

    if member_mask is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[
                rec_spec,
                q_spec,
                pl.BlockSpec(lut.shape, lambda i, ids: (0, 0)),
                mask_spec,
            ],
            out_specs=[
                pl.BlockSpec((1, capacity), lambda i, ids: (i, 0)),
                pl.BlockSpec((1, LANES), lambda i, ids: (i, 0)),
            ],
        )
        md, nd = pl.pallas_call(
            functools.partial(
                _page_scan_masked_kernel, cap=capacity, dim=dim, m=m
            ),
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((b, capacity), jnp.float32),
                jax.ShapeDtypeStruct((b, LANES), jnp.float32),
            ],
            interpret=interpret,
        )(page_ids.astype(jnp.int32), recs, qt, lut.astype(jnp.float32),
          member_mask)
        return md, nd[:, :rp]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            rec_spec,
            q_spec,
            pl.BlockSpec(lut.shape, lambda i, ids: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity), lambda i, ids: (i, 0)),
            pl.BlockSpec((1, LANES), lambda i, ids: (i, 0)),
        ],
    )
    md, nd = pl.pallas_call(
        functools.partial(_page_scan_kernel, cap=capacity, dim=dim, m=m),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, capacity), jnp.float32),
            jax.ShapeDtypeStruct((b, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(page_ids.astype(jnp.int32), recs, qt, lut.astype(jnp.float32))
    return md, nd[:, :rp]
