"""Metrics registry + Prometheus text exposition over the serving stack.

The serving layer already accumulates everything a scraper needs —
:class:`repro.serve.engine.EngineMetrics` (requests/latency/IO windows),
compile-cache hit counters, streaming-tier fetch counters, semantic-cache
:class:`CacheStats` — but only as one-shot Python snapshots. This module
turns those sources into a scrapeable surface:

  * :class:`MetricsRegistry` — named counters / gauges / histograms
    (explicit buckets), thread-safe, rendered via
    :meth:`MetricsRegistry.render` in Prometheus text exposition format
    (``text/plain; version=0.0.4``);
  * :func:`serve_registry` — the canonical wiring: a registry whose
    collector snapshots a ``BatchingEngine`` / ``VectorService``
    ``metrics()`` at scrape time and maps every field onto a series,
    plus per-collection residency gauges from ``VectorService.stats()``.

Counter semantics: every ``*_total`` series mirrors a cumulative,
monotone engine counter, and the engine captures all of its sources in
ONE lock-consistent snapshot (see ``BatchingEngine.metrics``), so two
scrapes never see e.g. ``fetch`` counters ahead of the ``requests`` they
belong to. Histograms are the exception: they expose the engine's
*trailing windows* (the same bounded deques behind the p50/p99 gauges),
recomputed per scrape — accurate for current-traffic quantiles, not
monotone across scrapes. They are labeled as such in HELP text; rate()
over them is meaningless, quantile estimation over them is exact.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Any, Callable, Iterable

import numpy as np

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# explicit default buckets for the serving-path distributions
LATENCY_MS_BUCKETS = (
    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0
)
HOP_BUCKETS = (1.0, 2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0)
IO_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)
FETCH_WALL_S_BUCKETS = (
    1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 1e-1, 5e-1
)


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers without the trailing .0."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_str(labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="' + v.replace("\\", "\\\\").replace('"', '\\"')
        .replace("\n", "\\n") + '"'
        for k, v in labels
    )
    return "{" + inner + "}"


class Metric:
    """One metric family: a name, a kind, and labeled samples.

    ``counter``/``gauge`` samples are scalars set via :meth:`set` /
    :meth:`inc`. ``histogram`` samples hold (bucket_counts, sum, count)
    against the family's explicit ``buckets``; fill them with
    :meth:`observe` (cumulative) or :meth:`observe_window` (replace with
    one window's distribution — the serving collector's mode).
    """

    def __init__(self, name: str, kind: str, help: str,
                 buckets: tuple[float, ...] | None = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        if kind not in ("counter", "gauge", "histogram"):
            raise ValueError(f"invalid metric kind {kind!r}")
        if kind == "histogram":
            if not buckets:
                raise ValueError(f"histogram {name!r} needs explicit buckets")
            b = tuple(float(x) for x in buckets)
            if list(b) != sorted(b) or len(set(b)) != len(b):
                raise ValueError(
                    f"histogram {name!r} buckets must be strictly increasing"
                )
            self.buckets = b
        else:
            if buckets is not None:
                raise ValueError(f"{kind} {name!r} takes no buckets")
            self.buckets = None
        self.name = name
        self.kind = kind
        self.help = help
        self._lock = threading.Lock()
        # labels tuple -> scalar, or -> [bucket_counts list, sum, count]
        self._samples: dict[tuple, Any] = {}

    @staticmethod
    def _key(labels: dict | None) -> tuple:
        if not labels:
            return ()
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r}")
        return tuple(sorted((k, str(v)) for k, v in labels.items()))

    # ----------------------------------------------------- scalar instruments
    def set(self, value: float, labels: dict | None = None) -> None:
        if self.kind == "histogram":
            raise TypeError(f"{self.name} is a histogram; use observe*")
        with self._lock:
            self._samples[self._key(labels)] = float(value)

    def inc(self, value: float = 1.0, labels: dict | None = None) -> None:
        if self.kind == "histogram":
            raise TypeError(f"{self.name} is a histogram; use observe*")
        key = self._key(labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(value)

    def value(self, labels: dict | None = None) -> float:
        with self._lock:
            return self._samples.get(self._key(labels), 0.0)

    # -------------------------------------------------- histogram instruments
    def _bucketize(self, values: np.ndarray) -> list:
        counts = [
            int(np.count_nonzero(values <= b)) for b in self.buckets
        ]
        return [counts, float(values.sum()), int(values.size)]

    def observe(self, value: float, labels: dict | None = None) -> None:
        if self.kind != "histogram":
            raise TypeError(f"{self.name} is a {self.kind}; use set/inc")
        key = self._key(labels)
        v = float(value)
        with self._lock:
            cell = self._samples.get(key)
            if cell is None:
                cell = [[0] * len(self.buckets), 0.0, 0]
                self._samples[key] = cell
            for i, b in enumerate(self.buckets):
                if v <= b:
                    cell[0][i] += 1
            cell[1] += v
            cell[2] += 1

    def observe_window(self, values, labels: dict | None = None) -> None:
        """Replace the sample with one trailing window's distribution
        (cumulative bucket counts over ``values``). Used by scrape-time
        collectors exposing bounded serving windows."""
        if self.kind != "histogram":
            raise TypeError(f"{self.name} is a {self.kind}; use set/inc")
        arr = np.asarray(values, np.float64).ravel()
        with self._lock:
            self._samples[self._key(labels)] = self._bucketize(arr)

    def clear_samples(self) -> None:
        with self._lock:
            self._samples.clear()

    # --------------------------------------------------------------- render
    def render(self) -> Iterable[str]:
        yield f"# HELP {self.name} {self.help}"
        yield f"# TYPE {self.name} {self.kind}"
        with self._lock:
            samples = dict(self._samples)
        for labels, v in sorted(samples.items()):
            if self.kind != "histogram":
                yield f"{self.name}{_labels_str(labels)} {_fmt(v)}"
                continue
            counts, total, count = v
            for b, c in zip(self.buckets, counts):
                lb = labels + (("le", _fmt(b)),)
                yield f"{self.name}_bucket{_labels_str(lb)} {c}"
            lb = labels + (("le", "+Inf"),)
            yield f"{self.name}_bucket{_labels_str(lb)} {count}"
            yield f"{self.name}_sum{_labels_str(labels)} {_fmt(total)}"
            yield f"{self.name}_count{_labels_str(labels)} {count}"


class MetricsRegistry:
    """Thread-safe registry of :class:`Metric` families plus scrape-time
    collectors. ``counter``/``gauge``/``histogram`` are create-or-get
    (re-declaring with a different kind raises); ``render()`` first runs
    every registered collector (which snapshots its source and updates
    instruments), then emits the exposition text in registration order."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Metric] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []

    def _declare(self, name: str, kind: str, help: str,
                 buckets=None) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"not {kind}"
                    )
                return m
            m = Metric(name, kind, help, buckets)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str) -> Metric:
        return self._declare(name, "counter", help)

    def gauge(self, name: str, help: str) -> Metric:
        return self._declare(name, "gauge", help)

    def histogram(self, name: str, help: str,
                  buckets: tuple[float, ...]) -> Metric:
        return self._declare(name, "histogram", help, buckets)

    def get(self, name: str) -> Metric:
        with self._lock:
            return self._metrics[name]

    def register_collector(
        self, fn: Callable[["MetricsRegistry"], None]
    ) -> None:
        """``fn(registry)`` runs at the top of every ``render()``."""
        with self._lock:
            self._collectors.append(fn)

    def render(self) -> str:
        with self._lock:
            collectors = list(self._collectors)
            metrics = list(self._metrics.values())
        for fn in collectors:
            fn(self)
        lines: list[str] = []
        for m in metrics:
            lines.extend(m.render())
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# the canonical serving wiring
# ---------------------------------------------------------------------------

# EngineMetrics field -> (series suffix, kind, help). Cumulative counters
# keep the Prometheus *_total convention; instantaneous aggregates are
# gauges.
_ENGINE_FIELDS = {
    "requests": ("requests_total", "counter",
                 "Completed search requests demuxed to futures"),
    "batches": ("batches_total", "counter", "Dispatched fixed-shape batches"),
    "inserts": ("inserts_total", "counter",
                "Vectors inserted through the engine write path"),
    "deletes": ("deletes_total", "counter",
                "Ids deleted through the engine write path"),
    "compactions": ("compactions_total", "counter",
                    "Delta-tier compactions folded into the base"),
    "early_exits": ("early_exits_total", "counter",
                    "Requests whose search exited before params.max_hops"),
    "sheds": ("sheds_total", "counter",
              "Requests shed by deadline expiry while queued"),
    "compile_hits": ("compile_hits_total", "counter",
                     "Dispatches served by an already-warm executable"),
    "compile_misses": ("compile_misses_total", "counter",
                       "Dispatches that compiled a new executable"),
    "pages_fetched": ("pages_fetched_total", "counter",
                      "Page records read off the host memmap (streaming)"),
    "fetch_hits": ("fetch_hits_total", "counter",
                   "Page requests served by the host staging cache"),
    "fetch_wall_s": ("fetch_wall_seconds_total", "counter",
                     "Wall seconds inside the host page-fetch callback"),
    "semantic_hits": ("semantic_hits_total", "counter",
                      "Submits served from the semantic query cache"),
    "semantic_misses": ("semantic_misses_total", "counter",
                        "Submits that fell through to a dispatch"),
    "semantic_evictions": ("semantic_evictions_total", "counter",
                           "Semantic-cache entries dropped by LRU or TTL"),
    "semantic_invalidations": ("semantic_invalidations_total", "counter",
                               "Semantic-cache entries dropped by writes"),
    "qps": ("qps", "gauge",
            "Completed requests / wall-clock first-submit..last-demux"),
    "latency_ms_mean": ("latency_ms_mean", "gauge",
                        "Mean request latency over the trailing window"),
    "latency_ms_p50": ("latency_ms_p50", "gauge",
                       "p50 request latency over the trailing window"),
    "latency_ms_p99": ("latency_ms_p99", "gauge",
                       "p99 request latency over the trailing window"),
    "mean_ios": ("mean_ios", "gauge", "Mean disk page reads per request"),
    "mean_hops": ("mean_hops", "gauge",
                  "Mean hop-loop iterations per request (trailing window)"),
    "p99_hops": ("p99_hops", "gauge",
                 "p99 hop-loop iterations per request (trailing window)"),
    "p99_ios": ("p99_ios", "gauge",
                "p99 disk page reads per request (trailing window)"),
    "mean_batch_occupancy": ("batch_occupancy_mean", "gauge",
                             "Real requests per dispatched batch"),
    "padded_fraction": ("padded_fraction", "gauge",
                        "Pad rows / dispatched rows"),
    "collections": ("collections", "gauge", "Registered collections"),
    "compiled_executables": ("compiled_executables", "gauge",
                             "Distinct compiled search signatures seen"),
}

# per-collection residency gauges pulled from VectorService.stats()
_COLLECTION_FIELDS = {
    "pages": ("collection_pages", "Total pages in the collection's disk tier"),
    "resident_pages": ("collection_resident_pages",
                       "Pages pinned device-resident (streaming split)"),
    "disk_bytes": ("collection_disk_bytes",
                   "On-disk bytes of the collection's page file"),
    "resident_bytes": ("collection_resident_bytes",
                       "Device-resident bytes of the collection's page tier"),
    "delta_live": ("collection_delta_live",
                   "Live rows in the collection's mutable delta tier"),
    "tombstones": ("collection_tombstones",
                   "Tombstoned base rows awaiting compaction"),
}

_WINDOW_HELP = (
    " (trailing-window distribution, recomputed per scrape; "
    "quantile-accurate for current traffic, not monotone)"
)


def serve_registry(
    source, *, namespace: str = "pageann",
    registry: MetricsRegistry | None = None,
) -> MetricsRegistry:
    """A registry scraping ``source`` — a ``BatchingEngine`` or
    ``VectorService`` — at render time.

    Every ``EngineMetrics`` field maps onto a ``{namespace}_*`` series
    (cumulative counters keep their monotone semantics; the engine
    snapshots all sources atomically, so a scrape is self-consistent).
    When the source exposes ``metrics_windows()`` the trailing latency /
    hops / ios / fetch-wall windows render as explicit-bucket histograms;
    when it exposes ``stats()`` (``VectorService``) each collection gets
    residency gauges labeled ``{collection="name"}``.
    """
    reg = registry if registry is not None else MetricsRegistry()
    instruments: dict[str, Metric] = {}
    for field, (suffix, kind, help) in _ENGINE_FIELDS.items():
        fn = reg.counter if kind == "counter" else reg.gauge
        instruments[field] = fn(f"{namespace}_{suffix}", help)
    h_lat = reg.histogram(
        f"{namespace}_request_latency_ms",
        "Request latency, submit to demux, milliseconds" + _WINDOW_HELP,
        LATENCY_MS_BUCKETS,
    )
    h_hops = reg.histogram(
        f"{namespace}_request_hops",
        "Hop-loop iterations per request" + _WINDOW_HELP,
        HOP_BUCKETS,
    )
    h_ios = reg.histogram(
        f"{namespace}_request_ios",
        "Disk page reads per request" + _WINDOW_HELP,
        IO_BUCKETS,
    )
    h_fetch = reg.histogram(
        f"{namespace}_fetch_wall_seconds",
        "Host page-fetch callback wall seconds per hop" + _WINDOW_HELP,
        FETCH_WALL_S_BUCKETS,
    )
    col_gauges = {
        key: reg.gauge(f"{namespace}_{suffix}", help)
        for key, (suffix, help) in _COLLECTION_FIELDS.items()
    }

    def collect(_reg: MetricsRegistry) -> None:
        m = source.metrics()
        for field, inst in instruments.items():
            inst.set(float(getattr(m, field)))
        windows_fn = getattr(source, "metrics_windows", None)
        if callable(windows_fn):
            win = windows_fn()
            h_lat.observe_window(win.get("latency_ms", ()))
            h_hops.observe_window(win.get("hops", ()))
            h_ios.observe_window(win.get("ios", ()))
            h_fetch.observe_window(win.get("fetch_wall_s", ()))
        stats_fn = getattr(source, "stats", None)
        if callable(stats_fn):
            for name, st in stats_fn().items():
                flat = dict(st)
                base = st.get("base")
                if isinstance(base, dict):
                    for k, v in base.items():
                        flat.setdefault(k, v)
                for key, inst in col_gauges.items():
                    if key in flat and isinstance(flat[key], (int, float)):
                        inst.set(float(flat[key]),
                                 labels={"collection": name})

    reg.register_collector(collect)
    return reg


# ---------------------------------------------------------------------------
# exposition parsing (tests, self-checks, the CI scrape gate)
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$"
)
_LABEL_PAIR_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Parse text exposition into ``{series_name: [(labels, value), ...]}``.

    Strict enough to be a format gate: any non-comment, non-blank line
    that does not parse as a sample raises ``ValueError``."""
    out: dict[str, list[tuple[dict, float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {line!r}")
        labels = {
            k: v.replace('\\"', '"').replace("\\n", "\n")
            .replace("\\\\", "\\")
            for k, v in _LABEL_PAIR_RE.findall(m.group("labels") or "")
        }
        raw = m.group("value")
        value = float("inf") if raw == "+Inf" else (
            float("-inf") if raw == "-Inf" else float(raw)
        )
        out.setdefault(m.group("name"), []).append((labels, value))
    return out


def sample_value(
    parsed: dict[str, list[tuple[dict, float]]], name: str, **labels: str
) -> float:
    """The value of ``name`` whose labels are a superset of ``labels``;
    KeyError when absent (the scrape gate's assertion primitive)."""
    for got, value in parsed.get(name, ()):
        if all(got.get(k) == str(v) for k, v in labels.items()):
            return value
    raise KeyError(f"no sample {name} with labels {labels}")
