"""Observability subsystem: request tracing, metrics exposition, search
profiling.

Three layers, each usable on its own:

  * :mod:`repro.obs.trace` — a lightweight thread-safe span tracer
    (bounded ring buffer, injected monotonic clock, ~zero cost when
    disabled) the serving stack threads through the whole query path:
    ``submit -> queue_wait -> batch_assemble -> compile ->
    device_dispatch -> demux``, plus child spans for semantic-cache
    lookups, streaming-tier host page fetches, and mutable-index writes.
    Exports Chrome ``trace_event`` JSON so a request's life is viewable
    in Perfetto (https://ui.perfetto.dev).
  * :mod:`repro.obs.metrics` — a registry of named counters / gauges /
    histograms wrapping the existing ``EngineMetrics`` / ``CacheStats`` /
    compile-cache / fetch counters as sources, rendered as Prometheus
    text exposition; :mod:`repro.obs.server` serves it over a tiny stdlib
    ``http.server`` sidecar (``/metrics``, ``/healthz``, ``/stats``).
  * per-hop search profiling — ``PageANNIndex.profile(queries)``
    (``core.search.profile_search``) captures the beam's per-hop trail
    without touching the compiled fast path; ``python -m
    repro.obs.report`` renders a trace or profile into a human-readable
    phase breakdown.

The serving layer never imports this package on its hot path — tracers
and registries are injected (duck-typed), so observability stays an
opt-in layer, not a dependency of the query loop.
"""
from repro.obs.metrics import (
    MetricsRegistry,
    parse_prometheus_text,
    sample_value,
    serve_registry,
)
from repro.obs.server import MetricsServer
from repro.obs.trace import NULL_TRACER, Span, Tracer

__all__ = [
    "MetricsRegistry",
    "MetricsServer",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "parse_prometheus_text",
    "sample_value",
    "serve_registry",
]
