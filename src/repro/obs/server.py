"""Stdlib HTTP sidecar exposing the observability surface.

:class:`MetricsServer` wraps a ``ThreadingHTTPServer`` on a daemon
thread serving three read-only endpoints:

  * ``GET /metrics``  — Prometheus text exposition from the registry
    (``text/plain; version=0.0.4``);
  * ``GET /healthz``  — ``ok`` once the serving source answers a
    ``metrics()`` snapshot, 503 with the error otherwise;
  * ``GET /stats``    — JSON dump: the full ``EngineMetrics`` snapshot
    plus per-collection stats (residency split, delta fill) when the
    source exposes ``stats()``.

No third-party dependencies — the sidecar must run wherever the serving
CLI runs. Bind with ``port=0`` to take an ephemeral port (``.port``
reports the bound one), which is how tests and the CI smoke scrape a
just-started server without a port race.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _jsonable(obj):
    """Best-effort conversion of stats payloads (NamedTuples, numpy
    scalars, nested dicts) into JSON-serializable structures."""
    if hasattr(obj, "_asdict"):
        return {k: _jsonable(v) for k, v in obj._asdict().items()}
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, bool)) or obj is None:
        return obj
    if isinstance(obj, (int, float)):
        return obj
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:
            pass
    return repr(obj)


class MetricsServer:
    """Serve ``registry`` (and optionally ``source`` stats) over HTTP.

    ``source`` is duck-typed: ``metrics()`` backs ``/healthz`` and the
    snapshot half of ``/stats``; ``stats()``, when present, adds the
    per-collection residency dump. Runs on a daemon thread; ``close()``
    shuts the listener down (also a context manager).
    """

    def __init__(self, registry, *, source=None, host: str = "127.0.0.1",
                 port: int = 0):
        self._registry = registry
        self._source = source

        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # quiet by default
                pass

            def _reply(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        body = server._registry.render().encode()
                        self._reply(200, body, PROMETHEUS_CONTENT_TYPE)
                    elif path == "/healthz":
                        server._check_health()
                        self._reply(200, b"ok\n", "text/plain")
                    elif path == "/stats":
                        body = json.dumps(
                            server._stats_payload(), indent=2
                        ).encode()
                        self._reply(200, body, "application/json")
                    else:
                        self._reply(404, b"not found\n", "text/plain")
                except Exception as exc:  # noqa: BLE001 — surface as 503
                    self._reply(
                        503, f"unhealthy: {exc}\n".encode(), "text/plain"
                    )

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="obs-metrics-server",
            daemon=True,
        )
        self._thread.start()

    def _check_health(self) -> None:
        if self._source is not None:
            self._source.metrics()  # raises if the engine is wedged

    def _stats_payload(self) -> dict:
        payload: dict = {}
        if self._source is not None:
            payload["metrics"] = _jsonable(self._source.metrics())
            stats_fn = getattr(self._source, "stats", None)
            if callable(stats_fn):
                payload["collections"] = _jsonable(stats_fn())
        return payload

    @property
    def port(self) -> int:
        """The bound port (useful with ``port=0``)."""
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False
