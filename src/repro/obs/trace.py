"""Lightweight request tracer: bounded span ring buffer, Chrome export.

The serving stack emits one :class:`Span` per phase of a request's life
(``submit``, ``queue_wait``, ``device_dispatch``, …) plus child spans for
the host work hanging off a dispatch (semantic-cache lookup, streaming
page fetches, mutable-index writes). Design constraints, in order:

  * **~zero cost when disabled** — every emission point guards on
    ``tracer.enabled`` (or on the tracer being ``None``) before touching
    the clock or building args, and :meth:`Tracer.span` returns one
    shared no-op context manager, so a disabled tracer adds a single
    attribute check to the hot path;
  * **bounded** — spans land in a ring buffer (``capacity``); a server
    left tracing for a week drops the oldest spans, never grows;
  * **thread-safe** — the engine dispatches from submitter and timer
    threads concurrently; appends and snapshots take one small lock;
  * **testable** — the clock is injected (monotonic by contract). Spans
    recorded with :meth:`Tracer.add` carry caller-supplied timestamps,
    so the engine can stamp spans with ITS injected clock and the trace
    stays coherent under a fake clock. For a coherent multi-component
    trace, inject the same clock everywhere (the default everywhere is
    ``time.perf_counter``).

Export: :meth:`Tracer.to_chrome_json` emits Chrome ``trace_event``
format — complete (``ph: "X"``) events in microseconds with one tid per
track name and thread-name metadata — loadable in Perfetto or
``chrome://tracing``, so "where did this request's 40 ms go" is a
zoomable timeline, not a log-grep.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Callable, NamedTuple


class Span(NamedTuple):
    """One timed phase. ``ts``/``dur`` are seconds on the tracer's clock;
    ``track`` names the Perfetto row the span renders on (``"engine"``,
    ``"req-17"``, ``"host-fetch"``, …)."""

    name: str
    cat: str
    track: str
    ts: float
    dur: float
    args: dict


class _NullSpan:
    """Shared no-op context manager returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Context manager recording one span on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_args", "_t0")

    def __init__(self, tracer, name, cat, track, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._args = args
        self._t0 = tracer._clock()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer.add(
            self._name, self._t0, self._tracer._clock(),
            cat=self._cat, track=self._track, args=self._args,
        )
        return False


class Tracer:
    """Thread-safe span collector over a bounded ring buffer.

    ``capacity`` bounds retained spans (oldest dropped, ``dropped``
    counts them). ``enabled`` can be toggled at runtime; emission points
    are expected to guard on it so a disabled tracer costs one attribute
    read. ``clock`` must be monotonic; it is injected for testability
    and for timebase coherence with the serving engine's own clock.
    """

    def __init__(
        self,
        *,
        capacity: int = 65536,
        enabled: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: collections.deque[Span] = collections.deque(
            maxlen=capacity
        )
        self._capacity = capacity
        self._dropped = 0
        self.enabled = bool(enabled)

    # -------------------------------------------------------------- recording
    def now(self) -> float:
        """The tracer's clock — for callers stamping spans themselves."""
        return self._clock()

    def span(self, name: str, *, cat: str = "", track: str = "main",
             **args: Any):
        """Context manager timing one span; a no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _LiveSpan(self, name, cat, track, args)

    def add(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        cat: str = "",
        track: str = "main",
        args: dict | None = None,
    ) -> None:
        """Record a span from caller-supplied timestamps (same timebase as
        the tracer's clock). No-op when disabled."""
        if not self.enabled:
            return
        span = Span(
            name=name, cat=cat, track=track,
            ts=float(t0), dur=max(0.0, float(t1) - float(t0)),
            args=args or {},
        )
        with self._lock:
            if len(self._spans) == self._capacity:
                self._dropped += 1
            self._spans.append(span)

    def instant(self, name: str, *, cat: str = "", track: str = "main",
                **args: Any) -> None:
        """Record a zero-duration marker at the current clock reading."""
        if not self.enabled:
            return
        t = self._clock()
        self.add(name, t, t, cat=cat, track=track, args=args)

    # -------------------------------------------------------------- querying
    def spans(self) -> list[Span]:
        """Snapshot of retained spans, in recording order."""
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    @property
    def dropped(self) -> int:
        """Spans evicted by the ring buffer since the last ``clear``."""
        with self._lock:
            return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._dropped = 0

    # --------------------------------------------------------------- export
    def to_chrome_json(self) -> str:
        """Chrome ``trace_event`` JSON (Perfetto / chrome://tracing).

        Each distinct ``track`` becomes one tid (named via thread-name
        metadata events); timestamps are microseconds relative to the
        earliest retained span, so a trace started hours into a process
        still opens at t=0."""
        spans = sorted(self.spans(), key=lambda s: s.ts)
        t0 = spans[0].ts if spans else 0.0
        tids: dict[str, int] = {}
        events: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "repro-serve"}},
        ]
        body: list[dict] = []
        for s in spans:
            tid = tids.get(s.track)
            if tid is None:
                tid = len(tids) + 1
                tids[s.track] = tid
                events.append(
                    {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                     "args": {"name": s.track}}
                )
            body.append(
                {
                    "name": s.name,
                    "cat": s.cat or "default",
                    "ph": "X",
                    "pid": 1,
                    "tid": tid,
                    "ts": round((s.ts - t0) * 1e6, 3),
                    "dur": round(s.dur * 1e6, 3),
                    "args": s.args,
                }
            )
        return json.dumps(
            {"traceEvents": events + body, "displayTimeUnit": "ms"}
        )

    def save(self, path: str) -> None:
        """Write :meth:`to_chrome_json` to ``path``."""
        with open(path, "w") as f:
            f.write(self.to_chrome_json())

    def __repr__(self) -> str:
        return (
            f"Tracer(spans={len(self)}, capacity={self._capacity}, "
            f"enabled={self.enabled})"
        )


# A process-wide disabled tracer for call sites that want an always-valid
# tracer object rather than Optional handling. Never records anything.
NULL_TRACER = Tracer(capacity=1, enabled=False)
