"""Render a captured trace or search profile as a phase breakdown.

``python -m repro.obs.report FILE.json`` sniffs the payload:

  * a Chrome ``trace_event`` capture (``Tracer.save`` /
    ``to_chrome_json``) renders per-phase aggregates — count, total /
    mean / p95 / max wall — grouped by span name, plus a per-track
    summary, answering "where did the wall time go" without opening
    Perfetto;
  * a search profile (``PageANNIndex.profile(..., save=...)``) renders
    the per-hop trail — pages scheduled, disk IOs vs cache hits, the
    shrinking worst-of-top-k frontier and the adaptive stall counter —
    per query, answering "why was THIS query slow".

The render functions are importable (``render_trace`` /
``render_profile``) so tests and notebooks can format in-memory captures
without the filesystem round-trip.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _quantile(vals: list[float], q: float) -> float:
    return float(np.quantile(np.asarray(vals, np.float64), q)) if vals else 0.0


def render_trace(payload: dict, *, top: int = 30) -> str:
    """Phase breakdown of a Chrome ``trace_event`` payload."""
    events = [
        e for e in payload.get("traceEvents", ())
        if e.get("ph") == "X"
    ]
    tid_names = {
        e.get("tid"): e["args"]["name"]
        for e in payload.get("traceEvents", ())
        if e.get("ph") == "M" and e.get("name") == "thread_name"
    }
    if not events:
        return "trace: no complete events\n"
    by_name: dict[str, list[float]] = {}
    by_track: dict[str, list[float]] = {}
    t_lo = min(e["ts"] for e in events)
    t_hi = max(e["ts"] + e.get("dur", 0.0) for e in events)
    for e in events:
        dur = float(e.get("dur", 0.0))
        by_name.setdefault(e["name"], []).append(dur)
        track = tid_names.get(e.get("tid"), f"tid-{e.get('tid')}")
        by_track.setdefault(track, []).append(dur)

    lines = [
        f"trace: {len(events)} spans over {(t_hi - t_lo) / 1e3:.3f} ms "
        f"wall, {len(by_name)} phases, {len(by_track)} tracks",
        "",
        f"{'phase':<28} {'count':>7} {'total_ms':>10} {'mean_us':>10} "
        f"{'p95_us':>10} {'max_us':>10}",
    ]
    ranked = sorted(by_name.items(), key=lambda kv: -sum(kv[1]))
    for name, durs in ranked[:top]:
        lines.append(
            f"{name[:28]:<28} {len(durs):>7} {sum(durs) / 1e3:>10.3f} "
            f"{sum(durs) / len(durs):>10.1f} "
            f"{_quantile(durs, 0.95):>10.1f} {max(durs):>10.1f}"
        )
    if len(ranked) > top:
        lines.append(f"... {len(ranked) - top} more phases")
    lines += ["", f"{'track':<28} {'spans':>7} {'total_ms':>10}"]
    for track, durs in sorted(by_track.items(), key=lambda kv: -sum(kv[1])):
        lines.append(
            f"{track[:28]:<28} {len(durs):>7} {sum(durs) / 1e3:>10.3f}"
        )
    return "\n".join(lines) + "\n"


def profile_to_dict(result, profile) -> dict:
    """JSON-able dump of (``SearchResult``, ``HopProfile``) from
    ``core.search.profile_search`` — the on-disk profile format."""
    return {
        "kind": "pageann_profile",
        "ids": np.asarray(result.ids).tolist(),
        "dists": np.asarray(result.dists, np.float64).tolist(),
        "ios": np.asarray(result.ios).tolist(),
        "hops": np.asarray(result.hops).tolist(),
        "cache_hits": np.asarray(result.cache_hits).tolist(),
        "hop_pages": np.asarray(profile.pages).tolist(),
        "hop_ios": np.asarray(profile.ios).tolist(),
        "hop_cache_hits": np.asarray(profile.cache_hits).tolist(),
        "hop_active": np.asarray(profile.active).astype(bool).tolist(),
        "hop_worst_topk": np.asarray(
            profile.worst_topk, np.float64
        ).tolist(),
        "hop_stall": np.asarray(profile.stall).tolist(),
    }


def render_profile(payload: dict, *, queries: int | None = None) -> str:
    """Per-hop trail of a saved search profile, one block per query."""
    active = payload["hop_active"]
    nq = len(active)
    shown = nq if queries is None else min(queries, nq)
    lines = [f"profile: {nq} queries" +
             (f" (showing {shown})" if shown < nq else "")]
    for qi in range(shown):
        hops = int(payload["hops"][qi])
        lines += [
            "",
            f"query {qi}: hops={hops} ios={payload['ios'][qi]} "
            f"cache_hits={payload['cache_hits'][qi]} "
            f"top1={payload['dists'][qi][0]:.4f} "
            f"(id {payload['ids'][qi][0]})",
            f"  {'hop':>3} {'ios':>4} {'hits':>4} {'stall':>5} "
            f"{'worst_topk':>12}  pages",
        ]
        for h, act in enumerate(active[qi]):
            if not act:
                continue
            pages = [p for p in payload["hop_pages"][qi][h] if p >= 0]
            worst = payload["hop_worst_topk"][qi][h]
            worst_s = f"{worst:>12.4f}" if np.isfinite(worst) else (
                f"{'inf':>12}"
            )
            lines.append(
                f"  {h:>3} {payload['hop_ios'][qi][h]:>4} "
                f"{payload['hop_cache_hits'][qi][h]:>4} "
                f"{payload['hop_stall'][qi][h]:>5} {worst_s}  "
                f"{pages}"
            )
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a Chrome trace or PageANN search profile "
        "as a human-readable phase breakdown.",
    )
    ap.add_argument("file", help="trace.json (Tracer.save) or profile.json "
                    "(PageANNIndex.profile save=)")
    ap.add_argument("--queries", type=int, default=None,
                    help="profile mode: show only the first N queries")
    ap.add_argument("--top", type=int, default=30,
                    help="trace mode: show only the top N phases")
    args = ap.parse_args(argv)

    with open(args.file) as f:
        payload = json.load(f)
    if payload.get("kind") == "pageann_profile":
        sys.stdout.write(render_profile(payload, queries=args.queries))
    elif "traceEvents" in payload:
        sys.stdout.write(render_trace(payload, top=args.top))
    else:
        sys.stderr.write(
            "unrecognized payload: expected traceEvents or "
            "kind=pageann_profile\n"
        )
        return 2
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
