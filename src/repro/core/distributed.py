"""Distributed PageANN: independent sharding over a TPU mesh.

The index is partitioned into S shards (S == size of the ``data`` mesh axis);
each shard is a complete PageANN sub-index over a slice of the vectors.
Queries are sharded over the ``model`` axis (throughput dimension, the
paper's "query threads"). A query executes as:

  local beam search on this device's shard   (shard_map block)
  -> all_gather(k local results) over 'data'
  -> global top-k merge

which is the "independent sharding" design surveyed in the paper's §7,
mapped onto jax-native collectives. The cross-shard merge is one all-gather
of (k ids + k distances) per query — tiny — so the collective roofline term
stays negligible (see EXPERIMENTS.md §Roofline pageann rows).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core import search as search_mod
from repro.core.config import PageANNConfig, SearchParams

PAD = -1


class ShardedIndex(NamedTuple):
    """SearchData pytree with a leading shard axis on every array, plus the
    per-shard id->original-id maps (host side)."""

    data: search_mod.SearchData        # every leaf: (S, ...)
    new_to_old: np.ndarray             # (S, P*cap) original ids, PAD padded
    capacity: int


def partition_vectors(x: np.ndarray, num_shards: int, seed: int = 0):
    """Balanced random partition (independent sharding)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    return np.array_split(perm, num_shards)


def build_sharded_index(
    x: np.ndarray, cfg: PageANNConfig, num_shards: int
) -> ShardedIndex:
    """Build per-shard sub-indexes and stack them to identical shapes."""
    from repro.core.index import PageANNIndex

    parts = partition_vectors(x, num_shards, cfg.seed)
    idxs = [PageANNIndex.build(x[p], cfg) for p in parts]
    return stack_shards(idxs, parts)


def stack_shards(idxs, parts) -> ShardedIndex:
    """Stack already-built per-shard sub-indexes (``PageANNIndex`` each,
    over the id slices in ``parts``) into one ``ShardedIndex`` whose leaves
    carry a leading shard axis — the shard_map input layout.  Ragged shards
    are padded to the largest shard's page count; the pad slots carry
    member_count 0 / PAD ids, and the merge in :func:`make_sharded_search`
    masks them out explicitly."""
    num_shards = len(idxs)
    max_pages = max(i.store.num_pages for i in idxs)
    cap = idxs[0].store.capacity

    def pad_pages(d: search_mod.SearchData, pages: int) -> search_mod.SearchData:
        pad = max_pages - pages

        def padp(a, fill):
            if pad == 0:
                return a
            widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
            return jnp.pad(a, widths, constant_values=fill)

        return d._replace(
            page_recs=padp(d.page_recs, 0.0),
            member_count=padp(d.member_count, 0),
            nbr_ids=padp(d.nbr_ids, PAD),
            nbr_count=padp(d.nbr_count, 0),
            # sharded shards are always fully resident: identity residency
            # over the padded page axis (pad pages map to their zero recs)
            resident_map=jnp.arange(max_pages, dtype=jnp.int32),
        )

    datas = [pad_pages(i.data, i.store.num_pages) for i in idxs]
    # mem_codes are sized P*cap per shard -> pad to max
    nmax = max_pages * cap

    def pad_mem(d):
        padn = nmax - d.mem_codes.shape[0]
        return d._replace(
            mem_codes=jnp.pad(d.mem_codes, [(0, padn), (0, 0)]),
            mem_mask=jnp.pad(d.mem_mask, [(0, padn)]),
        )

    datas = [pad_mem(d) for d in datas]
    # cached_pages may differ in length; pad with a sentinel beyond range
    cmax = max(d.cached_pages.shape[0] for d in datas)
    datas = [
        d._replace(
            cached_pages=jnp.pad(
                d.cached_pages,
                [(0, cmax - d.cached_pages.shape[0])],
                constant_values=np.int32(2**31 - 1) if cmax else 0,
            )
        )
        for d in datas
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *datas)

    n2o = np.full((num_shards, nmax), PAD, np.int64)
    for s, (i, p) in enumerate(zip(idxs, parts)):
        local = i.store.new_to_old  # local original ids within shard slice
        valid = local != PAD
        row = np.full(nmax, PAD, np.int64)
        row[: len(local)][valid] = p[local[valid]]
        n2o[s] = row
    return ShardedIndex(data=stacked, new_to_old=n2o, capacity=cap)


def make_sharded_search(
    mesh: Mesh,
    cfg: PageANNConfig,
    capacity: int,
    k: int,
    *,
    params: SearchParams | None = None,
    shard_axis: str = "data",
    query_axis: str = "model",
):
    """Returns (jitted_fn, in_shardings) executing the sharded search.

    stacked SearchData leaves are sharded P(shard_axis); queries (Q, d) are
    sharded P(query_axis); outputs (Q, k) are sharded P(query_axis).
    ``params`` defaults to the config's search knobs.
    """
    p = (params or SearchParams.from_config(cfg)).replace(k=k)
    mode = cfg.memory_mode.value

    def local_search(data_blk, q_blk):
        # data_blk leaves: (1, ...) — this device's shard
        data = jax.tree.map(lambda a: a[0], data_blk)
        res = search_mod.batch_search(
            q_blk, data, p, capacity=capacity, mode=mode
        )
        # Mask pad-slot candidates BEFORE the cross-shard merge.  A ragged
        # partition pads every shard to the largest shard's page count, so a
        # shard-local id can point at a pad slot (slot >= member_count of
        # its page, or a wholly padded page with member_count 0).  The
        # search kernel masks those to inf today, but the merge must not
        # depend on that: a pad candidate that ranked would displace a real
        # candidate from another shard and surface as PAD after
        # ``translate_ids``.  Validity is derivable on-device from
        # member_count alone, so enforce it here.
        page = jnp.clip(res.ids, 0) // capacity
        slot = jnp.clip(res.ids, 0) % capacity
        real = (res.ids >= 0) & (slot < data.member_count[page])
        tagged = jnp.where(real, res.ids, PAD)
        dists = jnp.where(real, res.dists, jnp.inf)
        # gather every shard's candidates for these queries
        all_ids = jax.lax.all_gather(tagged, shard_axis)        # (S, q, k)
        all_d = jax.lax.all_gather(dists, shard_axis)           # (S, q, k)
        all_io = jax.lax.all_gather(res.ios, shard_axis)        # (S, q)
        s, qn, _ = all_ids.shape
        shard_tag = jnp.arange(s, dtype=jnp.int32)[:, None, None]
        flat_ids = (all_ids + shard_tag * 0).transpose(1, 0, 2).reshape(qn, -1)
        flat_tag = jnp.broadcast_to(shard_tag, all_ids.shape).transpose(1, 0, 2).reshape(qn, -1)
        flat_d = all_d.transpose(1, 0, 2).reshape(qn, -1)
        flat_d = jnp.where(flat_ids == PAD, jnp.inf, flat_d)
        order = jnp.argsort(flat_d, axis=1)[:, :k]
        top_ids = jnp.take_along_axis(flat_ids, order, axis=1)
        top_tag = jnp.take_along_axis(flat_tag, order, axis=1)
        top_d = jnp.take_along_axis(flat_d, order, axis=1)
        return top_ids, top_tag, top_d, all_io.sum(0)

    data_spec = jax.tree.map(lambda _: P(shard_axis), search_mod.SearchData(
        *[0] * len(search_mod.SearchData._fields)
    ))
    in_specs = (data_spec, P(query_axis))
    out_specs = (P(query_axis), P(query_axis), P(query_axis), P(query_axis))

    fn = compat.shard_map(
        local_search, mesh=mesh, in_specs=in_specs, out_specs=out_specs
    )
    in_shard = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), data_spec),
        NamedSharding(mesh, P(query_axis)),
    )
    return jax.jit(fn), in_shard


def translate_ids(
    sharded: ShardedIndex, top_ids: np.ndarray, top_tag: np.ndarray
) -> np.ndarray:
    """(Q, k) shard-local reassigned ids + shard tags -> original ids."""
    out = np.full_like(top_ids, PAD, dtype=np.int64)
    valid = top_ids >= 0
    out[valid] = sharded.new_to_old[top_tag[valid], top_ids[valid]]
    return out
