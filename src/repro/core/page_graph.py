"""Page-node graph construction — Algorithm 1 of the paper.

Phase 1 (lines 1-13): greedily group vectors into page nodes of capacity n.
Each seed pulls its n-1 closest *ungrouped* vectors found within h hops of
the Vamana graph; leftover capacity is filled from the ungrouped pool.

Phase 2 (lines 14-26): derive page-level connectivity. For every page,
aggregate the vector-level out-edges of its members, drop intra-page edges,
merge duplicates, and keep up to R_p external neighbor *vectors* (Fig. 5
stores neighbor vector ids + their compressed values on the page). Neighbors
are ranked by incoming edge multiplicity (connectivity strength), tie-broken
by distance to the page centroid — this is the "merging technique" that frees
page bytes for more search-relevant data.

Build-time code, so plain numpy; the hot loops are vectorized.
"""
from __future__ import annotations

import dataclasses

import numpy as np

PAD = -1


@dataclasses.dataclass
class PageGrouping:
    pages: np.ndarray        # (P, capacity) int32 original vector ids, PAD-padded
    page_of: np.ndarray      # (N,) int32 page index of each original vector
    slot_of: np.ndarray      # (N,) int32 slot within its page


def _hop_candidates(nbrs: np.ndarray, seed: int, h: int, ungrouped: np.ndarray) -> np.ndarray:
    """Ungrouped vector ids within h hops of seed (excluding seed)."""
    frontier = np.array([seed], np.int64)
    seen = {int(seed)}
    out: list[np.ndarray] = []
    for _ in range(h):
        nxt = nbrs[frontier].ravel()
        nxt = nxt[nxt != PAD]
        if nxt.size == 0:
            break
        nxt = np.unique(nxt)
        fresh = np.array([u for u in nxt if u not in seen], np.int64)
        if fresh.size == 0:
            break
        seen.update(int(u) for u in fresh)
        out.append(fresh)
        frontier = fresh
    if not out:
        return np.empty((0,), np.int64)
    cand = np.concatenate(out)
    return cand[ungrouped[cand]]


def group_pages(
    x: np.ndarray, nbrs: np.ndarray, capacity: int, h: int = 2
) -> PageGrouping:
    """Algorithm 1, lines 1-13."""
    n = len(x)
    ungrouped = np.ones(n, bool)
    # seeds in degree-descending order: well-connected vectors make good
    # page anchors and their hop-neighborhoods are dense.
    seed_order = np.argsort(-(nbrs != PAD).sum(1), kind="stable")
    pool_ptr = 0
    pool = np.arange(n)
    pages: list[np.ndarray] = []
    page_of = np.full(n, PAD, np.int32)
    slot_of = np.full(n, PAD, np.int32)

    for seed in seed_order:
        if not ungrouped[seed]:
            continue
        members = [int(seed)]
        ungrouped[seed] = False
        cand = _hop_candidates(nbrs, int(seed), h, ungrouped)
        if cand.size:
            d = ((x[cand] - x[seed]) ** 2).sum(-1)
            take = cand[np.argsort(d)[: capacity - 1]]
            members.extend(int(u) for u in take)
            ungrouped[take] = False
        # fill leftovers from the global ungrouped pool (lines 9-11)
        while len(members) < capacity:
            while pool_ptr < n and not ungrouped[pool[pool_ptr]]:
                pool_ptr += 1
            if pool_ptr >= n:
                break
            u = int(pool[pool_ptr])
            members.append(u)
            ungrouped[u] = False
        row = np.full(capacity, PAD, np.int32)
        row[: len(members)] = members
        pid = len(pages)
        pages.append(row)
        for s, u in enumerate(members):
            page_of[u] = pid
            slot_of[u] = s

    return PageGrouping(
        pages=np.stack(pages).astype(np.int32),
        page_of=page_of,
        slot_of=slot_of,
    )


def derive_page_edges(
    x: np.ndarray,
    nbrs: np.ndarray,
    grouping: PageGrouping,
    page_degree: int,
) -> np.ndarray:
    """Algorithm 1, lines 14-26: external neighbor vectors per page.

    Returns (P, page_degree) int32 of *original vector ids*, PAD-padded.
    """
    pages, page_of = grouping.pages, grouping.page_of
    p = len(pages)
    out = np.full((p, page_degree), PAD, np.int32)
    for pid in range(p):
        members = pages[pid][pages[pid] != PAD]
        ext = nbrs[members].ravel()
        ext = ext[ext != PAD]
        ext = ext[page_of[ext] != pid]          # drop intra-page edges
        if ext.size == 0:
            continue
        uniq, counts = np.unique(ext, return_counts=True)  # merge duplicates
        centroid = x[members].mean(0)
        d = ((x[uniq] - centroid) ** 2).sum(-1)
        # strong connectivity first, then proximity
        order = np.lexsort((d, -counts))
        keep = uniq[order][:page_degree]
        out[pid, : len(keep)] = keep
    return out


def page_graph_stats(page_nbrs: np.ndarray) -> dict:
    deg = (page_nbrs != PAD).sum(1)
    return {
        "pages": int(len(page_nbrs)),
        "mean_degree": float(deg.mean()),
        "max_degree": int(deg.max()),
        "min_degree": int(deg.min()),
    }
