"""Lightweight LSH routing index (paper Sec 4.3, "Caching for fast
lightweight indexing").

A sample of vectors is projected onto random hyperplanes; the sign pattern is
packed into uint32 words. A query computes its own code, XOR+popcounts against
the sampled codes, and the top-T smallest Hamming distances become the entry
candidates for the page-node graph traversal (Alg. 2, line 4).

Adaptation noted in DESIGN.md: the paper probes all buckets within Hamming
radius r; we take top-T by Hamming distance — identical candidates for small
r, but fixed-shape and TPU-friendly (one XOR/popcount sweep, one top-k).
The sweep's Pallas kernel lives in ``repro.kernels.hamming``.

The sampled vectors' PQ codes are kept alongside (a few KB) so entry
candidates always have an estimated distance, even in DISK_ONLY mode —
this is the paper's 0.05 GB minimum-memory configuration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """Bit-twiddling popcount on uint32 lanes (no intrinsics needed)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., B) {0,1} -> (..., B//32) uint32, little-endian within a word."""
    *lead, b = bits.shape
    w = b // 32
    bits = bits.reshape(*lead, w, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits << shifts).sum(-1).astype(jnp.uint32)


def hash_codes(x: jnp.ndarray, planes: jnp.ndarray) -> jnp.ndarray:
    """Random-hyperplane binary hash, packed. x: (N, d), planes: (d, B)."""
    bits = (x @ planes > 0).astype(jnp.uint32)
    return pack_bits(bits)


def hamming_distance(codes: jnp.ndarray, qcode: jnp.ndarray) -> jnp.ndarray:
    """Hamming distances between packed codes (S, W) and a query code (W,).

    Pure-jnp oracle for ``repro.kernels.hamming``.
    """
    return _popcount32(jnp.bitwise_xor(codes, qcode[None, :])).sum(-1)


@dataclasses.dataclass
class LSHIndex:
    planes: jnp.ndarray        # (d, B) float32
    sample_ids: jnp.ndarray    # (S,) int32 — vector ids (reassigned space)
    sample_codes: jnp.ndarray  # (S, B//32) uint32
    sample_pq: jnp.ndarray     # (S, M) uint8 — PQ codes of the sample

    @property
    def memory_bytes(self) -> int:
        return int(
            self.planes.size * 4
            + self.sample_ids.size * 4
            + self.sample_codes.size * 4
            + self.sample_pq.size
        )

    def query(self, q: jnp.ndarray, top_t: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Entry vector ids + Hamming distances for a single query (d,)."""
        qcode = hash_codes(q[None, :], self.planes)[0]
        ham = hamming_distance(self.sample_codes, qcode)
        top = jnp.argsort(ham)[:top_t]
        return self.sample_ids[top], ham[top]


def build_lsh(
    x: np.ndarray,
    pq_codes: np.ndarray,
    bits: int,
    sample: int,
    seed: int = 0,
) -> LSHIndex:
    """Sample vectors, hash them, remember their ids and PQ codes.

    ``x`` must already be in the *reassigned* id space (row i == vector id i)
    so that routed entries can be mapped to pages with id // capacity.
    """
    n, d = x.shape
    rng = np.random.default_rng(seed)
    sample = min(sample, n)
    ids = rng.choice(n, size=sample, replace=False).astype(np.int32)
    planes = rng.standard_normal((d, bits)).astype(np.float32)
    codes = hash_codes(jnp.asarray(x[ids], jnp.float32), jnp.asarray(planes))
    return LSHIndex(
        planes=jnp.asarray(planes),
        sample_ids=jnp.asarray(ids),
        sample_codes=codes,
        sample_pq=jnp.asarray(pq_codes[ids]),
    )
