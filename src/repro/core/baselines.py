"""Baselines the paper compares against, re-implemented in JAX.

* ``diskann_search`` — DiskANN-style traversal: a vector-granularity Vamana
  beam search where next hops are chosen with in-memory PQ estimates and every
  expanded node costs one disk read of its (vector + adjacency) record. With
  id-ordered placement multiple unrelated vectors share an SSD page, so each
  node read drags a full page: the read-amplification regime of Table 1.

* ``starling_search`` — Starling-style variant: identical traversal but the
  disk layout packs *similar* vectors per page (we reuse PageANN's grouping)
  and a page, once read, contributes all its members to reranking, so repeat
  visits to co-located vectors are free (unique-page accounting).

Both count "Mean I/Os" the same way the paper's Table 3 does, which makes
them directly comparable with ``core.search`` on the same data.

:class:`DiskANNIndex` / :class:`StarlingIndex` wrap the raw searches in the
same :class:`repro.core.protocol.VectorIndex` lifecycle as
``PageANNIndex`` — build/from_data → save → load → ``search(queries, k,
params)`` returning a ``SearchResult`` — so benchmarks and the serving
engine drive all three systems through one code path.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pq_mod
from repro.core.config import PageANNConfig, SearchParams, resolve_search_params

PAD = -1
INF = jnp.inf


class BaselineData(NamedTuple):
    x: jnp.ndarray           # (N, d) full vectors ('on disk')
    nbrs: jnp.ndarray        # (N, R) vamana adjacency ('on disk' with vector)
    codes: jnp.ndarray       # (N, M) PQ codes (in memory — DiskANN keeps these)
    codebooks: jnp.ndarray   # (M, ksub, dsub)
    page_of: jnp.ndarray     # (N,) page id of each vector under the layout
    entry: jnp.ndarray       # () medoid id


class BaselineResult(NamedTuple):
    ids: jnp.ndarray
    dists: jnp.ndarray
    ios: jnp.ndarray       # page reads
    hops: jnp.ndarray


def _beam_search_one(
    q, data: BaselineData, *, beam, k, max_hops, io_batch, unique_pages: bool
):
    n, r = data.nbrs.shape
    num_pages = jnp.max(data.page_of) + 1

    lut = pq_mod.pq_lut(q, data.codebooks)

    cand_ids = jnp.full((beam,), PAD, jnp.int32).at[0].set(data.entry)
    cand_d = jnp.full((beam,), INF, jnp.float32).at[0].set(
        pq_mod.adc_distance(data.codes[data.entry][None], lut)[0]
    )
    cand_vis = jnp.zeros((beam,), bool)
    node_vis = jnp.zeros((n,), bool)
    # visited-page bitmap: only consulted when unique_pages (Starling layout)
    page_vis = jnp.zeros((data.page_of.shape[0],), bool)  # sized N >= P
    res_ids = jnp.full((k,), PAD, jnp.int32)
    res_d = jnp.full((k,), INF, jnp.float32)
    io = jnp.int32(0)
    hops = jnp.int32(0)

    def cond(s):
        cand_ids, cand_d, cand_vis, node_vis, page_vis, res_ids, res_d, io, hops = s
        live = (~cand_vis) & (cand_ids != PAD) & jnp.isfinite(cand_d)
        return live.any() & (hops < max_hops)

    def body(s):
        cand_ids, cand_d, cand_vis, node_vis, page_vis, res_ids, res_d, io, hops = s

        batch = jnp.full((io_batch,), PAD, jnp.int32)

        def pick(j, carry):
            cand_vis, node_vis, batch = carry
            masked = jnp.where(cand_vis | (cand_ids == PAD), INF, cand_d)
            slot = jnp.argmin(masked)
            ok = jnp.isfinite(masked[slot])
            cand_vis = cand_vis.at[slot].set(True)
            v = jnp.where(ok, cand_ids[slot], PAD)
            node_vis = jnp.where(
                ok, node_vis.at[jnp.maximum(v, 0)].set(True), node_vis
            )
            return cand_vis, node_vis, batch.at[j].set(v)

        cand_vis, node_vis, batch = jax.lax.fori_loop(
            0, io_batch, pick, (cand_vis, node_vis, batch)
        )
        ok = batch >= 0
        safe = jnp.maximum(batch, 0)

        # --- the disk read: vector + adjacency record of each expanded node
        pages = data.page_of[safe]
        if unique_pages:
            fresh = ok & ~page_vis[pages]
            # two batch entries may share a page: count once
            first = jnp.zeros_like(fresh)
            seen = jnp.full((io_batch,), PAD, jnp.int32)

            def dedup(j, carry):
                first, seen = carry
                dup = (seen == pages[j]).any()
                first = first.at[j].set(fresh[j] & ~dup)
                seen = seen.at[j].set(jnp.where(ok[j], pages[j], PAD))
                return first, seen

            first, _ = jax.lax.fori_loop(0, io_batch, dedup, (first, seen))
            io2 = io + first.sum().astype(jnp.int32)
            page_vis = page_vis.at[jnp.where(ok, pages, 0)].set(
                page_vis[jnp.where(ok, pages, 0)] | ok
            )
        else:
            io2 = io + ok.sum().astype(jnp.int32)  # one page read per node

        vec = data.x[safe]                      # (b, d)
        adj = data.nbrs[safe]                   # (b, R)

        # exact rerank of the expanded nodes
        ex = jnp.sum((vec - q[None, :]) ** 2, axis=-1)
        ex = jnp.where(ok, ex, INF)
        all_rd = jnp.concatenate([res_d, ex])
        all_ri = jnp.concatenate([res_ids, batch])
        order = jnp.argsort(all_rd)[:k]
        res_d2, res_ids2 = all_rd[order], all_ri[order]

        # estimated distances of neighbors via in-memory PQ
        flat = adj.reshape(-1)
        validn = (flat != PAD) & ok.repeat(r)
        est = pq_mod.adc_distance(data.codes[jnp.maximum(flat, 0)], lut)
        est = jnp.where(validn, est, INF)
        est = jnp.where(node_vis[jnp.maximum(flat, 0)], INF, est)
        dup = (flat[:, None] == cand_ids[None, :]).any(1)
        est = jnp.where(dup, INF, est)
        o = jnp.argsort(flat)
        sflat = flat[o]
        dupm = jnp.concatenate([jnp.array([False]), sflat[1:] == sflat[:-1]])
        dup2 = jnp.zeros_like(dupm).at[o].set(dupm)
        est = jnp.where(dup2 & (flat != PAD), INF, est)

        all_ci = jnp.concatenate([cand_ids, flat])
        all_cd = jnp.concatenate([cand_d, est])
        all_cv = jnp.concatenate([cand_vis, jnp.zeros_like(validn)])
        order = jnp.argsort(all_cd)[:beam]
        return (
            all_ci[order], all_cd[order], all_cv[order],
            node_vis, page_vis, res_ids2, res_d2, io2, hops + 1,
        )

    s = (cand_ids, cand_d, cand_vis, node_vis, page_vis, res_ids, res_d, io, hops)
    s = jax.lax.while_loop(cond, body, s)
    return s[5], s[6], s[7], s[8]


@functools.partial(
    jax.jit,
    static_argnames=("beam", "k", "max_hops", "io_batch", "unique_pages"),
)
def baseline_search(
    queries, data: BaselineData, *, beam, k, max_hops, io_batch, unique_pages
) -> BaselineResult:
    fn = functools.partial(
        _beam_search_one,
        data=data,
        beam=beam,
        k=k,
        max_hops=max_hops,
        io_batch=io_batch,
        unique_pages=unique_pages,
    )
    ids, dists, ios, hops = jax.vmap(fn)(queries)
    return BaselineResult(ids=ids, dists=dists, ios=ios, hops=hops)


def make_baseline_data(
    x: np.ndarray,
    nbrs: np.ndarray,
    codebooks: np.ndarray,
    page_of: np.ndarray | None = None,
    vectors_per_page: int | None = None,
) -> BaselineData:
    """id-order layout when page_of is None (DiskANN); else custom layout."""
    from repro.core.vamana import medoid

    x = np.asarray(x, np.float32)
    codes = np.asarray(
        pq_mod.pq_encode(jnp.asarray(x), jnp.asarray(codebooks))
    )
    if page_of is None:
        vpp = vectors_per_page or max(1, 4096 // (x.shape[1] * 4))
        page_of = np.arange(len(x)) // vpp
    return BaselineData(
        x=jnp.asarray(x),
        nbrs=jnp.asarray(nbrs),
        codes=jnp.asarray(codes),
        codebooks=jnp.asarray(codebooks),
        page_of=jnp.asarray(page_of.astype(np.int32)),
        entry=jnp.asarray(medoid(x), jnp.int32),
    )


def diskann_search(queries, data: BaselineData, *, beam=64, k=10, max_hops=64, io_batch=5):
    return baseline_search(
        queries, data, beam=beam, k=k, max_hops=max_hops,
        io_batch=io_batch, unique_pages=False,
    )


def starling_search(queries, data: BaselineData, *, beam=64, k=10, max_hops=64, io_batch=5):
    return baseline_search(
        queries, data, beam=beam, k=k, max_hops=max_hops,
        io_batch=io_batch, unique_pages=True,
    )


# --------------------------------------------------------------------------
# VectorIndex lifecycle wrappers (protocol shared with PageANNIndex)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BaselineStats:
    num_vectors: int
    pages: int
    memory_bytes: int   # in-memory PQ codes + codebooks (what DiskANN keeps)


class _BaselineIndex:
    """Shared ``VectorIndex`` plumbing over a :class:`BaselineData`.

    Ids are never reassigned by the baselines, so ``search`` results are
    already ORIGINAL vector ids; ``cache_hits`` is always zero (no warmed
    page cache in either baseline).
    """

    kind: str = ""
    _unique_pages: bool = False

    def __init__(self, data: BaselineData):
        self.data = data

    # ------------------------------------------------------------ properties
    @property
    def dim(self) -> int:
        return int(self.data.x.shape[1])

    @property
    def default_params(self) -> SearchParams:
        return SearchParams()

    def resolve_params(
        self, k: int | None, params: SearchParams | None
    ) -> SearchParams:
        return resolve_search_params(self.default_params, k, params)

    @property
    def stats(self) -> BaselineStats:
        return BaselineStats(
            num_vectors=int(self.data.x.shape[0]),
            pages=int(np.asarray(self.data.page_of).max()) + 1,
            memory_bytes=int(
                self.data.codes.size + self.data.codebooks.size * 4
            ),
        )

    # ----------------------------------------------------------------- search
    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
    ):
        from repro.core.search import SearchResult

        p = self.resolve_params(k, params)
        res = baseline_search(
            jnp.asarray(queries, jnp.float32),
            self.data,
            beam=p.beam_width,
            k=p.k,
            max_hops=p.max_hops,
            io_batch=p.io_batch,
            unique_pages=self._unique_pages,
        )
        ios = np.asarray(res.ios)
        return SearchResult(
            ids=np.asarray(res.ids),
            dists=np.asarray(res.dists),
            ios=ios,
            hops=np.asarray(res.hops),
            cache_hits=np.zeros_like(ios),
        )

    # -------------------------------------------------------------- lifecycle
    def save(self, directory: str) -> None:
        from repro.core import persist

        os.makedirs(directory, exist_ok=True)
        np.savez(
            os.path.join(directory, persist.ARRAYS_NPZ),
            x=np.asarray(self.data.x),
            nbrs=np.asarray(self.data.nbrs),
            codes=np.asarray(self.data.codes),
            codebooks=np.asarray(self.data.codebooks),
            page_of=np.asarray(self.data.page_of),
            entry=np.asarray(self.data.entry),
        )
        persist.write_manifest(
            directory,
            dict(kind=self.kind, dim=self.dim,
                 stats=dataclasses.asdict(self.stats)),
        )

    @classmethod
    def load(cls, directory: str) -> "_BaselineIndex":
        from repro.core import persist

        doc = persist.read_manifest(directory)
        if doc["kind"] != cls.kind:
            raise ValueError(
                f"{directory}: kind={doc['kind']!r}, expected {cls.kind!r}"
            )
        with np.load(os.path.join(directory, persist.ARRAYS_NPZ)) as z:
            data = BaselineData(
                x=jnp.asarray(z["x"]),
                nbrs=jnp.asarray(z["nbrs"]),
                codes=jnp.asarray(z["codes"]),
                codebooks=jnp.asarray(z["codebooks"]),
                page_of=jnp.asarray(z["page_of"]),
                entry=jnp.asarray(z["entry"]),
            )
        return cls(data)

    # --------------------------------------------------------------- builders
    @classmethod
    def from_data(
        cls,
        x: np.ndarray,
        nbrs: np.ndarray,
        codebooks: np.ndarray,
        *,
        page_of: np.ndarray | None = None,
        vectors_per_page: int | None = None,
    ) -> "_BaselineIndex":
        """Wrap a prebuilt Vamana graph + PQ codebooks (shared with PageANN
        sweeps so all systems search the same graph)."""
        return cls(
            make_baseline_data(
                np.asarray(x), np.asarray(nbrs), np.asarray(codebooks),
                page_of=page_of, vectors_per_page=vectors_per_page,
            )
        )

    @classmethod
    def build(cls, x: np.ndarray, cfg: PageANNConfig) -> "_BaselineIndex":
        """Full build from raw vectors using the config's graph/PQ knobs."""
        from repro.core.vamana import build_vamana

        x = np.ascontiguousarray(x, np.float32)
        nbrs = build_vamana(
            x, degree=cfg.graph_degree, beam=cfg.build_beam,
            alpha=cfg.alpha, rounds=cfg.build_rounds, seed=cfg.seed,
        )
        books = np.asarray(pq_mod.train_pq(
            x, cfg.pq_subspaces, cfg.pq_ksub, cfg.pq_iters, seed=cfg.seed
        ))
        return cls.from_data(x, nbrs, books, page_of=cls._layout(x, nbrs, cfg))

    @classmethod
    def _layout(cls, x, nbrs, cfg: PageANNConfig):
        return None  # id-order pages (DiskANN); Starling overrides


class DiskANNIndex(_BaselineIndex):
    kind = "diskann"
    _unique_pages = False


class StarlingIndex(_BaselineIndex):
    kind = "starling"
    _unique_pages = True

    @classmethod
    def _layout(cls, x, nbrs, cfg: PageANNConfig):
        from repro.core.page_graph import group_pages

        return group_pages(x, nbrs, cfg.resolve_capacity(), cfg.hop_h).page_of


BASELINE_KINDS = {
    DiskANNIndex.kind: DiskANNIndex,
    StarlingIndex.kind: StarlingIndex,
}


def load_baseline(directory: str) -> _BaselineIndex:
    from repro.core import persist

    kind = persist.read_manifest(directory)["kind"]
    return BASELINE_KINDS[kind].load(directory)
