"""The unified index lifecycle contract: build → save → load → search.

Every searchable index in this repo — :class:`repro.core.index.PageANNIndex`
and the DiskANN/Starling baselines in :mod:`repro.core.baselines` — speaks
the same small surface, so benchmarks sweep all systems through one code
path and the serving engine (:class:`repro.serve.BatchingEngine`) is
implementation-agnostic:

  * ``search(queries, k=None, params=None) -> SearchResult`` — runtime
    knobs arrive per call as a :class:`repro.core.config.SearchParams`
    (``k`` overrides ``params.k`` when given); results carry ORIGINAL
    vector ids and the paper's I/O accounting.
  * ``save(directory)`` — persist the index artifact to disk.
  * ``load(directory)`` (classmethod) — reload it; searches on the loaded
    index are bit-identical to the saved one.
  * ``stats`` — build/footprint statistics object.
  * ``dim`` — vector dimensionality accepted by ``search``.

``repro.core.persist.load_index`` reopens a saved directory as whichever
implementation wrote it.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.config import SearchParams
from repro.core.search import SearchResult


@runtime_checkable
class VectorIndex(Protocol):
    @property
    def dim(self) -> int: ...

    @property
    def default_params(self) -> SearchParams: ...

    @property
    def stats(self): ...

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
    ) -> SearchResult: ...

    def save(self, directory: str) -> None: ...

    @classmethod
    def load(cls, directory: str) -> "VectorIndex": ...
