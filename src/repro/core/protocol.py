"""The unified index lifecycle contract: build → save → load → search.

Every searchable index in this repo — :class:`repro.core.index.PageANNIndex`
and the DiskANN/Starling baselines in :mod:`repro.core.baselines` — speaks
the same small surface, so benchmarks sweep all systems through one code
path and the serving layer is implementation-agnostic: the
collection-agnostic :class:`repro.serve.BatchingEngine` batches requests
per ``(collection, k-bin, params)`` group, and the database-level
:class:`repro.serve.VectorService` registers any number of named
``VectorIndex`` collections on one shared core (whole databases persist
via ``repro.core.persist.save_database`` — a versioned ``db.json`` over
per-collection artifacts):

  * ``search(queries, k=None, params=None) -> SearchResult`` — runtime
    knobs arrive per call as a :class:`repro.core.config.SearchParams`
    (``k`` overrides ``params.k`` when given); results carry ORIGINAL
    vector ids and the paper's I/O accounting.
  * ``save(directory)`` — persist the index artifact to disk.
  * ``load(directory)`` (classmethod) — reload it; searches on the loaded
    index are bit-identical to the saved one. Implementations with a page
    tier additionally accept ``load(directory, memory_budget=...)`` (a
    :class:`repro.core.config.MemoryBudget`): the hottest pages that fit
    are pinned on device and the rest stream from the ``pages.bin`` memmap
    per hop — same results, bounded device footprint.
  * ``stats`` — build/footprint statistics object. Disk footprint numbers
    describe the artifact as persisted: an index loaded via memmap reports
    the actual on-disk byte size of its page file
    (``BuildStats.disk_bytes``), not a recomputation from device arrays.
    The resident/streamed split rides the same object —
    ``resident_pages`` / ``resident_bytes`` vs ``disk_bytes``.
  * ``dim`` — vector dimensionality accepted by ``search``.

:class:`MutableVectorIndex` extends the contract with writes —
``insert`` / ``delete`` / ``compact`` — implemented by
:class:`repro.core.delta.MutableIndex` (in-memory delta tier + tombstones
over a frozen base, folded back into the disk artifact on compaction).

``repro.core.persist.load_index`` reopens a saved directory as whichever
implementation wrote it.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.config import SearchParams
from repro.core.search import SearchResult


@runtime_checkable
class VectorIndex(Protocol):
    @property
    def dim(self) -> int: ...

    @property
    def default_params(self) -> SearchParams: ...

    @property
    def stats(self): ...

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
    ) -> SearchResult: ...

    def save(self, directory: str) -> None: ...

    @classmethod
    def load(cls, directory: str) -> "VectorIndex": ...


@runtime_checkable
class MutableVectorIndex(VectorIndex, Protocol):
    """A ``VectorIndex`` that accepts writes between searches.

    ``insert`` returns the external ids assigned to the new vectors (caller
    ids echoed back, or freshly allocated when omitted); ``delete`` returns
    how many ids were live; ``compact`` folds pending writes into a fresh
    base artifact and returns whether anything was folded. Writes must
    interleave safely with concurrent ``search`` calls.
    """

    def insert(
        self, vectors: np.ndarray, ids: np.ndarray | None = None
    ) -> np.ndarray: ...

    def delete(self, ids: np.ndarray) -> int: ...

    def compact(self) -> bool: ...
