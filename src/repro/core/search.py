"""PageANN graph search — Algorithm 2, as a fixed-shape JAX program.

Per query the loop maintains a :class:`BeamState`:
  * a candidate set (size-L, distance-sorted, visited flags) over *vector*
    ids in the reassigned space (page = id // capacity),
  * a visited-page bitmap (the paper's visited set V),
  * a running exact-distance result set (size-K),
and per hop applies three pure transition functions:

  ``select_batch``      pick up to b closest unvisited candidates on fresh
                        pages — the I/O schedule for this hop — as ONE
                        vectorized pass: a single stable sort of the beam
                        by (distance, slot) plus a first-occurrence-per-
                        page mask, no serial argmin loop,
  ``score_page_batch``  read those packed page records in one batched DMA
                        (the I/O unit; ``kernels.ops.page_scan`` — scalar-
                        prefetched page-record DMA on TPU, jnp oracle on
                        CPU) and emit BOTH score sets from the single
                        resident record: exact member L2 distances and
                        neighbor ADC distances (on-page codes from the
                        same record; in-memory codes via
                        ``kernels.ops.pq_adc`` per the coordination mode),
  ``merge``             fold both score sets into the beam and result
                        top-k via ``jax.lax.top_k`` — no full sorts.

The hot loop is argsort-free: merges use ``lax.top_k``, batch-local dedup
is one ``lax.sort`` + segment-boundary mask, and beam-membership tests are
sorted ``searchsorted`` probes instead of O(b*Rp*L) broadcasts.

Everything is fixed-shape: the loop is a ``lax.while_loop``, queries are
vmapped (``batch_search``) and optionally sharded over a device mesh
(``shard_search`` — pad rows carry ``valid=False`` and exit at hop 0).
Runtime knobs (beam L, io batch b, max hops, LSH top-T, k) arrive per call
as a frozen :class:`repro.core.config.SearchParams` used as a static jit
argument — one compiled executable per distinct value, over one index.
I/O and cache-hit counters reproduce the paper's "Mean I/Os" metric.
Later async-prefetch / cache-eviction work should extend the transition
functions, not re-inline the loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import pq as pq_mod
from repro.core.config import MemoryMode, SearchParams
from repro.core.filter import CompiledFilter, MetaArrays, filter_mask
from repro.core.layout import MemoryTier, PageStore
from repro.core.lsh import LSHIndex, hash_codes
from repro.kernels import ops

PAD = -1
INF = jnp.inf


class SearchData(NamedTuple):
    """All device arrays the search touches (a single pytree argument)."""

    # disk tier: packed page records (members + neighbor codes + counts in
    # one (rows, 128) tile per page — see core.layout.pack_page_records).
    # Under a MemoryBudget, page_recs holds only the RESIDENT subset
    # (R <= P rows) and resident_map routes each logical page id to its
    # resident slot (-1 = streamed from the host memmap per hop); fully
    # resident indexes carry resident_map == arange(P) with R == P.
    page_recs: jnp.ndarray     # (R, rows, 128) f32
    member_count: jnp.ndarray  # (P,)
    nbr_ids: jnp.ndarray       # (P, Rp)
    nbr_count: jnp.ndarray     # (P,)
    resident_map: jnp.ndarray  # (P,) int32: slot into page_recs, or -1
    # memory tier
    mem_codes: jnp.ndarray     # (N_pad, M_mem)
    mem_mask: jnp.ndarray      # (N_pad,)
    mem_codebooks: jnp.ndarray
    disk_codebooks: jnp.ndarray
    cached_pages: jnp.ndarray  # (C,) sorted
    # routing index
    lsh_planes: jnp.ndarray
    lsh_ids: jnp.ndarray
    lsh_codes: jnp.ndarray
    lsh_pq: jnp.ndarray        # (S, M_disk)


def make_search_data(store: PageStore, tier: MemoryTier, lsh: LSHIndex) -> SearchData:
    resident_map = store.resident_map
    if resident_map is None:
        # fully resident: the identity routing (page id == resident slot)
        resident_map = jnp.arange(store.recs.shape[0], dtype=jnp.int32)
    return SearchData(
        page_recs=store.recs,
        member_count=store.member_count,
        nbr_ids=store.nbr_ids,
        nbr_count=store.nbr_count,
        resident_map=resident_map,
        mem_codes=tier.mem_codes,
        mem_mask=tier.mem_mask,
        mem_codebooks=tier.mem_codebooks,
        disk_codebooks=tier.disk_codebooks,
        cached_pages=tier.cached_pages,
        lsh_planes=lsh.planes,
        lsh_ids=lsh.sample_ids,
        lsh_codes=lsh.sample_codes,
        lsh_pq=lsh.sample_pq,
    )


class SearchResult(NamedTuple):
    ids: jnp.ndarray      # (Q, k) reassigned vector ids
    dists: jnp.ndarray    # (Q, k) exact squared distances
    ios: jnp.ndarray      # (Q,) page reads that went to 'disk'
    hops: jnp.ndarray     # (Q,) while_loop iterations
    cache_hits: jnp.ndarray  # (Q,) page reads served by the warmed cache


class BeamState(NamedTuple):
    """Per-query loop state of Algorithm 2 (one pytree, while_loop carry).

    The two adaptive fields are ``None`` — absent from the pytree — unless
    per-query early termination is on (``AdaptiveParams.patience``), so the
    non-adaptive loop carries the exact pre-adaptive structure and compiles
    to the same program.
    """

    cand_ids: jnp.ndarray   # (L,) candidate vector ids, PAD padded
    cand_d: jnp.ndarray     # (L,) estimated distances, INF padded
    cand_vis: jnp.ndarray   # (L,) expanded/scheduled flags
    page_vis: jnp.ndarray   # (P,) visited-page bitmap (the paper's V)
    res_ids: jnp.ndarray    # (k,) running exact top-k ids
    res_d: jnp.ndarray      # (k,) running exact top-k distances
    io: jnp.ndarray         # () page reads served from 'disk'
    cache_hits: jnp.ndarray  # () page reads served by the warmed cache
    hops: jnp.ndarray       # () loop iterations
    # early termination (None unless patience is set): the worst running
    # top-k distance at the last improving hop, and how many consecutive
    # hops failed to improve it by more than epsilon
    frontier: jnp.ndarray | None = None   # () f32
    stall: jnp.ndarray | None = None      # () int32 patience counter


def _mask_dups_keep_first(ids: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Set distance to INF for duplicate ids (keeping the first occurrence).

    One stable value sort of (ids, positions) + a segment-boundary compare;
    duplicate flags are scattered back through the carried positions — no
    argsort on the hot path.
    """
    n = ids.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    s, spos = jax.lax.sort((ids, pos), num_keys=1, is_stable=True)
    dup_sorted = jnp.concatenate([jnp.array([False]), s[1:] == s[:-1]])
    dup = jnp.zeros((n,), bool).at[spos].set(dup_sorted)
    return jnp.where(dup & (ids != PAD), INF, d)


def _top_k_merge(d: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Ascending top-k of a distance vector: (dists, indices).

    ``lax.top_k`` breaks ties toward lower indices, matching a stable
    ascending argsort — same selection, a fraction of the cost.
    """
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


# --------------------------------------------------------------------------
# per-hop transition functions (pure; composed by _search_one's loop body)
# --------------------------------------------------------------------------

def init_state(
    q: jnp.ndarray,
    data: SearchData,
    disk_lut: jnp.ndarray,
    *,
    beam: int,
    k: int,
    entries: int,
    entry_slack: int | None = None,
    min_entries: int = 1,
    patience: int | None = None,
) -> BeamState:
    """In-memory routing (Alg. 2 line 4, Fig. 6 step 1): LSH entry points.

    With query-sensitive entry selection on (``entry_slack`` is not None),
    the top-T Hamming profile becomes a per-query entry-quality signal:
    only candidates within ``entry_slack`` bits of the best candidate seed
    the beam (at least ``min_entries`` by rank). A confidently-routed query
    — a sharply peaked profile — starts from its few genuinely close
    entries instead of the fixed top-T slice, so it schedules fewer junk
    pages on the opening hops; a flat profile (poorly routed, hard query)
    keeps the whole top-T. Fixed-shape and vmap-safe: dropped candidates
    are masked to PAD/INF in place, never compacted.
    """
    num_pages = data.resident_map.shape[0]
    qcode = hash_codes(q[None], data.lsh_planes)[0]
    ham = ops.hamming(data.lsh_codes, qcode)
    ham_top, top = _top_k_merge(ham.astype(jnp.float32), entries)
    entry_ids = data.lsh_ids[top].astype(jnp.int32)
    entry_d = ops.pq_adc(data.lsh_pq[top], disk_lut)
    if entry_slack is not None:
        keep = (ham_top <= ham_top[0] + float(entry_slack)) | (
            jnp.arange(entries) < min_entries
        )
        entry_ids = jnp.where(keep, entry_ids, PAD)
        entry_d = jnp.where(keep, entry_d, INF)
    entry_d = _mask_dups_keep_first(entry_ids, entry_d)

    cand_ids = jnp.full((beam,), PAD, jnp.int32).at[:entries].set(entry_ids)
    cand_d = jnp.full((beam,), INF, jnp.float32).at[:entries].set(entry_d)
    return BeamState(
        cand_ids=cand_ids,
        cand_d=cand_d,
        cand_vis=jnp.zeros((beam,), bool),
        page_vis=jnp.zeros((num_pages,), bool),
        res_ids=jnp.full((k,), PAD, jnp.int32),
        res_d=jnp.full((k,), INF, jnp.float32),
        io=jnp.int32(0),
        cache_hits=jnp.int32(0),
        hops=jnp.int32(0),
        frontier=None if patience is None else jnp.float32(INF),
        stall=None if patience is None else jnp.int32(0),
    )


def select_batch(
    state: BeamState, *, capacity: int, io_batch: int
) -> tuple[BeamState, jnp.ndarray]:
    """Pick up to b closest unvisited candidates whose pages are fresh.

    One vectorized pass replacing the seed's serial per-pick ``fori_loop``:
    stable-sort the beam by (masked distance, slot), keep the first
    occurrence of each page among finite entries, and take the first b —
    exactly the pages the iterated argmin would have scheduled, in the same
    order. Returns the updated state (selected candidates expanded, their
    pages marked visited, candidates on stale pages retired) and the (b,)
    batch of page ids to read, PAD padded.
    """
    cand_ids = state.cand_ids
    beam = cand_ids.shape[0]
    num_pages = state.page_vis.shape[0]
    b = io_batch

    cpages = jnp.where(cand_ids >= 0, cand_ids // capacity, 0)
    # retire candidates whose page was visited before this hop
    stale = (cand_ids != PAD) & state.page_vis[cpages]
    masked = jnp.where(
        state.cand_vis | stale | (cand_ids == PAD), INF, state.cand_d
    )

    slot = jnp.arange(beam, dtype=jnp.int32)
    sd, sslot = jax.lax.sort((masked, slot), num_keys=1, is_stable=True)
    spages = cpages[sslot]
    finite = jnp.isfinite(sd)
    # first finite occurrence of each page in (distance, slot) order
    earlier_same = (
        (spages[:, None] == spages[None, :])
        & (slot[None, :] < slot[:, None])      # strictly earlier sorted pos
        & finite[None, :]
    ).any(1)
    first = finite & ~earlier_same
    rank = jnp.cumsum(first) - first           # fresh pages scheduled before
    scheduled = first & (rank < b)
    n_sched = scheduled.sum()

    batch = (
        jnp.full((b,), PAD, jnp.int32)
        .at[jnp.where(scheduled, rank, b)]
        .set(spages.astype(jnp.int32), mode="drop")
    )
    page_vis = state.page_vis.at[
        jnp.where(scheduled, spages, num_pages)
    ].set(True, mode="drop")

    # expanded flags: the b scheduled picks, plus co-page candidates of any
    # page scheduled before the final pick (the serial loop's stale marking
    # ran once more after each pick except the last)
    early_pv = (
        jnp.zeros_like(state.page_vis)
        .at[jnp.where(scheduled & (rank < b - 1), spages, num_pages)]
        .set(True, mode="drop")
    )
    cand_vis = state.cand_vis | stale
    cand_vis = cand_vis.at[jnp.where(scheduled, sslot, beam)].set(
        True, mode="drop"
    )
    cand_vis = cand_vis | ((cand_ids != PAD) & early_pv[cpages])
    # the serial argmin marked slot 0 on every exhausted pick (all-INF mask)
    cand_vis = cand_vis.at[0].set(cand_vis[0] | (n_sched < b))
    return state._replace(cand_vis=cand_vis, page_vis=page_vis), batch


def page_member_mask(
    meta: MetaArrays, cfilter: CompiledFilter, batch: jnp.ndarray,
    *, capacity: int,
) -> jnp.ndarray:
    """Evaluate a compiled filter over one hop's page batch.

    ``meta`` holds page-slot-aligned metadata columns ((P*cap, T) tags /
    (P*cap, N) numerics — the same ``new_to_old`` layout the page records
    use), so a page's rows are one contiguous slice: gather the (b,)
    batch and evaluate the predicate to a (b, cap) f32 mask (1 = passes).
    Pad slots carry the missing sentinels (-1 / NaN) and never pass.
    """
    # explicit page count: a zero-width column block (schema with no tag
    # or no numeric fields) cannot infer it from a -1 reshape
    pages = meta.tags.shape[0] // capacity
    tags = meta.tags.reshape(pages, capacity, meta.tags.shape[-1])[batch]
    nums = meta.nums.reshape(pages, capacity, meta.nums.shape[-1])[batch]
    return filter_mask(cfilter, tags, nums).astype(jnp.float32)


def score_page_batch(
    q: jnp.ndarray,
    data: SearchData,
    batch: jnp.ndarray,
    state: BeamState,
    disk_lut: jnp.ndarray,
    mem_lut: jnp.ndarray | None,
    *,
    capacity: int,
    mode: str,
    fetch=None,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched page-record read (Fig. 6 steps 2-4, THE I/O) -> both score
    sets from one DMA per page.

    ``kernels.ops.page_scan`` scalar-prefetches the (b,) page ids and, per
    grid step, DMAs ONE packed record (members + neighbor codes + counts)
    HBM->VMEM, emitting exact member L2 distances and on-page neighbor ADC
    distances from the same resident block. MEM_ALL skips the on-page ADC
    (``compute_adc=False``) and HYBRID/MEM_ALL re-score covered neighbors
    with the finer in-memory codes via ``kernels.ops.pq_adc``.

    ``fetch`` is the streaming page tier's host hook (see
    ``stream_search``): when set, ``data.page_recs`` holds only the
    resident subset. Resident lanes are scored by the SAME fused
    ``ops.page_scan`` gather+scan over the device store the fully
    resident graph uses (identical op pattern -> identical codegen ->
    bit-identical floats); misses are pulled from the host memmap by the
    callback and scored from that staged buffer by
    ``ops.page_scan_recs`` (same per-record arithmetic). The two score
    sets merge per lane — record bytes are exact copies either way, so
    every score matches the fully resident search bit for bit.
    ``fetch=None`` (fully resident) keeps the one-array fused scan
    untouched.

    With a filter bound (``meta`` + ``cfilter``), the predicate is
    evaluated over the batch's page-slot-aligned metadata and pushed into
    the scan as a member mask: filtered-out members score ``+inf`` INSIDE
    the kernel, so the running result top-k only ever holds passing
    candidates. Neighbor ADC estimates stay unmasked — the graph must
    remain traversable through filtered-out regions to reach passing
    ones. With no filter both are ``None`` and the traced program is the
    exact pre-filter one.

    Returns (member_ids, member_dists) flattened to (b*cap,),
    (neighbor_ids, estimated_dists) flattened to (b*Rp,) and INF-masked,
    plus this hop's disk-I/O and cache-hit deltas.
    """
    cap = capacity
    rp = data.nbr_ids.shape[1]
    safe = jnp.maximum(batch, 0)
    fetched = batch >= 0

    member_mask = (
        page_member_mask(meta, cfilter, safe, capacity=cap)
        if meta is not None and cfilter is not None
        else None
    )
    compute_adc = mode != MemoryMode.MEM_ALL.value
    if fetch is None:
        ex, est_disk = ops.page_scan(
            data.page_recs, safe, q, disk_lut,
            capacity=cap, dim=q.shape[0], rp=rp, compute_adc=compute_adc,
            member_mask=member_mask,
        )
    else:
        slot = data.resident_map[safe]                  # (b,)
        resident = slot >= 0
        # host fetch only what the device lacks; everything else (resident
        # pages, unselected PAD lanes) is masked to -1 and comes back as a
        # zero record whose scores are discarded by the per-lane merge /
        # downstream validity masks
        staged = fetch(jnp.where(fetched & ~resident, safe, PAD))
        # the mask is a function of the page id alone, so the SAME (b,
        # cap) mask applies to the resident and staged lanes of the hop
        ex_r, est_r = ops.page_scan(
            data.page_recs, jnp.where(resident, slot, 0), q, disk_lut,
            capacity=cap, dim=q.shape[0], rp=rp, compute_adc=compute_adc,
            member_mask=member_mask,
        )
        ex_s, est_s = ops.page_scan_recs(
            staged, q, disk_lut,
            capacity=cap, dim=q.shape[0], rp=rp, compute_adc=compute_adc,
            member_mask=member_mask,
        )
        ex = jnp.where(resident[:, None], ex_r, ex_s)
        est_disk = (
            None if est_r is None
            else jnp.where(resident[:, None], est_r, est_s)
        )
    slots = jnp.arange(cap)[None, :]
    ex = jnp.where(slots < data.member_count[safe][:, None], ex, INF)
    ex = jnp.where(fetched[:, None], ex, INF)
    member_ids = (batch[:, None] * capacity + slots).astype(jnp.int32)

    # warmed page cache (Sec 4.3): sorted-membership test
    if data.cached_pages.shape[0] > 0:
        pos = jnp.searchsorted(data.cached_pages, safe)
        pos = jnp.minimum(pos, data.cached_pages.shape[0] - 1)
        in_cache = data.cached_pages[pos] == safe
    else:
        in_cache = jnp.zeros_like(fetched)
    io_delta = (fetched & ~in_cache).sum().astype(jnp.int32)
    hit_delta = (fetched & in_cache).sum().astype(jnp.int32)

    # neighbor estimates (Fig. 6 steps 3-4) per the coordination mode
    page_nids = data.nbr_ids[safe]                          # (b, Rp)
    flat_nids = page_nids.reshape(-1)                       # (b*Rp,)
    valid_n = (
        (jnp.arange(rp)[None, :] < data.nbr_count[safe][:, None]).reshape(-1)
        & (flat_nids != PAD)
        & fetched.repeat(rp)
    )
    safe_nids = jnp.maximum(flat_nids, 0)
    if mode == MemoryMode.DISK_ONLY.value:
        est = est_disk.reshape(-1)
    elif mode == MemoryMode.MEM_ALL.value:
        est = ops.pq_adc(data.mem_codes[safe_nids], mem_lut)
    else:  # HYBRID: prefer the higher-accuracy in-memory codes
        est_mem = ops.pq_adc(data.mem_codes[safe_nids], mem_lut)
        est = jnp.where(data.mem_mask[safe_nids], est_mem, est_disk.reshape(-1))
    est = jnp.where(valid_n, est, INF)
    # skip neighbors on already-visited pages
    est = jnp.where(state.page_vis[safe_nids // capacity], INF, est)
    # skip neighbors already in the candidate set: sorted membership probe
    sorted_cand = jnp.sort(state.cand_ids)
    pos = jnp.searchsorted(sorted_cand, flat_nids)
    pos = jnp.minimum(pos, sorted_cand.shape[0] - 1)
    est = jnp.where(sorted_cand[pos] == flat_nids, INF, est)
    # dedupe within this batch
    est = _mask_dups_keep_first(flat_nids, est)
    return member_ids.ravel(), ex.ravel(), flat_nids, est, io_delta, hit_delta


def merge(
    state: BeamState,
    member_ids: jnp.ndarray,
    member_d: jnp.ndarray,
    nbr_ids: jnp.ndarray,
    nbr_d: jnp.ndarray,
    io_delta: jnp.ndarray,
    hit_delta: jnp.ndarray,
    *,
    patience: int | None = None,
    epsilon: float = 0.0,
) -> BeamState:
    """Fold exact member scores into the result top-k and estimated
    neighbor scores into the beam (Alg. 2 line 12, Fig. 6 step 5) —
    ``lax.top_k`` selections, no full argsort merges.

    With early termination on (``patience``), this is also where the
    convergence signal updates: the worst of the new top-k either improved
    on the carried frontier by more than ``epsilon`` (stall resets) or it
    did not (stall increments) — the loop cond trips the lane once stall
    reaches ``patience``."""
    k = state.res_ids.shape[0]
    beam = state.cand_ids.shape[0]

    all_rd = jnp.concatenate([state.res_d, member_d])
    all_ri = jnp.concatenate([state.res_ids, member_ids])
    res_d, order = _top_k_merge(all_rd, k)
    res_ids = all_ri[order]

    all_ci = jnp.concatenate([state.cand_ids, nbr_ids])
    all_cd = jnp.concatenate([state.cand_d, nbr_d])
    all_cv = jnp.concatenate(
        [state.cand_vis, jnp.zeros(nbr_ids.shape, bool)]
    )
    cand_d, order = _top_k_merge(all_cd, beam)
    if patience is None:
        frontier, stall = state.frontier, state.stall
    else:
        # the running top-k only tightens, so the worst slot is monotone
        # non-increasing; "improved" means it dropped by more than epsilon
        # since the previous hop (INF - finite epsilon stays INF, so the
        # unfilled opening hops compare correctly)
        worst = res_d[k - 1]
        improved = worst < state.frontier - jnp.float32(epsilon)
        frontier = worst
        stall = jnp.where(improved, jnp.int32(0), state.stall + 1)
    return state._replace(
        cand_ids=all_ci[order],
        cand_d=cand_d,
        cand_vis=all_cv[order],
        res_ids=res_ids,
        res_d=res_d,
        io=state.io + io_delta,
        cache_hits=state.cache_hits + hit_delta,
        hops=state.hops + 1,
        frontier=frontier,
        stall=stall,
    )


def _search_one(
    q: jnp.ndarray,
    valid: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
    fetch=None,
    patience: int | None = None,
    epsilon: float = 0.0,
    entry_slack: int | None = None,
    min_entries: int = 1,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
):
    disk_lut = pq_mod.pq_lut(q, data.disk_codebooks)  # (M_disk, ksub)
    # the finer in-memory LUT is dead weight in DISK_ONLY mode — skip it
    mem_lut = (
        pq_mod.pq_lut(q, data.mem_codebooks)          # (M_mem, ksub)
        if mode != MemoryMode.DISK_ONLY.value
        else None
    )
    state = init_state(
        q, data, disk_lut, beam=beam, k=k, entries=entries,
        entry_slack=entry_slack, min_entries=min_entries, patience=patience,
    )

    def cond(state: BeamState):
        live = (
            (~state.cand_vis)
            & (state.cand_ids != PAD)
            & jnp.isfinite(state.cand_d)
        )
        go = live.any() & (state.hops < max_hops) & valid
        if patience is not None:
            # per-query early termination: once the worst of the top-k
            # stalled for `patience` consecutive hops, this lane exits
            # (vmap freezes it via select while stragglers keep hopping)
            go = go & (state.stall < patience)
        return go

    def body(state: BeamState):
        state, batch = select_batch(
            state, capacity=capacity, io_batch=io_batch
        )
        mids, md, nids, nd, io_delta, hit_delta = score_page_batch(
            q, data, batch, state, disk_lut, mem_lut,
            capacity=capacity, mode=mode, fetch=fetch,
            meta=meta, cfilter=cfilter,
        )
        return merge(
            state, mids, md, nids, nd, io_delta, hit_delta,
            patience=patience, epsilon=epsilon,
        )

    state = jax.lax.while_loop(cond, body, state)
    return state.res_ids, state.res_d, state.io, state.hops, state.cache_hits


def _batch_search_impl(
    queries: jnp.ndarray,
    data: SearchData,
    valid: jnp.ndarray,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
    fetch=None,
    patience: int | None = None,
    epsilon: float = 0.0,
    entry_slack: int | None = None,
    min_entries: int = 1,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> SearchResult:
    fn = functools.partial(
        _search_one,
        data=data,
        capacity=capacity,
        beam=beam,
        io_batch=io_batch,
        k=k,
        max_hops=max_hops,
        entries=entries,
        mode=mode,
        fetch=fetch,
        patience=patience,
        epsilon=epsilon,
        entry_slack=entry_slack,
        min_entries=min_entries,
        meta=meta,
        cfilter=cfilter,
    )
    ids, dists, ios, hops, hits = jax.vmap(fn)(queries, valid)
    return SearchResult(ids=ids, dists=dists, ios=ios, hops=hops, cache_hits=hits)


def _impl_kwargs(params: SearchParams, capacity: int, mode: str) -> dict:
    problems = params.pageann_violations()
    if problems:
        # every violated invariant in ONE error, not first-wins
        raise ValueError(
            "invalid SearchParams for PageANN search: " + "; ".join(problems)
        )
    a = params.adaptive
    return dict(
        capacity=capacity,
        beam=params.beam_width,
        io_batch=params.io_batch,
        k=params.k,
        max_hops=params.max_hops,
        entries=params.lsh_entries,
        mode=mode,
        patience=None if a is None else a.patience,
        epsilon=0.0 if a is None else a.epsilon,
        entry_slack=None if a is None else a.entry_slack_bits,
        min_entries=1 if a is None else a.min_entries,
    )


@functools.partial(
    jax.jit, static_argnames=("params", "capacity", "mode", "cfilter")
)
def batch_search(
    queries: jnp.ndarray,
    data: SearchData,
    params: SearchParams,
    *,
    capacity: int,
    mode: str,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> SearchResult:
    """Search a batch of queries. queries: (Q, d).

    ``params`` carries the per-call runtime knobs (beam L, io batch b,
    max hops, LSH top-T, k) and, being frozen/hashable, is a *static* jit
    argument: each distinct ``SearchParams`` value keys one compiled
    executable over the same built index. ``capacity`` and ``mode`` are
    build-time properties of the index artifact.

    Filtered search binds ``meta`` (page-slot-aligned metadata columns, a
    dynamic pytree) and ``cfilter`` (the compiled predicate — frozen
    tuples, another static arg, so each distinct predicate keys its own
    executable). Both default to ``None``, and because ``meta`` is an
    argument rather than a ``SearchData`` field, the no-filter call keeps
    the exact pre-filter jit signature and traces the identical program.
    """
    valid = jnp.ones((queries.shape[0],), bool)
    return _batch_search_impl(
        queries, data, valid, meta=meta, cfilter=cfilter,
        **_impl_kwargs(params, capacity, mode),
    )


# --------------------------------------------------------------------------
# streaming entry point: resident subset on device, misses fetched per hop
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _stream_search_fn(
    fetcher, params: SearchParams, capacity: int, mode: str,
    cfilter: CompiledFilter | None = None,
):
    """jitted streaming search bound to one host fetcher.

    Cached per (fetcher, params, capacity, mode, cfilter): the fetcher is
    baked into the executable as the hop body's host callback, so two
    streamed indexes never share a compiled closure — mirrored in the
    serving layer's compile-cache key
    (``serve.compile_cache.geometry_of``). The fetcher participates in
    the lru key by identity, which is exactly the sharing rule we want;
    the compiled filter (frozen tuples) participates by value, one
    executable per distinct predicate.
    """
    from repro.core import compat

    kwargs = _impl_kwargs(params, capacity, mode)
    rows, lanes = fetcher.record_shape

    def fetch(ids: jnp.ndarray) -> jnp.ndarray:
        return compat.pure_callback_batched(
            fetcher,
            jax.ShapeDtypeStruct(ids.shape + (rows, lanes), jnp.float32),
            ids,
        )

    @jax.jit
    def fn(queries, data, valid, meta=None):
        return _batch_search_impl(
            queries, data, valid, fetch=fetch, meta=meta, cfilter=cfilter,
            **kwargs,
        )

    return fn


def stream_search(
    queries: jnp.ndarray,
    data: SearchData,
    params: SearchParams,
    *,
    capacity: int,
    mode: str,
    fetcher,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> SearchResult:
    """``batch_search`` over a budgeted index: ``data.page_recs`` holds
    only the resident page subset, and each hop's misses are pulled from
    the host memmap by ``fetcher`` (a ``core.stream.PageFetcher``) through
    a batched ``pure_callback`` — ONE host round-trip per hop for the
    whole query batch.

    Results are bit-identical to the fully resident ``batch_search`` on
    the same artifact: the staged batch is scored by
    ``kernels.ops.page_scan_recs`` with the same per-record compute, and
    every counter in ``SearchResult`` (ios/hops/cache_hits) is carried
    on-device independent of residency. (Host-side fetch counters are a
    superset of the useful reads — a vmapped while_loop keeps converged
    queries in the body until the whole batch exits, and their discarded
    hops still fetch.)
    """
    fn = _stream_search_fn(fetcher, params, capacity, mode, cfilter)
    valid = jnp.ones((queries.shape[0],), bool)
    return fn(queries, data, valid, meta)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk_streams(
    ids_a: jnp.ndarray,
    d_a: jnp.ndarray,
    ids_b: jnp.ndarray,
    d_b: jnp.ndarray,
    *,
    k: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two per-query top-k result streams into one (Q, k) top-k.

    The fresh+disk unification point of the mutable index
    (``repro.core.delta``): stream *a* is the persisted page-file search
    (tombstones already masked to PAD/INF), stream *b* the in-memory delta
    scan. Both are (Q, ka) / (Q, kb) ascending-by-distance with PAD ids
    carrying INF distances; the merge is one batched ``lax.top_k`` over the
    concatenation — same selection rule as the hot loop's ``merge`` — and
    re-masks non-finite winners to PAD so padding never leaks as a result.
    Returns (ids (Q, k) int32, dists (Q, k) f32).
    """
    d = jnp.concatenate([d_a, d_b], axis=1)
    ids = jnp.concatenate([ids_a, ids_b], axis=1).astype(jnp.int32)
    neg, idx = jax.lax.top_k(-d, k)
    merged = jnp.take_along_axis(ids, idx, axis=1)
    return jnp.where(jnp.isfinite(neg), merged, PAD), -neg


# --------------------------------------------------------------------------
# profiling entry point: the same transitions, with the per-hop trail kept
# --------------------------------------------------------------------------

class HopProfile(NamedTuple):
    """Per-hop trail of a profiled search (leading dims (Q, max_hops)).

    Hops past a query's exit carry ``active=False`` with PAD pages and
    zero deltas — fixed shape, mask to read. ``worst_topk`` is the worst
    running top-k distance *after* the hop (the early-termination
    frontier signal); ``stall`` is the adaptive patience counter (all
    zeros when the params are non-adaptive).
    """

    pages: jnp.ndarray       # (Q, H, b) page ids scheduled, PAD padded
    ios: jnp.ndarray         # (Q, H) disk page reads this hop
    cache_hits: jnp.ndarray  # (Q, H) cached page reads this hop
    active: jnp.ndarray      # (Q, H) bool: did the lane actually hop
    worst_topk: jnp.ndarray  # (Q, H) f32 running worst top-k distance
    stall: jnp.ndarray       # (Q, H) int32 patience counter after the hop


def _profile_one(
    q: jnp.ndarray,
    valid: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
    fetch=None,
    patience: int | None = None,
    epsilon: float = 0.0,
    entry_slack: int | None = None,
    min_entries: int = 1,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
):
    """``_search_one`` with the per-hop trail recorded.

    A ``lax.scan`` over ``max_hops`` replaces the ``while_loop``, calling
    the SAME pure transitions (``select_batch`` -> ``score_page_batch``
    -> ``merge``) and replicating the loop semantics explicitly: each
    step evaluates the while-cond, runs the body, and keeps the new state
    only where the cond held — the per-lane freeze vmap applies to a
    while_loop. ``_search_one`` itself is untouched, so the non-profiled
    path still traces the exact pre-profiling program.
    """
    disk_lut = pq_mod.pq_lut(q, data.disk_codebooks)
    mem_lut = (
        pq_mod.pq_lut(q, data.mem_codebooks)
        if mode != MemoryMode.DISK_ONLY.value
        else None
    )
    state = init_state(
        q, data, disk_lut, beam=beam, k=k, entries=entries,
        entry_slack=entry_slack, min_entries=min_entries, patience=patience,
    )

    def cond(state: BeamState):
        live = (
            (~state.cand_vis)
            & (state.cand_ids != PAD)
            & jnp.isfinite(state.cand_d)
        )
        go = live.any() & (state.hops < max_hops) & valid
        if patience is not None:
            go = go & (state.stall < patience)
        return go

    def step(state: BeamState, _):
        active = cond(state)
        st, batch = select_batch(
            state, capacity=capacity, io_batch=io_batch
        )
        mids, md, nids, nd, io_delta, hit_delta = score_page_batch(
            q, data, batch, st, disk_lut, mem_lut,
            capacity=capacity, mode=mode, fetch=fetch,
            meta=meta, cfilter=cfilter,
        )
        st = merge(
            st, mids, md, nids, nd, io_delta, hit_delta,
            patience=patience, epsilon=epsilon,
        )
        new = jax.tree.map(
            lambda a, b: jnp.where(active, b, a), state, st
        )
        rec = (
            jnp.where(active, batch, PAD),
            jnp.where(active, io_delta, 0).astype(jnp.int32),
            jnp.where(active, hit_delta, 0).astype(jnp.int32),
            active,
            new.res_d[k - 1],
            new.stall if patience is not None else jnp.int32(0),
        )
        return new, rec

    final, (pages, ios, hits, active, worst, stall) = jax.lax.scan(
        step, state, None, length=max_hops
    )
    return (
        (final.res_ids, final.res_d, final.io, final.hops, final.cache_hits),
        (pages, ios, hits, active, worst, stall),
    )


@functools.partial(
    jax.jit, static_argnames=("params", "capacity", "mode", "cfilter")
)
def profile_search(
    queries: jnp.ndarray,
    data: SearchData,
    params: SearchParams,
    *,
    capacity: int,
    mode: str,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> tuple[SearchResult, HopProfile]:
    """``batch_search`` plus the per-hop trail (opt-in debug mode).

    Same arguments, same selection semantics: the profile run reuses the
    hop transitions verbatim, so scheduled pages, IO counters, hops and
    result ids match ``batch_search`` exactly (distances match up to XLA
    fusion reassociation across the scan-vs-while program boundary).
    This is a SEPARATE traced program — calling it never touches the
    compiled fast path's cache entries or its codegen.
    """
    valid = jnp.ones((queries.shape[0],), bool)
    fn = functools.partial(
        _profile_one, data=data, meta=meta, cfilter=cfilter,
        **_impl_kwargs(params, capacity, mode),
    )
    res, trail = jax.vmap(lambda q, v: fn(q, v))(queries, valid)
    ids, dists, ios, hops, hits = res
    pages, hio, hhits, active, worst, stall = trail
    return (
        SearchResult(ids=ids, dists=dists, ios=ios, hops=hops,
                     cache_hits=hits),
        HopProfile(pages=pages, ios=hio, cache_hits=hhits, active=active,
                   worst_topk=worst, stall=stall),
    )


# --------------------------------------------------------------------------
# mesh-sharded entry point: shard the query batch, replicate the index
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _shard_search_fn(
    mesh, params: SearchParams, capacity: int, mode: str,
    cfilter: CompiledFilter | None = None, with_meta: bool = False,
):
    """jitted shard_map: queries split over every mesh axis, data replicated.

    Cached per (mesh, params, capacity, mode, cfilter, with_meta) so
    repeated serving calls reuse the compiled executable. Filtered
    dispatches replicate the metadata columns like the index arrays
    (``with_meta``); the no-filter entry builds the exact pre-filter
    shard_map signature.
    """
    axes = tuple(mesh.axis_names)
    local = functools.partial(
        _batch_search_impl, **_impl_kwargs(params, capacity, mode)
    )
    data_spec = jax.tree.map(
        lambda _: P(), SearchData(*[0] * len(SearchData._fields))
    )
    if with_meta:
        def local_meta(queries, data, valid, meta):
            return local(queries, data, valid, meta=meta, cfilter=cfilter)

        fn = compat.shard_map(
            local_meta,
            mesh=mesh,
            in_specs=(P(axes), data_spec, P(axes), MetaArrays(P(), P())),
            out_specs=P(axes),
        )
    else:
        fn = compat.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axes), data_spec, P(axes)),
            out_specs=P(axes),
        )
    return jax.jit(fn)


def shard_search(
    queries: jnp.ndarray,
    data: SearchData,
    params: SearchParams,
    *,
    mesh=None,
    capacity: int,
    mode: str,
    meta: MetaArrays | None = None,
    cfilter: CompiledFilter | None = None,
) -> SearchResult:
    """``batch_search`` with the query batch sharded across a device mesh.

    The index (``data``) is replicated on every device; the (Q, d) query
    batch is split over all mesh axes — the paper's "query threads"
    throughput dimension mapped onto chips. Ragged batches are zero-padded
    to a multiple of the mesh size; the pad rows carry ``valid=False`` so
    their while_loop exits at hop 0 (no wasted full searches) and are
    trimmed from the result. On a 1-device mesh with no padding this runs
    the exact ``_batch_search_impl`` trace, so ids and distances are
    bitwise identical to ``batch_search``. (Index sharding — partitioning
    the vectors themselves — is the orthogonal axis and lives in
    ``core.distributed``.)
    """
    if mesh is None:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
    fn = _shard_search_fn(
        mesh, params, capacity, mode, cfilter, meta is not None
    )
    num_dev = 1
    for n in mesh.shape.values():
        num_dev *= n
    qn = queries.shape[0]
    pad = (-qn) % num_dev
    valid = jnp.ones((qn,), bool)
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pad, queries.shape[1]), queries.dtype)]
        )
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    res = fn(queries, data, valid, meta) if meta is not None else fn(
        queries, data, valid
    )
    if pad:
        res = jax.tree.map(lambda a: a[:qn], res)
    return res


