"""PageANN graph search — Algorithm 2, as a fixed-shape JAX program.

Per query the loop maintains
  * a candidate set (size-L, distance-sorted, visited flags) over *vector*
    ids in the reassigned space (page = id // capacity),
  * a visited-page bitmap (the paper's visited set V),
  * a running exact-distance result set (size-K),
and per hop it (1) picks up to b closest unvisited candidates whose pages are
new, (2) gathers those page records in one batched read — the I/O unit, (3)
scores every member vector exactly (MXU L2 kernel), (4) scores the pages'
external neighbors with ADC over on-page or in-memory PQ codes depending on
the memory-disk coordination mode, and (5) merges both sets.

Everything is fixed-shape: the loop is a ``lax.while_loop``, queries are
vmapped, and the whole thing jits (and lowers for TPU meshes — see
``core.distributed``). I/O and cache-hit counters reproduce the paper's
"Mean I/Os" metric.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq as pq_mod
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.layout import MemoryTier, PageStore
from repro.core.lsh import LSHIndex, hamming_distance, hash_codes

PAD = -1
INF = jnp.inf


class SearchData(NamedTuple):
    """All device arrays the search touches (a single pytree argument)."""

    # disk tier (page records)
    vecs: jnp.ndarray          # (P, cap, d)
    member_count: jnp.ndarray  # (P,)
    nbr_ids: jnp.ndarray       # (P, Rp)
    nbr_codes: jnp.ndarray     # (P, Rp, M_disk)
    nbr_count: jnp.ndarray     # (P,)
    # memory tier
    mem_codes: jnp.ndarray     # (N_pad, M_mem)
    mem_mask: jnp.ndarray      # (N_pad,)
    mem_codebooks: jnp.ndarray
    disk_codebooks: jnp.ndarray
    cached_pages: jnp.ndarray  # (C,) sorted
    # routing index
    lsh_planes: jnp.ndarray
    lsh_ids: jnp.ndarray
    lsh_codes: jnp.ndarray
    lsh_pq: jnp.ndarray        # (S, M_disk)


def make_search_data(store: PageStore, tier: MemoryTier, lsh: LSHIndex) -> SearchData:
    return SearchData(
        vecs=store.vecs,
        member_count=store.member_count,
        nbr_ids=store.nbr_ids,
        nbr_codes=store.nbr_codes,
        nbr_count=store.nbr_count,
        mem_codes=tier.mem_codes,
        mem_mask=tier.mem_mask,
        mem_codebooks=tier.mem_codebooks,
        disk_codebooks=tier.disk_codebooks,
        cached_pages=tier.cached_pages,
        lsh_planes=lsh.planes,
        lsh_ids=lsh.sample_ids,
        lsh_codes=lsh.sample_codes,
        lsh_pq=lsh.sample_pq,
    )


class SearchResult(NamedTuple):
    ids: jnp.ndarray      # (Q, k) reassigned vector ids
    dists: jnp.ndarray    # (Q, k) exact squared distances
    ios: jnp.ndarray      # (Q,) page reads that went to 'disk'
    hops: jnp.ndarray     # (Q,) while_loop iterations
    cache_hits: jnp.ndarray  # (Q,) page reads served by the warmed cache


def _mask_dups_keep_first(ids: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Set distance to INF for duplicate ids (keeping one occurrence)."""
    order = jnp.argsort(ids)
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return jnp.where(dup & (ids != PAD), INF, d)


def _search_one(
    q: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
):
    P = data.vecs.shape[0]
    cap, d = data.vecs.shape[1], data.vecs.shape[2]
    rp = data.nbr_ids.shape[1]

    disk_lut = pq_mod.pq_lut(q, data.disk_codebooks)  # (M_disk, ksub)
    mem_lut = pq_mod.pq_lut(q, data.mem_codebooks)    # (M_mem, ksub)

    # ---- in-memory routing (Alg. 2 line 4, Fig. 6 step 1) ----
    qcode = hash_codes(q[None], data.lsh_planes)[0]
    ham = hamming_distance(data.lsh_codes, qcode)
    top = jnp.argsort(ham)[:entries]
    entry_ids = data.lsh_ids[top].astype(jnp.int32)
    entry_d = pq_mod.adc_distance(data.lsh_pq[top], disk_lut)
    entry_d = _mask_dups_keep_first(entry_ids, entry_d)

    cand_ids = jnp.full((beam,), PAD, jnp.int32)
    cand_d = jnp.full((beam,), INF, jnp.float32)
    cand_vis = jnp.zeros((beam,), bool)
    cand_ids = cand_ids.at[:entries].set(entry_ids)
    cand_d = cand_d.at[:entries].set(entry_d)

    page_vis = jnp.zeros((P,), bool)
    res_ids = jnp.full((k,), PAD, jnp.int32)
    res_d = jnp.full((k,), INF, jnp.float32)
    io = jnp.int32(0)
    hits = jnp.int32(0)
    hops = jnp.int32(0)

    def cond(state):
        cand_ids, cand_d, cand_vis, page_vis, res_ids, res_d, io, hits, hops = state
        live = (~cand_vis) & (cand_ids != PAD) & jnp.isfinite(cand_d)
        return live.any() & (hops < max_hops)

    def body(state):
        cand_ids, cand_d, cand_vis, page_vis, res_ids, res_d, io, hits, hops = state

        # ---- select up to b closest unvisited candidates on fresh pages ----
        batch = jnp.full((io_batch,), PAD, jnp.int32)

        def pick(j, carry):
            cand_vis, page_vis, batch = carry
            # skip candidates whose page is already visited/scheduled
            cpages = jnp.where(cand_ids >= 0, cand_ids // capacity, 0)
            stale = (cand_ids != PAD) & page_vis[cpages]
            cand_vis2 = cand_vis | stale
            masked = jnp.where(
                cand_vis2 | (cand_ids == PAD), INF, cand_d
            )
            slot = jnp.argmin(masked)
            ok = jnp.isfinite(masked[slot])
            cand_vis2 = cand_vis2.at[slot].set(True)
            pid = jnp.where(ok, cand_ids[slot] // capacity, PAD)
            page_vis = jnp.where(
                ok, page_vis.at[jnp.maximum(pid, 0)].set(True), page_vis
            )
            batch = batch.at[j].set(pid)
            return cand_vis2, page_vis, batch

        cand_vis, page_vis, batch = jax.lax.fori_loop(
            0, io_batch, pick, (cand_vis, page_vis, batch)
        )

        # ---- batched page read (Fig. 6 step 2): THE I/O ----
        safe = jnp.maximum(batch, 0)
        page_vecs = data.vecs[safe]            # (b, cap, d)
        page_mc = data.member_count[safe]      # (b,)
        page_nids = data.nbr_ids[safe]         # (b, Rp)
        page_ncodes = data.nbr_codes[safe]     # (b, Rp, M_disk)
        page_nc = data.nbr_count[safe]

        fetched = batch >= 0
        # warmed page cache (Sec 4.3): sorted-membership test
        if data.cached_pages.shape[0] > 0:
            pos = jnp.searchsorted(data.cached_pages, safe)
            pos = jnp.minimum(pos, data.cached_pages.shape[0] - 1)
            in_cache = data.cached_pages[pos] == safe
        else:
            in_cache = jnp.zeros_like(fetched)
        io = io + (fetched & ~in_cache).sum().astype(jnp.int32)
        hits = hits + (fetched & in_cache).sum().astype(jnp.int32)

        # ---- exact distances for every member vector (step 5) ----
        ex = jnp.sum((page_vecs - q[None, None, :]) ** 2, axis=-1)  # (b, cap)
        slots = jnp.arange(cap)[None, :]
        ex = jnp.where(slots < page_mc[:, None], ex, INF)
        ex = jnp.where(fetched[:, None], ex, INF)
        mids = (batch[:, None] * capacity + slots).astype(jnp.int32)
        all_rd = jnp.concatenate([res_d, ex.ravel()])
        all_ri = jnp.concatenate([res_ids, mids.ravel()])
        order = jnp.argsort(all_rd)[:k]
        res_d, res_ids = all_rd[order], all_ri[order]

        # ---- estimated distances for page neighbors (steps 3-4) ----
        flat_nids = page_nids.reshape(-1)                       # (b*Rp,)
        valid_n = (
            (jnp.arange(rp)[None, :] < page_nc[:, None]).reshape(-1)
            & (flat_nids != PAD)
            & fetched.repeat(rp)
        )
        safe_nids = jnp.maximum(flat_nids, 0)
        est_disk = pq_mod.adc_distance(
            page_ncodes.reshape(-1, page_ncodes.shape[-1]), disk_lut
        )
        if mode == MemoryMode.DISK_ONLY.value:
            est = est_disk
        elif mode == MemoryMode.MEM_ALL.value:
            est = pq_mod.adc_distance(data.mem_codes[safe_nids], mem_lut)
        else:  # HYBRID: prefer the higher-accuracy in-memory codes
            est_mem = pq_mod.adc_distance(data.mem_codes[safe_nids], mem_lut)
            est = jnp.where(data.mem_mask[safe_nids], est_mem, est_disk)
        est = jnp.where(valid_n, est, INF)
        # skip neighbors on already-visited pages
        est = jnp.where(page_vis[safe_nids // capacity], INF, est)
        # skip neighbors already in the candidate set
        dup_in_cand = (flat_nids[:, None] == cand_ids[None, :]).any(1)
        est = jnp.where(dup_in_cand, INF, est)
        # dedupe within this batch
        est = _mask_dups_keep_first(flat_nids, est)

        all_ci = jnp.concatenate([cand_ids, flat_nids])
        all_cd = jnp.concatenate([cand_d, est])
        all_cv = jnp.concatenate([cand_vis, jnp.zeros_like(valid_n)])
        order = jnp.argsort(all_cd)[:beam]
        return (
            all_ci[order], all_cd[order], all_cv[order],
            page_vis, res_ids, res_d, io, hits, hops + 1,
        )

    state = (cand_ids, cand_d, cand_vis, page_vis, res_ids, res_d, io, hits, hops)
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, _, res_ids, res_d, io, hits, hops = state
    return res_ids, res_d, io, hops, hits


@functools.partial(
    jax.jit,
    static_argnames=(
        "capacity", "beam", "io_batch", "k", "max_hops", "entries", "mode"
    ),
)
def batch_search(
    queries: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
) -> SearchResult:
    """Search a batch of queries. queries: (Q, d)."""
    fn = functools.partial(
        _search_one,
        data=data,
        capacity=capacity,
        beam=beam,
        io_batch=io_batch,
        k=k,
        max_hops=max_hops,
        entries=entries,
        mode=mode,
    )
    ids, dists, ios, hops, hits = jax.vmap(fn)(queries)
    return SearchResult(ids=ids, dists=dists, ios=ios, hops=hops, cache_hits=hits)


def search_kwargs(cfg: PageANNConfig, capacity: int) -> dict:
    return dict(
        capacity=capacity,
        beam=cfg.beam_width,
        io_batch=cfg.io_batch,
        max_hops=cfg.max_hops,
        entries=cfg.lsh_entries,
        mode=cfg.memory_mode.value,
    )
