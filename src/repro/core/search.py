"""PageANN graph search — Algorithm 2, as a fixed-shape JAX program.

Per query the loop maintains a :class:`BeamState`:
  * a candidate set (size-L, distance-sorted, visited flags) over *vector*
    ids in the reassigned space (page = id // capacity),
  * a visited-page bitmap (the paper's visited set V),
  * a running exact-distance result set (size-K),
and per hop applies four pure transition functions:

  ``select_batch``    pick up to b closest unvisited candidates on fresh
                      pages — the I/O schedule for this hop,
  ``score_members``   gather those page records in one batched read (the
                      I/O unit; ``kernels.ops.page_gather_l2`` — scalar-
                      prefetched page DMA on TPU, jnp oracle on CPU) and
                      score every member vector exactly,
  ``score_neighbors`` ADC-score the pages' external neighbors over on-page
                      or in-memory PQ codes (``kernels.ops.pq_adc``),
  ``merge``           fold both score sets into the beam and result top-k.

Everything is fixed-shape: the loop is a ``lax.while_loop``, queries are
vmapped (``batch_search``) and optionally sharded over a device mesh
(``shard_search``). I/O and cache-hit counters reproduce the paper's
"Mean I/Os" metric. Later async-prefetch / cache-eviction work should
extend the transition functions, not re-inline the loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import pq as pq_mod
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.layout import MemoryTier, PageStore
from repro.core.lsh import LSHIndex, hash_codes
from repro.kernels import ops

PAD = -1
INF = jnp.inf


class SearchData(NamedTuple):
    """All device arrays the search touches (a single pytree argument)."""

    # disk tier (page records)
    vecs: jnp.ndarray          # (P, cap, d)
    member_count: jnp.ndarray  # (P,)
    nbr_ids: jnp.ndarray       # (P, Rp)
    nbr_codes: jnp.ndarray     # (P, Rp, M_disk)
    nbr_count: jnp.ndarray     # (P,)
    # memory tier
    mem_codes: jnp.ndarray     # (N_pad, M_mem)
    mem_mask: jnp.ndarray      # (N_pad,)
    mem_codebooks: jnp.ndarray
    disk_codebooks: jnp.ndarray
    cached_pages: jnp.ndarray  # (C,) sorted
    # routing index
    lsh_planes: jnp.ndarray
    lsh_ids: jnp.ndarray
    lsh_codes: jnp.ndarray
    lsh_pq: jnp.ndarray        # (S, M_disk)


def make_search_data(store: PageStore, tier: MemoryTier, lsh: LSHIndex) -> SearchData:
    return SearchData(
        vecs=store.vecs,
        member_count=store.member_count,
        nbr_ids=store.nbr_ids,
        nbr_codes=store.nbr_codes,
        nbr_count=store.nbr_count,
        mem_codes=tier.mem_codes,
        mem_mask=tier.mem_mask,
        mem_codebooks=tier.mem_codebooks,
        disk_codebooks=tier.disk_codebooks,
        cached_pages=tier.cached_pages,
        lsh_planes=lsh.planes,
        lsh_ids=lsh.sample_ids,
        lsh_codes=lsh.sample_codes,
        lsh_pq=lsh.sample_pq,
    )


class SearchResult(NamedTuple):
    ids: jnp.ndarray      # (Q, k) reassigned vector ids
    dists: jnp.ndarray    # (Q, k) exact squared distances
    ios: jnp.ndarray      # (Q,) page reads that went to 'disk'
    hops: jnp.ndarray     # (Q,) while_loop iterations
    cache_hits: jnp.ndarray  # (Q,) page reads served by the warmed cache


class BeamState(NamedTuple):
    """Per-query loop state of Algorithm 2 (one pytree, while_loop carry)."""

    cand_ids: jnp.ndarray   # (L,) candidate vector ids, PAD padded
    cand_d: jnp.ndarray     # (L,) estimated distances, INF padded
    cand_vis: jnp.ndarray   # (L,) expanded/scheduled flags
    page_vis: jnp.ndarray   # (P,) visited-page bitmap (the paper's V)
    res_ids: jnp.ndarray    # (k,) running exact top-k ids
    res_d: jnp.ndarray      # (k,) running exact top-k distances
    io: jnp.ndarray         # () page reads served from 'disk'
    cache_hits: jnp.ndarray  # () page reads served by the warmed cache
    hops: jnp.ndarray       # () loop iterations


def _mask_dups_keep_first(ids: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Set distance to INF for duplicate ids (keeping one occurrence)."""
    order = jnp.argsort(ids)
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return jnp.where(dup & (ids != PAD), INF, d)


# --------------------------------------------------------------------------
# per-hop transition functions (pure; composed by _search_one's loop body)
# --------------------------------------------------------------------------

def init_state(
    q: jnp.ndarray,
    data: SearchData,
    disk_lut: jnp.ndarray,
    *,
    beam: int,
    k: int,
    entries: int,
) -> BeamState:
    """In-memory routing (Alg. 2 line 4, Fig. 6 step 1): LSH entry points."""
    num_pages = data.vecs.shape[0]
    qcode = hash_codes(q[None], data.lsh_planes)[0]
    ham = ops.hamming(data.lsh_codes, qcode)
    top = jnp.argsort(ham)[:entries]
    entry_ids = data.lsh_ids[top].astype(jnp.int32)
    entry_d = ops.pq_adc(data.lsh_pq[top], disk_lut)
    entry_d = _mask_dups_keep_first(entry_ids, entry_d)

    cand_ids = jnp.full((beam,), PAD, jnp.int32).at[:entries].set(entry_ids)
    cand_d = jnp.full((beam,), INF, jnp.float32).at[:entries].set(entry_d)
    return BeamState(
        cand_ids=cand_ids,
        cand_d=cand_d,
        cand_vis=jnp.zeros((beam,), bool),
        page_vis=jnp.zeros((num_pages,), bool),
        res_ids=jnp.full((k,), PAD, jnp.int32),
        res_d=jnp.full((k,), INF, jnp.float32),
        io=jnp.int32(0),
        cache_hits=jnp.int32(0),
        hops=jnp.int32(0),
    )


def select_batch(
    state: BeamState, *, capacity: int, io_batch: int
) -> tuple[BeamState, jnp.ndarray]:
    """Pick up to b closest unvisited candidates whose pages are fresh.

    Returns the updated state (candidates expanded, pages marked visited)
    and the (b,) batch of page ids to read, PAD padded.
    """
    cand_ids = state.cand_ids
    batch = jnp.full((io_batch,), PAD, jnp.int32)

    def pick(j, carry):
        cand_vis, page_vis, batch = carry
        # skip candidates whose page is already visited/scheduled
        cpages = jnp.where(cand_ids >= 0, cand_ids // capacity, 0)
        stale = (cand_ids != PAD) & page_vis[cpages]
        cand_vis2 = cand_vis | stale
        masked = jnp.where(cand_vis2 | (cand_ids == PAD), INF, state.cand_d)
        slot = jnp.argmin(masked)
        ok = jnp.isfinite(masked[slot])
        cand_vis2 = cand_vis2.at[slot].set(True)
        pid = jnp.where(ok, cand_ids[slot] // capacity, PAD)
        page_vis = jnp.where(
            ok, page_vis.at[jnp.maximum(pid, 0)].set(True), page_vis
        )
        batch = batch.at[j].set(pid)
        return cand_vis2, page_vis, batch

    cand_vis, page_vis, batch = jax.lax.fori_loop(
        0, io_batch, pick, (state.cand_vis, state.page_vis, batch)
    )
    return state._replace(cand_vis=cand_vis, page_vis=page_vis), batch


def score_members(
    q: jnp.ndarray, data: SearchData, batch: jnp.ndarray, *, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched page read (Fig. 6 step 2, THE I/O) + exact member scoring.

    The gather-and-score is one ``kernels.ops.page_gather_l2`` call: on TPU
    the (b,) page ids are scalar-prefetched and each page record arrives as
    one aligned HBM->VMEM DMA; on CPU the jnp oracle runs. Returns
    (member_ids, member_dists) flattened to (b*cap,), plus this hop's
    disk-I/O and cache-hit deltas.
    """
    cap = data.vecs.shape[1]
    safe = jnp.maximum(batch, 0)
    fetched = batch >= 0

    ex = ops.page_gather_l2(data.vecs, safe, q)            # (b, cap)
    slots = jnp.arange(cap)[None, :]
    ex = jnp.where(slots < data.member_count[safe][:, None], ex, INF)
    ex = jnp.where(fetched[:, None], ex, INF)
    member_ids = (batch[:, None] * capacity + slots).astype(jnp.int32)

    # warmed page cache (Sec 4.3): sorted-membership test
    if data.cached_pages.shape[0] > 0:
        pos = jnp.searchsorted(data.cached_pages, safe)
        pos = jnp.minimum(pos, data.cached_pages.shape[0] - 1)
        in_cache = data.cached_pages[pos] == safe
    else:
        in_cache = jnp.zeros_like(fetched)
    io_delta = (fetched & ~in_cache).sum().astype(jnp.int32)
    hit_delta = (fetched & in_cache).sum().astype(jnp.int32)
    return member_ids.ravel(), ex.ravel(), io_delta, hit_delta


def score_neighbors(
    data: SearchData,
    batch: jnp.ndarray,
    state: BeamState,
    disk_lut: jnp.ndarray,
    mem_lut: jnp.ndarray,
    *,
    capacity: int,
    mode: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Estimated distances for the fetched pages' external neighbors
    (Fig. 6 steps 3-4) via ADC (``kernels.ops.pq_adc``) over on-page or
    in-memory PQ codes per the memory-disk coordination mode. Returns
    (neighbor_ids, estimated_dists) flattened to (b*Rp,), INF-masked."""
    rp = data.nbr_ids.shape[1]
    safe = jnp.maximum(batch, 0)
    fetched = batch >= 0
    page_nids = data.nbr_ids[safe]                          # (b, Rp)
    page_ncodes = data.nbr_codes[safe]                      # (b, Rp, M_disk)
    page_nc = data.nbr_count[safe]

    flat_nids = page_nids.reshape(-1)                       # (b*Rp,)
    valid_n = (
        (jnp.arange(rp)[None, :] < page_nc[:, None]).reshape(-1)
        & (flat_nids != PAD)
        & fetched.repeat(rp)
    )
    safe_nids = jnp.maximum(flat_nids, 0)
    est_disk = ops.pq_adc(
        page_ncodes.reshape(-1, page_ncodes.shape[-1]), disk_lut
    )
    if mode == MemoryMode.DISK_ONLY.value:
        est = est_disk
    elif mode == MemoryMode.MEM_ALL.value:
        est = ops.pq_adc(data.mem_codes[safe_nids], mem_lut)
    else:  # HYBRID: prefer the higher-accuracy in-memory codes
        est_mem = ops.pq_adc(data.mem_codes[safe_nids], mem_lut)
        est = jnp.where(data.mem_mask[safe_nids], est_mem, est_disk)
    est = jnp.where(valid_n, est, INF)
    # skip neighbors on already-visited pages
    est = jnp.where(state.page_vis[safe_nids // capacity], INF, est)
    # skip neighbors already in the candidate set
    dup_in_cand = (flat_nids[:, None] == state.cand_ids[None, :]).any(1)
    est = jnp.where(dup_in_cand, INF, est)
    # dedupe within this batch
    est = _mask_dups_keep_first(flat_nids, est)
    return flat_nids, est


def merge(
    state: BeamState,
    member_ids: jnp.ndarray,
    member_d: jnp.ndarray,
    nbr_ids: jnp.ndarray,
    nbr_d: jnp.ndarray,
    io_delta: jnp.ndarray,
    hit_delta: jnp.ndarray,
) -> BeamState:
    """Fold exact member scores into the result top-k and estimated
    neighbor scores into the beam (Alg. 2 line 12, Fig. 6 step 5)."""
    k = state.res_ids.shape[0]
    beam = state.cand_ids.shape[0]

    all_rd = jnp.concatenate([state.res_d, member_d])
    all_ri = jnp.concatenate([state.res_ids, member_ids])
    order = jnp.argsort(all_rd)[:k]
    res_d, res_ids = all_rd[order], all_ri[order]

    all_ci = jnp.concatenate([state.cand_ids, nbr_ids])
    all_cd = jnp.concatenate([state.cand_d, nbr_d])
    all_cv = jnp.concatenate(
        [state.cand_vis, jnp.zeros(nbr_ids.shape, bool)]
    )
    order = jnp.argsort(all_cd)[:beam]
    return state._replace(
        cand_ids=all_ci[order],
        cand_d=all_cd[order],
        cand_vis=all_cv[order],
        res_ids=res_ids,
        res_d=res_d,
        io=state.io + io_delta,
        cache_hits=state.cache_hits + hit_delta,
        hops=state.hops + 1,
    )


def _search_one(
    q: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
):
    disk_lut = pq_mod.pq_lut(q, data.disk_codebooks)  # (M_disk, ksub)
    mem_lut = pq_mod.pq_lut(q, data.mem_codebooks)    # (M_mem, ksub)
    state = init_state(q, data, disk_lut, beam=beam, k=k, entries=entries)

    def cond(state: BeamState):
        live = (
            (~state.cand_vis)
            & (state.cand_ids != PAD)
            & jnp.isfinite(state.cand_d)
        )
        return live.any() & (state.hops < max_hops)

    def body(state: BeamState):
        state, batch = select_batch(
            state, capacity=capacity, io_batch=io_batch
        )
        mids, md, io_delta, hit_delta = score_members(
            q, data, batch, capacity=capacity
        )
        nids, nd = score_neighbors(
            data, batch, state, disk_lut, mem_lut,
            capacity=capacity, mode=mode,
        )
        return merge(state, mids, md, nids, nd, io_delta, hit_delta)

    state = jax.lax.while_loop(cond, body, state)
    return state.res_ids, state.res_d, state.io, state.hops, state.cache_hits


def _batch_search_impl(
    queries: jnp.ndarray,
    data: SearchData,
    *,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
) -> SearchResult:
    fn = functools.partial(
        _search_one,
        data=data,
        capacity=capacity,
        beam=beam,
        io_batch=io_batch,
        k=k,
        max_hops=max_hops,
        entries=entries,
        mode=mode,
    )
    ids, dists, ios, hops, hits = jax.vmap(fn)(queries)
    return SearchResult(ids=ids, dists=dists, ios=ios, hops=hops, cache_hits=hits)


batch_search = jax.jit(
    _batch_search_impl,
    static_argnames=(
        "capacity", "beam", "io_batch", "k", "max_hops", "entries", "mode"
    ),
)
batch_search.__doc__ = """Search a batch of queries. queries: (Q, d)."""


# --------------------------------------------------------------------------
# mesh-sharded entry point: shard the query batch, replicate the index
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _shard_search_fn(
    mesh, capacity, beam, io_batch, k, max_hops, entries, mode
):
    """jitted shard_map: queries split over every mesh axis, data replicated.

    Cached per (mesh, static config) so repeated serving calls reuse the
    compiled executable.
    """
    axes = tuple(mesh.axis_names)
    local = functools.partial(
        _batch_search_impl,
        capacity=capacity,
        beam=beam,
        io_batch=io_batch,
        k=k,
        max_hops=max_hops,
        entries=entries,
        mode=mode,
    )
    data_spec = jax.tree.map(
        lambda _: P(), SearchData(*[0] * len(SearchData._fields))
    )
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes), data_spec),
        out_specs=P(axes),
    )
    return jax.jit(fn)


def shard_search(
    queries: jnp.ndarray,
    data: SearchData,
    *,
    mesh=None,
    capacity: int,
    beam: int,
    io_batch: int,
    k: int,
    max_hops: int,
    entries: int,
    mode: str,
) -> SearchResult:
    """``batch_search`` with the query batch sharded across a device mesh.

    The index (``data``) is replicated on every device; the (Q, d) query
    batch is split over all mesh axes — the paper's "query threads"
    throughput dimension mapped onto chips. Ragged batches are zero-padded
    to a multiple of the mesh size and trimmed from the result. On a
    1-device mesh this runs the exact ``_batch_search_impl`` trace, so ids
    and distances are bitwise identical to ``batch_search``. (Index
    sharding — partitioning the vectors themselves — is the orthogonal
    axis and lives in ``core.distributed``.)
    """
    if mesh is None:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
    fn = _shard_search_fn(
        mesh, capacity, beam, io_batch, k, max_hops, entries, mode
    )
    num_dev = 1
    for n in mesh.shape.values():
        num_dev *= n
    qn = queries.shape[0]
    pad = (-qn) % num_dev
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pad, queries.shape[1]), queries.dtype)]
        )
    res = fn(queries, data)
    if pad:
        res = jax.tree.map(lambda a: a[:qn], res)
    return res


def search_kwargs(cfg: PageANNConfig, capacity: int) -> dict:
    return dict(
        capacity=capacity,
        beam=cfg.beam_width,
        io_batch=cfg.io_batch,
        max_hops=cfg.max_hops,
        entries=cfg.lsh_entries,
        mode=cfg.memory_mode.value,
    )
