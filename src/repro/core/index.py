"""High-level PageANN index: build / search / stats (Fig. 3 pipeline).

Pre-processing stage: Vamana vector graph -> page-node grouping (Alg. 1) ->
PQ codebooks (coarse on-page + fine in-memory) -> id reassignment + page
packing (Sec 4.2/5) -> LSH routing index -> memory-disk coordination
(Sec 4.3) with optional warm-up page caching.

Query stage: ``search`` wraps ``core.search.batch_search`` and translates
results back to original vector ids; runtime knobs arrive per call as a
:class:`repro.core.config.SearchParams` (one compiled executable per
distinct value — sweeps never rebuild the index).

Lifecycle: ``save(dir)`` / ``load(dir)`` persist the index through
``core.persist`` (raw page-aligned ``pages.bin`` + numpy sidecars + JSON
manifest); loading round-trips to bit-identical search results.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import filter as filter_mod
from repro.core import layout as layout_mod
from repro.core import lsh as lsh_mod
from repro.core import page_graph as pg_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core import vamana as vamana_mod
from repro.core.config import (
    AdaptiveParams,
    FilterParams,
    MemoryMode,
    PageANNConfig,
    SearchParams,
    resolve_search_params,
)
from repro.core.filter import FilterExpr, MetaArrays, MetadataSchema

PAD = -1


@dataclasses.dataclass
class BuildStats:
    vamana_s: float
    grouping_s: float
    pq_s: float
    pack_s: float
    lsh_s: float
    pages: int
    capacity: int
    mean_page_degree: float
    logical_page_bytes: int
    padded_tile_bytes: int
    memory_bytes: int
    # total bytes of the disk tier. For a freshly built index this is the
    # projected pages.bin size (pages * padded_tile_bytes); for an index
    # loaded via memmap, persist.load_pageann overwrites it with the actual
    # on-disk size of the persisted artifact — stats reports what the file
    # occupies, not a recomputation from device arrays. Defaults to 0 for
    # manifests written before the field existed.
    disk_bytes: int = 0
    # resident/streamed split of the disk tier on device: how many page
    # records are pinned in device memory and their byte footprint. Equal
    # to pages/disk_bytes when fully resident; smaller under a
    # ``MemoryBudget`` load, where the remainder streams from the pages.bin
    # memmap per hop. Default 0 for manifests written before streaming.
    resident_pages: int = 0
    resident_bytes: int = 0


@dataclasses.dataclass
class PageANNIndex:
    cfg: PageANNConfig
    store: layout_mod.PageStore
    tier: layout_mod.MemoryTier
    lsh: lsh_mod.LSHIndex
    data: search_mod.SearchData
    stats: BuildStats
    # streaming page tier (set by a ``MemoryBudget`` load, None otherwise):
    # the host-side per-hop reader over the pages.bin memmap
    fetcher: object | None = None
    # full residency priority, hottest page first (warm_cache access
    # counts); persisted so a budgeted load pins the right pages
    page_order: np.ndarray | None = None
    memory_budget: object | None = None
    # autotuned operating points (``autotune``): measured
    # {params, recall, qps, p99_us, target} dicts, persisted in the
    # manifest's ``tuned`` section; ``tuned_default`` is the point serving
    # resolves as this index's default SearchParams
    tuned: list = dataclasses.field(default_factory=list)
    tuned_default: SearchParams | None = None
    # filtered search (``core.filter``): the declared metadata schema, the
    # tag vocabularies (field -> tuple of values; codes are positions),
    # slot-aligned device columns the page scan gathers masks from, and
    # the original-order host copy (selectivity probe / brute-force
    # oracle / compaction source). All None/empty without a schema.
    schema: MetadataSchema | None = None
    vocab: dict = dataclasses.field(default_factory=dict)
    meta: MetaArrays | None = None
    meta_host: MetaArrays | None = None
    # per-FilterExpr compiled form + measured selectivity (host cache —
    # compiling and probing once per distinct predicate, like the jit
    # executable the static arg keys)
    _filter_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(
        x: np.ndarray,
        cfg: PageANNConfig,
        mem_subspaces: int | None = None,
        warmup_queries: np.ndarray | None = None,
        schema: MetadataSchema | None = None,
        metadata=None,
    ) -> "PageANNIndex":
        x = np.ascontiguousarray(x, np.float32)
        n, d = x.shape
        assert d == cfg.dim

        t0 = time.perf_counter()
        nbrs = vamana_mod.build_vamana(
            x,
            degree=cfg.graph_degree,
            beam=cfg.build_beam,
            alpha=cfg.alpha,
            rounds=cfg.build_rounds,
            seed=cfg.seed,
        )
        t1 = time.perf_counter()

        capacity = cfg.resolve_capacity()
        grouping = pg_mod.group_pages(x, nbrs, capacity, cfg.hop_h)
        page_nbrs_old = pg_mod.derive_page_edges(x, nbrs, grouping, cfg.page_degree)
        t2 = time.perf_counter()

        # coarse codes travel on-page; fine codes live in the memory tier
        m_disk = cfg.pq_subspaces
        m_mem = mem_subspaces or min(d, 2 * m_disk)
        disk_books = pq_mod.train_pq(
            x, m_disk, cfg.pq_ksub, cfg.pq_iters, seed=cfg.seed
        )
        mem_books = pq_mod.train_pq(
            x, m_mem, cfg.pq_ksub, cfg.pq_iters, seed=cfg.seed + 1
        )
        disk_codes_old = np.asarray(
            pq_mod.pq_encode(jnp.asarray(x), jnp.asarray(disk_books))
        )
        t3 = time.perf_counter()

        store = layout_mod.pack_pages(x, grouping, page_nbrs_old, disk_codes_old, cfg)
        x_new = layout_mod.reassigned_vectors(x, store)
        mem_codes_new = np.asarray(
            pq_mod.pq_encode(jnp.asarray(x_new), jnp.asarray(mem_books))
        )
        t4 = time.perf_counter()

        lsh = lsh_mod.build_lsh(
            x_new,
            np.asarray(pq_mod.pq_encode(jnp.asarray(x_new), jnp.asarray(disk_books))),
            bits=cfg.lsh_bits,
            sample=cfg.lsh_sample,
            seed=cfg.seed,
        )
        t5 = time.perf_counter()

        tier = layout_mod.build_memory_tier(
            x_new, mem_codes_new, mem_books, disk_books, cfg.memory_mode
        )
        data = search_mod.make_search_data(store, tier, lsh)

        # metadata columns: encode in original-id order, scatter to page-
        # slot order alongside the member vectors
        if metadata is not None and schema is None:
            raise ValueError("metadata= requires a schema=")
        vocab: dict = {}
        meta = meta_host = None
        if schema is not None:
            columns = filter_mod.normalize_metadata(
                schema, metadata if metadata is not None else {}, n
            )
            vocab = filter_mod.build_vocab(schema, columns)
            meta_host = filter_mod.encode_metadata(schema, vocab, columns, n)
            slot_tags, slot_nums = layout_mod.reassign_metadata(
                meta_host.tags, meta_host.nums, store
            )
            meta = MetaArrays(
                tags=jnp.asarray(slot_tags), nums=jnp.asarray(slot_nums)
            )

        idx = PageANNIndex(
            cfg=cfg,
            store=store,
            tier=tier,
            lsh=lsh,
            data=data,
            stats=BuildStats(
                vamana_s=t1 - t0,
                grouping_s=t2 - t1,
                pq_s=t3 - t2,
                pack_s=t4 - t3,
                lsh_s=t5 - t4,
                pages=store.num_pages,
                capacity=capacity,
                mean_page_degree=pg_mod.page_graph_stats(
                    np.asarray(store.nbr_ids)
                )["mean_degree"],
                logical_page_bytes=store.logical_page_bytes(cfg),
                padded_tile_bytes=store.padded_tile_bytes(),
                memory_bytes=tier.memory_bytes + lsh.memory_bytes,
                disk_bytes=store.num_pages * store.padded_tile_bytes(),
                resident_pages=store.num_pages,
                resident_bytes=store.num_pages * store.padded_tile_bytes(),
            ),
            schema=schema,
            vocab=vocab,
            meta=meta,
            meta_host=meta_host,
        )
        if warmup_queries is not None and cfg.cache_pages > 0:
            idx.warm_cache(warmup_queries)
        return idx

    # ------------------------------------------------------------ properties
    @property
    def dim(self) -> int:
        return self.cfg.dim

    @property
    def default_params(self) -> SearchParams:
        """The runtime parameter set searches resolve when none is given:
        the autotuned operating point if one is stored (``autotune`` /
        the manifest's ``tuned.default``), else the build config's knobs."""
        if self.tuned_default is not None:
            return self.tuned_default
        return SearchParams.from_config(self.cfg)

    def resolve_params(
        self, k: int | None, params: SearchParams | None
    ) -> SearchParams:
        return resolve_search_params(self.default_params, k, params)

    # ------------------------------------------------------------------ cache
    def warm_cache(self, queries: np.ndarray, params: SearchParams | None = None) -> None:
        """Sec 4.3: run a warm-up batch, cache the hottest pages.

        Also records the FULL access ordering over all pages as
        ``page_order`` (accessed pages by descending count, then the never-
        accessed rest in id order) — the residency policy a budgeted
        ``load(..., memory_budget=...)`` pins pages by."""
        p = self.resolve_params(None, params)
        res = self._raw_search(jnp.asarray(queries, jnp.float32), p)
        pages = np.asarray(res.ids) // self.store.capacity
        pages = pages[np.asarray(res.ids) >= 0]
        uniq, counts = np.unique(pages, return_counts=True)
        by_heat = uniq[np.argsort(-counts)].astype(np.int32)
        hot = by_heat[: self.cfg.cache_pages]
        cold = np.setdiff1d(
            np.arange(self.store.num_pages, dtype=np.int32), by_heat
        )
        self.page_order = np.concatenate([by_heat, cold])
        self.tier = dataclasses.replace(
            self.tier, cached_pages=jnp.asarray(np.sort(hot).astype(np.int32))
        )
        self.data = search_mod.make_search_data(self.store, self.tier, self.lsh)

    # ----------------------------------------------------------------- search
    def _raw_search(
        self, q: jnp.ndarray, params: SearchParams, mesh=None,
        meta=None, cfilter=None,
    ) -> search_mod.SearchResult:
        if mesh is not None:
            if self.fetcher is not None:
                raise ValueError(
                    "sharded search over a streamed (memory-budgeted) index "
                    "is not supported: reload without memory_budget to "
                    "search across a mesh"
                )
            return search_mod.shard_search(
                q, self.data, params,
                mesh=mesh,
                capacity=self.store.capacity,
                mode=self.cfg.memory_mode.value,
                meta=meta, cfilter=cfilter,
            )
        if self.fetcher is not None:
            return search_mod.stream_search(
                q, self.data, params,
                capacity=self.store.capacity,
                mode=self.cfg.memory_mode.value,
                fetcher=self.fetcher,
                meta=meta, cfilter=cfilter,
            )
        return search_mod.batch_search(
            q, self.data, params,
            capacity=self.store.capacity,
            mode=self.cfg.memory_mode.value,
            meta=meta, cfilter=cfilter,
        )

    # ----------------------------------------------------------------- filter
    def compiled_filter(self, expr: FilterExpr):
        """Resolve a ``FilterExpr`` against this index's schema/vocab and
        measure its selectivity (fraction of live vectors passing) over
        the host metadata columns. Cached per expression — the compiled
        form keys one jit executable, the selectivity drives the beam
        oversampling. Returns (CompiledFilter, selectivity)."""
        cached = self._filter_cache.get(expr)
        if cached is not None:
            return cached
        cf = filter_mod.compile_filter(expr, self.schema, self.vocab)
        mask = filter_mod.filter_mask_np(
            cf, self.meta_host.tags, self.meta_host.nums
        )
        sel = float(mask.mean()) if mask.size else 0.0
        self._filter_cache[expr] = (cf, sel)
        return cf, sel

    @staticmethod
    def _filter_oversample(selectivity: float, cap: int) -> int:
        """Pow2 beam-widening factor for a predicate's selectivity: a
        filter passing 1/s of the corpus needs ~s× the frontier to
        surface as many passing candidates as the unfiltered search —
        bucketed to powers of two (bounded compiled shapes, like the
        tombstone oversampling) and clamped to ``cap``."""
        if selectivity <= 0.0:
            return cap
        need = 1.0 / selectivity
        b = 1
        while b < need and b < cap:
            b *= 2
        return min(b, cap)

    def metadata_by_original_id(self) -> dict[str, list] | None:
        """Decoded metadata columns in ORIGINAL id order (missing ->
        None) — what a compaction merges with the delta tier's fresh
        metadata before re-encoding under a new vocabulary. ``None``
        when the index has no schema."""
        if self.schema is None:
            return None
        return filter_mod.decode_metadata(
            self.schema, self.vocab, self.meta_host
        )

    def fetch_stats(self) -> dict:
        """Streaming-tier counters (``pages_fetched`` / ``fetch_hits`` /
        ``fetch_wall_s``); zeros when fully resident."""
        if self.fetcher is None:
            return dict(pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0)
        return self.fetcher.fetch_stats()

    def vectors_by_original_id(self) -> np.ndarray:
        """Member vectors in ORIGINAL id order: the inverse of the build's
        page packing/id reassignment, recovered from the page store (which
        holds the vectors verbatim as f32 — exact round trip). This is the
        dataset a compaction (``core.delta``) merges fresh inserts into."""
        flat = np.asarray(self.store.vecs).reshape(-1, self.store.dim)
        valid = self.store.new_to_old >= 0
        out = np.empty((self.store.num_vectors, self.store.dim), np.float32)
        out[self.store.new_to_old[valid]] = flat[valid]
        return out

    def translate_ids(self, ids: np.ndarray) -> np.ndarray:
        """Reassigned (page-packed) vector ids -> original ids, PAD kept."""
        ids = np.asarray(ids)
        valid = ids >= 0
        old = np.full_like(ids, PAD)
        old[valid] = self.store.new_to_old[ids[valid]]
        return old

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
        *,
        mesh=None,
        filter: FilterExpr | None = None,
        filter_params: FilterParams | None = None,
    ) -> search_mod.SearchResult:
        """Search; returns ORIGINAL vector ids.

        ``params`` supplies the runtime knobs (defaults come from the build
        config); ``k`` overrides ``params.k`` when given. Passing a device
        mesh routes through ``shard_search`` (query batch split across it).

        ``filter`` restricts results to vectors whose metadata satisfies
        the predicate (see ``core.filter``): the compiled filter masks
        non-passing members to ``+inf`` inside the page scan, and the
        beam is widened by a pow2 factor of the predicate's measured
        selectivity (bounded by
        ``filter_params.max_filter_oversample``) so recall matches a
        post-filter brute force. ``filter=None`` compiles and runs the
        exact pre-filter program.
        """
        p = self.resolve_params(k, params)
        meta = cfilter = None
        if filter is not None:
            fp = filter_params if filter_params is not None else FilterParams()
            cfilter, sel = self.compiled_filter(filter)
            factor = self._filter_oversample(sel, fp.max_filter_oversample)
            if factor > 1:
                p = p.replace(beam_width=p.beam_width * factor)
            meta = self.meta
        res = self._raw_search(
            jnp.asarray(queries, jnp.float32), p, mesh=mesh,
            meta=meta, cfilter=cfilter,
        )
        return search_mod.SearchResult(
            ids=self.translate_ids(res.ids),
            dists=np.asarray(res.dists),
            ios=np.asarray(res.ios),
            hops=np.asarray(res.hops),
            cache_hits=np.asarray(res.cache_hits),
        )

    def profile(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
        *,
        filter: FilterExpr | None = None,
        filter_params: FilterParams | None = None,
        save: str | None = None,
    ) -> tuple[search_mod.SearchResult, search_mod.HopProfile]:
        """``search`` with the per-hop trail captured (opt-in debug mode).

        Runs ``core.search.profile_search`` — the same hop transitions,
        traced as a separate scan program — and returns the translated
        ``SearchResult`` plus a :class:`repro.core.search.HopProfile`
        holding, per query per hop: the scheduled frontier page ids, the
        disk-IO / cache-hit deltas, the shrinking worst-of-top-k frontier
        and the adaptive stall counter. Calling this never perturbs the
        compiled fast path: ``search`` keeps its own executables and its
        results stay bit-identical whether or not profiling ever ran.

        ``save=`` writes the profile as JSON readable by
        ``python -m repro.obs.report``. Not supported over a streamed
        (memory-budgeted) index — reload without ``memory_budget``.
        """
        if self.fetcher is not None:
            raise ValueError(
                "profile() over a streamed (memory-budgeted) index is not "
                "supported: reload without memory_budget to profile"
            )
        p = self.resolve_params(k, params)
        meta = cfilter = None
        if filter is not None:
            fp = filter_params if filter_params is not None else FilterParams()
            cfilter, sel = self.compiled_filter(filter)
            factor = self._filter_oversample(sel, fp.max_filter_oversample)
            if factor > 1:
                p = p.replace(beam_width=p.beam_width * factor)
            meta = self.meta
        res, trail = search_mod.profile_search(
            jnp.asarray(queries, jnp.float32), self.data, p,
            capacity=self.store.capacity,
            mode=self.cfg.memory_mode.value,
            meta=meta, cfilter=cfilter,
        )
        res = search_mod.SearchResult(
            ids=self.translate_ids(np.asarray(res.ids)),
            dists=np.asarray(res.dists),
            ios=np.asarray(res.ios),
            hops=np.asarray(res.hops),
            cache_hits=np.asarray(res.cache_hits),
        )
        trail = search_mod.HopProfile(*(np.asarray(a) for a in trail))
        if save is not None:
            import json

            from repro.obs.report import profile_to_dict

            with open(save, "w") as f:
                json.dump(profile_to_dict(res, trail), f)
        return res, trail

    # -------------------------------------------------------------- autotune
    def _measure(
        self, queries: jnp.ndarray, params: SearchParams, truth: np.ndarray
    ) -> dict:
        """One operating point: recall + timed wall clock over the batch.

        The first call per distinct ``params`` compiles (SearchParams is a
        static jit arg); timing reruns the compiled executable. p99 latency
        is estimated from the hop distribution — per-query cost is hop-
        dominated (each hop is one batched page-record read), so
        ``mean_us * p99_hops / mean_hops`` prices the straggler lanes
        without needing per-query timers inside one vmapped batch."""
        res = self._raw_search(queries, params)          # compile + warm
        jnp.asarray(res.ids).block_until_ready()
        t0 = time.perf_counter()
        res = self._raw_search(queries, params)
        jnp.asarray(res.ids).block_until_ready()
        wall = time.perf_counter() - t0
        found = self.translate_ids(np.asarray(res.ids))
        recall = recall_at_k(found[:, : truth.shape[1]], truth)
        hops = np.asarray(res.hops)
        mean_us = wall / queries.shape[0] * 1e6
        mean_hops = float(hops.mean())
        p99_scale = (
            float(np.percentile(hops, 99)) / mean_hops if mean_hops else 1.0
        )
        return dict(
            params=params,
            recall=float(recall),
            qps=queries.shape[0] / wall if wall > 0 else float("inf"),
            mean_us=mean_us,
            p99_us=mean_us * p99_scale,
            mean_hops=mean_hops,
            mean_ios=float(np.asarray(res.ios).mean()),
        )

    def autotune(
        self,
        queries: np.ndarray,
        *,
        recall_target: float | None = None,
        p99_target_us: float | None = None,
        k: int = 10,
        truth: np.ndarray | None = None,
        beam_grid: tuple | None = None,
        patience_grid: tuple = (None, 2, 4),
        io_batch_grid: tuple | None = None,
        entries_grid: tuple | None = None,
        store: bool = True,
    ) -> dict:
        """Find the cheapest operating point meeting a recall (or p99
        latency) target over THIS loaded index — no rebuilds, one compiled
        executable per probed ``SearchParams`` (cheap since PR 3).

        Recall mode: recall is monotone in beam width, so binary-search the
        beam ladder for the cheapest rung meeting ``recall_target``, then
        refine around it with the adaptive knobs (early-termination
        patience, io_batch, entry count/slack) and keep the highest-QPS
        variant still meeting the target. Latency mode
        (``p99_target_us``): highest-recall measured point within budget.

        The winner is appended to ``self.tuned`` and becomes
        ``default_params`` (``store=True``); ``save`` round-trips it
        through the manifest's ``tuned`` section so
        ``load_index(...).search(q)`` and ``--recall-target`` serving run
        it with zero per-process retuning. Returns the winning measurement
        dict (params/recall/qps/p99_us/...).
        """
        if (recall_target is None) == (p99_target_us is None):
            raise ValueError(
                "autotune needs exactly one of recall_target= or "
                "p99_target_us="
            )
        q = jnp.asarray(queries, jnp.float32)
        if truth is None:
            truth = vamana_mod.brute_force_knn(
                self.vectors_by_original_id(), np.asarray(queries), k
            )
        truth = np.asarray(truth)[:, :k]

        base = SearchParams.from_config(self.cfg, k=k)
        t = base.lsh_entries
        if beam_grid is None:
            bw = base.beam_width
            beam_grid = tuple(sorted({max(t, bw // 4), max(t, bw // 2),
                                      bw, 2 * bw}))
        beam_grid = tuple(sorted(beam_grid))
        measured: list[dict] = []

        def probe(p: SearchParams) -> dict:
            m = self._measure(q, p, truth)
            measured.append(m)
            return m

        if recall_target is not None:
            # binary search the beam ladder: cheapest rung >= target
            lo, hi = 0, len(beam_grid) - 1
            best_rung = None
            while lo <= hi:
                mid = (lo + hi) // 2
                m = probe(base.replace(beam_width=beam_grid[mid]))
                if m["recall"] >= recall_target:
                    best_rung = m
                    hi = mid - 1
                else:
                    lo = mid + 1
            if best_rung is None:       # even the widest rung missed
                best_rung = max(measured, key=lambda m: m["recall"])
            # refine at the chosen rung: adaptive + cheaper-I/O variants
            rung = best_rung["params"]
            variants: list[SearchParams] = []
            for pat in patience_grid:
                if pat is not None:
                    variants.append(rung.replace(
                        adaptive=AdaptiveParams(patience=pat)))
            for iob in (io_batch_grid or ()):
                if iob != rung.io_batch:
                    variants.append(rung.replace(io_batch=iob))
            for ent in (entries_grid or ()):
                if ent != rung.lsh_entries and ent <= rung.beam_width:
                    variants.append(rung.replace(lsh_entries=ent))
            for v in variants:
                probe(v)
            ok = [m for m in measured if m["recall"] >= recall_target]
            pool = ok or [max(measured, key=lambda m: m["recall"])]
            winner = max(pool, key=lambda m: m["qps"])
            target = {"recall": recall_target}
        else:
            for b in beam_grid:
                probe(base.replace(beam_width=b))
                for pat in patience_grid:
                    if pat is not None:
                        probe(base.replace(
                            beam_width=b,
                            adaptive=AdaptiveParams(patience=pat)))
            ok = [m for m in measured if m["p99_us"] <= p99_target_us]
            pool = ok or [min(measured, key=lambda m: m["p99_us"])]
            winner = max(pool, key=lambda m: m["recall"])
            target = {"p99_us": p99_target_us}

        winner = dict(winner, target=target)
        if store:
            self.tuned.append(winner)
            self.tuned_default = winner["params"]
        return winner

    def params_for_target(
        self,
        recall_target: float | None = None,
        p99_target_us: float | None = None,
    ) -> SearchParams:
        """Resolve a stored tuned operating point for a serving target.

        Picks among points recorded by ``autotune`` (round-tripped through
        the manifest): for a recall target, the highest-QPS point whose
        measured recall meets it; for a latency target, the highest-recall
        point within budget. Raises ``LookupError`` when nothing stored
        qualifies — serving surfaces that as "autotune this index first"."""
        if (recall_target is None) == (p99_target_us is None):
            raise ValueError(
                "need exactly one of recall_target= or p99_target_us="
            )
        if recall_target is not None:
            ok = [m for m in self.tuned if m["recall"] >= recall_target]
            if not ok:
                raise LookupError(
                    f"no tuned operating point reaches recall "
                    f"{recall_target}: run autotune(queries, recall_target="
                    f"{recall_target}) on this index and save it"
                )
            return max(ok, key=lambda m: m["qps"])["params"]
        ok = [m for m in self.tuned if m["p99_us"] <= p99_target_us]
        if not ok:
            raise LookupError(
                f"no tuned operating point meets p99 <= {p99_target_us}us: "
                f"run autotune(queries, p99_target_us={p99_target_us}) on "
                "this index and save it"
            )
        return max(ok, key=lambda m: m["recall"])["params"]

    # -------------------------------------------------------------- lifecycle
    def save(self, directory: str) -> None:
        """Persist to ``directory``: page-aligned ``pages.bin`` (the paper's
        disk layout, memmap-readable) + numpy sidecars + JSON manifest."""
        from repro.core import persist

        persist.save_pageann(self, directory)

    @classmethod
    def load(cls, directory: str, *, memory_budget=None) -> "PageANNIndex":
        """Reload a saved index; searches are bit-identical to the original.

        ``memory_budget`` (``repro.core.MemoryBudget`` | bytes | fraction |
        spec string | None) caps the device-resident page-record region;
        pages beyond it stream from the ``pages.bin`` memmap per hop with
        no change to search results. ``None`` = fully resident."""
        from repro.core import persist

        return persist.load_pageann(directory, memory_budget=memory_budget)


def recall_at_k(found_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    """Mean recall@k over a query batch (paper's Recall@10 metric).

    Set semantics per row (duplicates counted once on both sides, PAD ids
    included verbatim — identical to the former per-query
    ``len(set & set)`` loop), vectorized as one broadcast comparison:
    a truth entry scores iff it appears anywhere in the found row and is
    the first occurrence of its value within the truth row.
    """
    found = np.asarray(found_ids)
    truth = np.asarray(truth_ids)
    q, k = truth.shape
    present = (truth[:, :, None] == found[:, None, :]).any(-1)     # (Q, k)
    j = np.arange(k)
    dup = ((truth[:, :, None] == truth[:, None, :])
           & (j[None, None, :] < j[None, :, None])).any(-1)        # (Q, k)
    return float((present & ~dup).sum() / (q * k))
