"""Page-aligned data layout (paper Sec 4.2, Fig. 5) + vector id reassignment
(Sec 5, "Vector ID reassignment and data layout").

Each page record holds:  [member vectors | external neighbor vector ids |
compressed (PQ) vectors of those neighbors | counts].  Vector ids are
reassigned so that   page_id(v) = v // capacity   and   slot(v) = v % capacity
— ``calculate_pageID`` in Alg. 2 becomes a shift, no mapping table needed on
the search path.

TPU adaptation (DESIGN.md §2): the record is padded to (8, 128)-aligned f32
lanes so one page == one aligned HBM→VMEM DMA burst; the *logical* byte
accounting below still follows the paper's 4 KB equation and drives the
read-amplification benchmark.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import pq as pq_mod
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.page_graph import PAD, PageGrouping


# record geometry is owned by kernels.record_layout (the kernel and its
# oracle read the same tile this module packs); re-exported for callers
from repro.kernels.record_layout import (  # noqa: F401  (re-exports)
    PAGE_LANES,
    member_rows,
    record_rows,
    rows_per_vector,
    vectors_per_row,
)


@dataclasses.dataclass
class PageStore:
    """The 'disk tier': page records as one big gather-addressable array set.

    ``recs`` is the physical page record the search path reads — members,
    neighbor codes, and counts packed into one (rows, 128)-lane f32 tile per
    page (``pack_page_records``) so a hop's scored page payload is a single
    aligned DMA through ``kernels.ops.page_scan`` (neighbor *ids* and the
    count vectors ride as small int side arrays in ``SearchData``). The
    unpacked ``vecs`` / ``nbr_codes`` views are host-side numpy for
    build-time tooling and test oracles only — they never reach device
    memory, so HBM holds one copy of the disk tier.
    """

    vecs: np.ndarray         # (P, capacity, d) f32 — member vectors (host)
    member_count: jnp.ndarray  # (P,) int32
    nbr_ids: jnp.ndarray     # (P, R_p) int32, REASSIGNED vector ids, PAD=-1
    nbr_codes: np.ndarray    # (P, R_p, M_disk) uint8 — unpacked codes (host)
    nbr_count: jnp.ndarray   # (P,) int32
    recs: jnp.ndarray        # (R, rows, 128) f32 — packed page records on
                             # device; R == P fully resident, R < P streamed
    capacity: int
    dim: int
    # id reassignment maps (host-side numpy; not used on the search path)
    new_to_old: np.ndarray   # (N,)
    old_to_new: np.ndarray   # (N,)
    # streaming tier (None => fully resident, ``recs`` holds every page):
    # resident_map[p] is the row of ``recs`` holding page p, or -1 if page p
    # is served from the host memmap (``recs_host``) per hop
    resident_map: jnp.ndarray | None = None   # (P,) int32
    recs_host: np.ndarray | None = None       # (P, rows, 128) f32 memmap

    @property
    def num_pages(self) -> int:
        return int(self.vecs.shape[0])

    @property
    def resident_pages(self) -> int:
        """Pages pinned on device (== num_pages when fully resident)."""
        return int(self.recs.shape[0])

    @property
    def resident_bytes(self) -> int:
        """Device footprint of the pinned page-record region."""
        return int(self.recs.shape[0]) * self.padded_tile_bytes()

    @property
    def num_vectors(self) -> int:
        """Real (non-pad) vectors in the store; ``new_to_old`` is longer —
        it has a row per page *slot*, PAD where a slot is empty."""
        return int(self.old_to_new.shape[0])

    def logical_page_bytes(self, cfg: PageANNConfig) -> int:
        """Bytes per page under the paper's Sec 4.2 equation (pre-padding)."""
        n_cv = self.nbr_codes.shape[1] if cfg.memory_mode != MemoryMode.MEM_ALL else 0
        if cfg.memory_mode == MemoryMode.HYBRID:
            n_cv //= 2
        return int(
            2 * 4
            + self.capacity * self.dim * cfg.dtype_bytes
            + self.nbr_ids.shape[1] * cfg.id_bytes
            + n_cv * self.nbr_codes.shape[2]
        )

    def padded_tile_bytes(self) -> int:
        """Bytes per page of the packed record actually DMA'd per hop."""
        return int(self.recs.shape[1] * self.recs.shape[2] * 4)


def reassign_ids(grouping: PageGrouping) -> tuple[np.ndarray, np.ndarray]:
    """new_id = page * capacity + slot. Returns (new_to_old, old_to_new)."""
    pages = grouping.pages
    p, cap = pages.shape
    n = int((pages != PAD).sum())
    new_to_old = np.full(p * cap, PAD, np.int64)
    flat = pages.ravel()
    valid = flat != PAD
    new_to_old[valid] = flat[valid]
    old_to_new = np.full(n, PAD, np.int64)
    old_to_new[flat[valid]] = np.nonzero(valid)[0]
    return new_to_old, old_to_new


def pack_page_records(vecs: np.ndarray, nbr_codes: np.ndarray) -> np.ndarray:
    """Pack per-page arrays into one (P, rows, 128) f32 record tile.

    Mirrors the paper's on-page layout (Fig. 5) in TPU lane geometry so
    that ``kernels.ops.page_scan`` reads a hop's entire *scored* payload —
    member vectors and neighbor PQ codes — in ONE aligned DMA per page.
    (Member/neighbor counts and neighbor ids are per-page scalars/small int
    vectors; they ride ``SearchData`` side arrays rather than wasting f32
    record lanes nothing on the scoring path would read.)

    Member block, with ``vpr = 128 // d`` vectors per row for d <= 128 and
    ``rpv = ceil(d / 128)`` rows per vector for d > 128:

      rows [0, Rv)       member vectors: vector i at row i // vpr, cols
                         [(i % vpr)*d, (i % vpr + 1)*d)  (d <= 128, dense —
                         a d=32 page wastes no lanes instead of 3/4), or
                         spanning rows [i*rpv, (i+1)*rpv) with the tail row
                         zero-padded (d > 128); Rv = member_rows(cap, d)
      rows [Rv, Rv+M)    neighbor PQ codes, subspace-major (row Rv+j holds
                         code j of neighbors 0..Rp-1 in cols [0, Rp)) — the
                         transpose keeps the kernel's per-subspace one-hot
                         contraction free of in-kernel transposes
      rows padded up to a multiple of 8 ((8, 128) f32 tile alignment)

    Unused lanes are zero; consumers mask via the side-array counts.
    """
    p, cap, d = vecs.shape
    rp, m = nbr_codes.shape[1:]
    if rp > PAGE_LANES:
        raise ValueError(
            f"packed page record needs page_degree<={PAGE_LANES}, got Rp={rp}"
        )
    mrows = member_rows(cap, d)
    rows = record_rows(cap, d, m)
    rec = np.zeros((p, rows, PAGE_LANES), np.float32)
    if d <= PAGE_LANES:
        vpr = vectors_per_row(d)
        padded = np.zeros((p, mrows * vpr, d), np.float32)
        padded[:, :cap] = vecs
        rec[:, :mrows, : vpr * d] = padded.reshape(p, mrows, vpr * d)
    else:
        rpv = rows_per_vector(d)
        padded = np.zeros((p, cap, rpv * PAGE_LANES), np.float32)
        padded[:, :, :d] = vecs
        rec[:, :mrows, :] = padded.reshape(p, mrows, PAGE_LANES)
    rec[:, mrows:mrows + m, :rp] = nbr_codes.transpose(0, 2, 1)
    return rec


def unpack_member_vectors(
    recs: np.ndarray, capacity: int, dim: int
) -> np.ndarray:
    """Inverse of ``pack_page_records`` for the member block: (P, cap, d).

    The packed record stores member vectors as verbatim f32 lanes, so the
    round trip is bit-exact — ``PageANNIndex.load`` rebuilds the host-side
    ``PageStore.vecs`` view from the memmapped page file instead of
    persisting the vectors twice.
    """
    recs = np.asarray(recs, np.float32)
    p = recs.shape[0]
    mrows = member_rows(capacity, dim)
    if dim <= PAGE_LANES:
        vpr = vectors_per_row(dim)
        flat = recs[:, :mrows, : vpr * dim].reshape(p, mrows * vpr, dim)
        return np.ascontiguousarray(flat[:, :capacity])
    rpv = rows_per_vector(dim)
    flat = recs[:, :mrows].reshape(p, capacity, rpv * PAGE_LANES)
    return np.ascontiguousarray(flat[:, :, :dim])


def unpack_neighbor_codes(
    recs: np.ndarray, capacity: int, dim: int, rp: int, m: int
) -> np.ndarray:
    """Inverse of ``pack_page_records`` for the code block: (P, Rp, M) u8.

    Code lanes hold the uint8 values verbatim as f32 (0..255 are exact), so
    like ``unpack_member_vectors`` this lets persistence keep one copy of
    the disk tier — only valid when the record carries code rows (i.e. not
    MEM_ALL, whose records drop them)."""
    recs = np.asarray(recs, np.float32)
    mrows = member_rows(capacity, dim)
    block = recs[:, mrows:mrows + m, :rp]               # (P, M, Rp)
    return np.ascontiguousarray(block.transpose(0, 2, 1).astype(np.uint8))


def pack_pages(
    x: np.ndarray,
    grouping: PageGrouping,
    page_nbrs_old: np.ndarray,
    disk_codes_old: np.ndarray,
    cfg: PageANNConfig,
) -> PageStore:
    """Assemble the page-record arrays in the reassigned id space.

    x: (N, d) original vectors (original id space).
    page_nbrs_old: (P, R_p) external neighbor *original* vector ids.
    disk_codes_old: (N, M_disk) on-page PQ codes, original id order.
    """
    pages = grouping.pages
    p, cap = pages.shape
    d = x.shape[1]
    new_to_old, old_to_new = reassign_ids(grouping)

    vecs = np.zeros((p, cap, d), np.float32)
    member_count = (pages != PAD).sum(1).astype(np.int32)
    flat = pages.ravel()
    valid = flat != PAD
    vecs.reshape(p * cap, d)[valid] = x[flat[valid]]

    nbr_valid = page_nbrs_old != PAD
    nbr_ids = np.full_like(page_nbrs_old, PAD)
    nbr_ids[nbr_valid] = old_to_new[page_nbrs_old[nbr_valid]]
    nbr_count = nbr_valid.sum(1).astype(np.int32)

    m_disk = disk_codes_old.shape[1]
    nbr_codes = np.zeros((*page_nbrs_old.shape, m_disk), np.uint8)
    nbr_codes[nbr_valid] = disk_codes_old[page_nbrs_old[nbr_valid]]

    # MEM_ALL keeps every compressed vector in the memory tier (Sec 4.3(3));
    # the search never ADC-scores on-page codes (compute_adc=False), so the
    # physical record drops the code rows — no dead DMA bytes per hop
    rec_codes = (
        nbr_codes[:, :, :0]
        if cfg.memory_mode == MemoryMode.MEM_ALL
        else nbr_codes
    )

    return PageStore(
        vecs=vecs,
        member_count=jnp.asarray(member_count),
        nbr_ids=jnp.asarray(nbr_ids.astype(np.int32)),
        nbr_codes=nbr_codes,
        nbr_count=jnp.asarray(nbr_count),
        recs=jnp.asarray(pack_page_records(vecs, rec_codes)),
        capacity=cap,
        dim=d,
        new_to_old=new_to_old,
        old_to_new=old_to_new,
    )


@dataclasses.dataclass
class MemoryTier:
    """The 'host memory' tier (Sec 4.3): always-resident arrays.

    mem_codes are the *high-accuracy* PQ codes (more subspaces than the
    on-page codes) for vectors cached in memory; mem_mask marks which
    reassigned vector ids are covered (all of them in MEM_ALL mode).
    """

    mem_codes: jnp.ndarray      # (N_pad, M_mem) uint8, reassigned order
    mem_mask: jnp.ndarray       # (N_pad,) bool
    mem_codebooks: jnp.ndarray  # (M_mem, ksub, dsub)
    disk_codebooks: jnp.ndarray  # (M_disk, ksub, dsub)
    cached_pages: jnp.ndarray   # (C,) int32 sorted page ids ('warmed' cache)

    @property
    def memory_bytes(self) -> int:
        covered = int(np.asarray(self.mem_mask).sum())
        return covered * self.mem_codes.shape[1] + self.mem_codebooks.size * 4


def build_memory_tier(
    x_new: np.ndarray,
    mem_codes: np.ndarray,
    mem_codebooks: np.ndarray,
    disk_codebooks: np.ndarray,
    mode: MemoryMode,
    hybrid_fraction: float = 0.5,
    cached_pages: np.ndarray | None = None,
    hot_ids: np.ndarray | None = None,
) -> MemoryTier:
    """x_new / mem_codes are in reassigned order, padded to P*cap rows."""
    n_pad = mem_codes.shape[0]
    if mode == MemoryMode.MEM_ALL:
        mask = np.ones(n_pad, bool)
    elif mode == MemoryMode.DISK_ONLY:
        mask = np.zeros(n_pad, bool)
    else:
        mask = np.zeros(n_pad, bool)
        k = int(n_pad * hybrid_fraction)
        if hot_ids is not None:
            mask[hot_ids[:k]] = True
        else:
            mask[:k] = True
    if cached_pages is None:
        cached_pages = np.empty((0,), np.int32)
    return MemoryTier(
        mem_codes=jnp.asarray(mem_codes),
        mem_mask=jnp.asarray(mask),
        mem_codebooks=jnp.asarray(mem_codebooks),
        disk_codebooks=jnp.asarray(disk_codebooks),
        cached_pages=jnp.asarray(np.sort(cached_pages).astype(np.int32)),
    )


def reassigned_vectors(x: np.ndarray, store: PageStore) -> np.ndarray:
    """Vectors in reassigned order, zero rows for padded slots: (P*cap, d)."""
    return np.asarray(store.vecs).reshape(-1, store.dim)


def reassigned_codes(
    x: np.ndarray, store: PageStore, codebooks: np.ndarray
) -> np.ndarray:
    """PQ-encode all vectors in reassigned order (padded slots encode 0)."""
    xr = reassigned_vectors(x, store)
    return np.asarray(pq_mod.pq_encode(jnp.asarray(xr), jnp.asarray(codebooks)))


def reassign_metadata(tags: np.ndarray, nums: np.ndarray, store: PageStore):
    """Scatter original-id metadata columns into page-slot order.

    tags: (N, T) int32 codes, nums: (N, Nn) f32 — original id order (as
    produced by ``filter.encode_metadata``). Returns the (P*cap, T) /
    (P*cap, Nn) slot-aligned arrays the filtered page scan gathers from:
    row ``page * capacity + slot`` holds the metadata of the vector the
    page layout placed there, so a page's metadata is one contiguous
    slice — the same ``new_to_old`` scatter the member vectors use. Pad
    slots keep the missing sentinels (-1 tag code / NaN numeric), which
    match no filter clause.
    """
    n2o = store.new_to_old
    rows = n2o.shape[0]
    out_tags = np.full((rows, tags.shape[1]), -1, np.int32)
    out_nums = np.full((rows, nums.shape[1]), np.nan, np.float32)
    valid = n2o != PAD
    out_tags[valid] = tags[n2o[valid]]
    out_nums[valid] = nums[n2o[valid]]
    return out_tags, out_nums


def unreassign_metadata(
    slot_tags: np.ndarray, slot_nums: np.ndarray, store: PageStore
):
    """Inverse of :func:`reassign_metadata`: slot-aligned columns back to
    original-id order (what ``load`` rebuilds the host copy from)."""
    n2o = store.new_to_old
    n = store.num_vectors
    tags = np.full((n, slot_tags.shape[1]), -1, np.int32)
    nums = np.full((n, slot_nums.shape[1]), np.nan, np.float32)
    valid = n2o != PAD
    tags[n2o[valid]] = slot_tags[valid]
    nums[n2o[valid]] = slot_nums[valid]
    return tags, nums
