"""Vamana graph construction (DiskANN's graph; the substrate of Alg. 1).

Build is offline pre-processing in the paper. Here it is a numpy/JAX hybrid:
greedy beam searches are batched and jitted (the compute hot spot), robust
pruning and reverse-edge insertion run sequentially on host (cheap, pointer
chasing). The resulting fixed-degree adjacency (N, R) int32 array, padded
with -1, feeds the page-node grouping in ``page_graph.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

PAD = -1


def l2_sq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Squared L2 distance matrix between rows of a and rows of b."""
    return (
        (a * a).sum(-1)[:, None]
        - 2.0 * a @ b.T
        + (b * b).sum(-1)[None, :]
    )


def medoid(x: np.ndarray) -> int:
    """Point closest to the dataset mean (the fixed search entry point)."""
    mean = x.mean(axis=0, keepdims=True)
    return int(np.argmin(l2_sq(mean, x)[0]))


@functools.partial(jax.jit, static_argnames=("beam", "iters"))
def _greedy_search_batch(x, nbrs, queries, entry, *, beam, iters):
    """Batched greedy beam search over a fixed-degree vector graph.

    Returns for every query the visited/expanded node ids and their exact
    distances (the candidate pool Vamana prunes from). Fixed shapes:
    ids (Q, beam + iters*R), dists likewise; unexpanded slots are PAD/inf.
    """
    n, r = nbrs.shape

    def one(q):
        # beam state: ascending by distance; expanded flags
        ids0 = jnp.full((beam,), PAD, jnp.int32).at[0].set(entry)
        d0 = jnp.full((beam,), jnp.inf, jnp.float32).at[0].set(
            jnp.sum((x[entry] - q) ** 2)
        )
        exp0 = jnp.zeros((beam,), bool)
        trail_ids0 = jnp.full((iters * r,), PAD, jnp.int32)
        trail_d0 = jnp.full((iters * r,), jnp.inf, jnp.float32)

        def body(i, state):
            ids, d, exp, t_ids, t_d = state
            # best unexpanded beam slot
            masked = jnp.where(exp | (ids == PAD), jnp.inf, d)
            slot = jnp.argmin(masked)
            done = jnp.isinf(masked[slot])
            cur = ids[slot]
            exp = exp.at[slot].set(True)
            cand = nbrs[jnp.maximum(cur, 0)]                  # (R,)
            cand = jnp.where(done, PAD, cand)
            cd = jnp.sum((x[jnp.maximum(cand, 0)] - q) ** 2, axis=-1)
            cd = jnp.where(cand == PAD, jnp.inf, cd)
            # drop candidates already in beam
            dup = (cand[:, None] == ids[None, :]).any(axis=1)
            cd = jnp.where(dup, jnp.inf, cd)
            t_ids = jax.lax.dynamic_update_slice(t_ids, cand, (i * r,))
            t_d = jax.lax.dynamic_update_slice(t_d, cd, (i * r,))
            # merge candidates into beam
            all_ids = jnp.concatenate([ids, cand])
            all_d = jnp.concatenate([d, cd])
            all_exp = jnp.concatenate([exp, jnp.zeros((r,), bool)])
            order = jnp.argsort(all_d)[:beam]
            return (all_ids[order], all_d[order], all_exp[order], t_ids, t_d)

        ids, d, _, t_ids, t_d = jax.lax.fori_loop(
            0, iters, body, (ids0, d0, exp0, trail_ids0, trail_d0)
        )
        return jnp.concatenate([ids, t_ids]), jnp.concatenate([d, t_d])

    return jax.vmap(one)(queries)


def robust_prune(
    point: int,
    cand_ids: np.ndarray,
    cand_d: np.ndarray,
    x: np.ndarray,
    degree: int,
    alpha: float,
) -> np.ndarray:
    """DiskANN robust prune: keep diverse close neighbors."""
    keep_mask = (cand_ids != PAD) & (cand_ids != point) & np.isfinite(cand_d)
    ids, d = cand_ids[keep_mask], cand_d[keep_mask]
    ids, first = np.unique(ids, return_index=True)
    d = d[first]
    order = np.argsort(d)
    ids, d = ids[order], d[order]
    out: list[int] = []
    alive = np.ones(len(ids), bool)
    for i in range(len(ids)):
        if not alive[i]:
            continue
        p = ids[i]
        out.append(int(p))
        if len(out) >= degree:
            break
        # kill candidates closer (x alpha) to p than to the point
        rest = alive & (np.arange(len(ids)) > i)
        if rest.any():
            rid = ids[rest]
            d_pc = ((x[rid] - x[p]) ** 2).sum(-1)
            alive[rest] &= ~(alpha * d_pc <= d[rest])
    res = np.full((degree,), PAD, np.int32)
    res[: len(out)] = out
    return res


def build_vamana(
    x: np.ndarray,
    degree: int = 32,
    beam: int = 64,
    alpha: float = 1.2,
    rounds: int = 2,
    batch: int = 256,
    seed: int = 0,
) -> np.ndarray:
    """Build a Vamana graph; returns (N, degree) int32 adjacency, PAD-padded."""
    x = np.asarray(x, np.float32)
    n = len(x)
    rng = np.random.default_rng(seed)
    degree = min(degree, n - 1)
    # random regular init
    nbrs = np.full((n, degree), PAD, np.int32)
    for i in range(n):
        c = rng.choice(n - 1, size=min(degree, n - 1), replace=False)
        c[c >= i] += 1
        nbrs[i, : len(c)] = c
    start = medoid(x)
    iters = max(8, beam // 2)

    for rnd in range(rounds):
        a = 1.0 if rnd < rounds - 1 else alpha
        order = rng.permutation(n)
        for lo in range(0, n, batch):
            pts = order[lo : lo + batch]
            jx = jnp.asarray(x)
            jn = jnp.asarray(nbrs)
            cand_ids, cand_d = _greedy_search_batch(
                jx, jn, jnp.asarray(x[pts]), start, beam=beam, iters=iters
            )
            cand_ids = np.asarray(cand_ids)
            cand_d = np.asarray(cand_d)
            for j, p in enumerate(pts):
                p = int(p)
                # prune candidate pool + current neighbors into new adjacency
                pool_ids = np.concatenate([cand_ids[j], nbrs[p]])
                cur = nbrs[p][nbrs[p] != PAD]
                pool_d = np.concatenate(
                    [cand_d[j], ((x[cur] - x[p]) ** 2).sum(-1)]
                    if len(cur)
                    else [cand_d[j], np.zeros((degree - len(cur),)) + np.inf]
                )
                if len(pool_d) < len(pool_ids):
                    pool_d = np.concatenate(
                        [pool_d, np.full(len(pool_ids) - len(pool_d), np.inf)]
                    )
                nbrs[p] = robust_prune(p, pool_ids, pool_d, x, degree, a)
                # reverse edges
                for u in nbrs[p]:
                    if u == PAD:
                        continue
                    row = nbrs[u]
                    if p in row:
                        continue
                    free = np.where(row == PAD)[0]
                    if len(free):
                        nbrs[u, free[0]] = p
                    else:
                        pool = np.concatenate([row, [p]]).astype(np.int32)
                        pd = ((x[pool] - x[u]) ** 2).sum(-1)
                        nbrs[u] = robust_prune(int(u), pool, pd, x, degree, a)
    return nbrs


def brute_force_knn(x: np.ndarray, q: np.ndarray, k: int) -> np.ndarray:
    """Exact kNN ids (ground truth for recall@k)."""
    d = l2_sq(np.asarray(q, np.float32), np.asarray(x, np.float32))
    return np.argsort(d, axis=1)[:, :k].astype(np.int32)
