"""Configuration for the PageANN index.

Mirrors the knobs in the paper (Secs. 4.1-4.4, 6.1):
  - Vamana build: degree R, build beam L_build, alpha.
  - Page-node graph: page capacity n, hop parameter h, page degree R_p.
  - PQ compression: M subspaces x 256 centroids (8-bit codes).
  - LSH routing: B hyperplane bits, S sampled vectors, top-T entries.
  - Search: beam L, I/O batch b (paper fixes b=5), result k.
  - Memory-disk coordination mode (Sec 4.3).
"""
from __future__ import annotations

import dataclasses
import enum


class MemoryMode(enum.Enum):
    """Memory-disk coordination regimes from Sec 4.3.

    DISK_ONLY: compressed neighbor vectors live on the SSD page next to the
        page node (severely constrained memory; paper's ~0% memory ratio).
    HYBRID:    a slice of compressed vectors is cached in host memory, the
        remainder stays on-page (moderate budgets).
    MEM_ALL:   all compressed vectors live in memory; the freed page bytes are
        reallocated to raise the page capacity (sufficient memory).
    """

    DISK_ONLY = "disk_only"
    HYBRID = "hybrid"
    MEM_ALL = "mem_all"


@dataclasses.dataclass(frozen=True)
class AdaptiveParams:
    """Query-adaptive search knobs (the PR-7 adaptive engine). Frozen and
    hashable so a value can ride :class:`SearchParams` into a static jit
    argument. Every feature is off by default (``None``), and an
    all-``None`` value compiles to the exact non-adaptive program — results
    are bit-identical to a search with ``adaptive=None``.

    * **Early termination** (``patience`` / ``epsilon``): the hop loop
      carries a per-query stall counter that increments whenever the worst
      of the running top-k fails to improve by more than ``epsilon`` and
      resets on improvement; a query whose counter reaches ``patience``
      exits its lane instead of running to ``max_hops``. Easy queries stop
      paying worst-case page reads; hard ones keep hopping.
    * **Query-sensitive entry selection** (``entry_slack_bits`` /
      ``min_entries``): the LSH router's top-T Hamming distances are a
      per-query entry-quality signal. Only candidates within
      ``entry_slack_bits`` Hamming bits of the best candidate seed the
      beam (never fewer than ``min_entries``): a confidently-routed query
      starts from its few genuinely close entries instead of a fixed-size
      slice, while a poorly-routed (flat-profile) query keeps the whole
      top-T to hedge.
    """

    # early termination: consecutive non-improving hops before a query's
    # lane exits (None = run to max_hops, exactly the non-adaptive loop)
    patience: int | None = None
    # minimum improvement of the worst top-k distance that counts as
    # progress (absolute squared-L2; 0.0 = any strict improvement)
    epsilon: float = 0.0
    # entry selection: Hamming slack (in bits) around the best entry
    # candidate that keeps a candidate as a beam seed (None = disabled,
    # seed all top-T as before)
    entry_slack_bits: int | None = None
    # floor on per-query seeded entries when entry selection is on
    min_entries: int = 1

    def __post_init__(self):
        problems = []
        if self.patience is not None and self.patience < 1:
            problems.append(f"patience must be >= 1 (got {self.patience})")
        if not self.epsilon >= 0.0:
            problems.append(f"epsilon must be >= 0 (got {self.epsilon})")
        if self.entry_slack_bits is not None and self.entry_slack_bits < 0:
            problems.append(
                f"entry_slack_bits must be >= 0 (got {self.entry_slack_bits})"
            )
        if self.min_entries < 1:
            problems.append(f"min_entries must be >= 1 (got {self.min_entries})")
        if problems:
            raise ValueError(
                "invalid AdaptiveParams: " + "; ".join(problems)
            )
        object.__setattr__(self, "epsilon", float(self.epsilon))

    @property
    def enabled(self) -> bool:
        """Whether any adaptive feature is actually on."""
        return self.patience is not None or self.entry_slack_bits is not None

    def replace(self, **kw) -> "AdaptiveParams":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, doc: dict) -> "AdaptiveParams":
        return cls(**doc)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Runtime search knobs (Alg. 2), decoupled from the build-time config.

    Frozen and hashable so a ``SearchParams`` value can be a *static* jit
    argument: each distinct value keys one compiled executable, and a
    recall-vs-beam sweep compiles a few executables over ONE built index
    instead of rebuilding it per point. Everything that shapes the on-disk
    artifact (page geometry, PQ, memory mode) stays in
    :class:`PageANNConfig`; everything here may vary per search call.

    ``adaptive`` carries the query-adaptive knobs (:class:`AdaptiveParams`:
    per-query early termination + entry selection); ``None`` — and an
    all-default ``AdaptiveParams()`` — compile to the exact non-adaptive
    program.
    """

    k: int = 10              # result set size
    beam_width: int = 64     # L: candidate set size
    io_batch: int = 5        # b: batched I/O size (paper uses 5)
    max_hops: int = 64       # safety bound on the search while_loop
    lsh_entries: int = 16    # T: top-T Hamming entry candidates
    adaptive: AdaptiveParams | None = None  # query-adaptive knobs (off=None)

    def __post_init__(self):
        # beam_width >= lsh_entries is a PageANN-path invariant, enforced
        # where the LSH router is actually used (core.search) — baseline
        # indexes ignore lsh_entries and accept any positive beam. Every
        # violated field is reported in ONE error, not first-wins.
        problems = [
            f"{name} must be positive (got {getattr(self, name)})"
            for name in ("k", "beam_width", "io_batch", "max_hops",
                         "lsh_entries")
            if getattr(self, name) <= 0
        ]
        if self.adaptive is not None and not isinstance(
            self.adaptive, AdaptiveParams
        ):
            problems.append(
                "adaptive must be an AdaptiveParams or None "
                f"(got {type(self.adaptive).__name__})"
            )
        if problems:
            raise ValueError("invalid SearchParams: " + "; ".join(problems))

    def pageann_violations(self) -> list:
        """Cross-field invariants of the PageANN search path (the LSH
        router actually seeds the beam there; baselines ignore these).
        Returns ALL violations so the caller can raise them in one error."""
        problems = []
        if self.beam_width < self.lsh_entries:
            problems.append(
                "beam_width >= lsh_entries is required: the top-T LSH "
                f"entry candidates seed the beam (got L={self.beam_width}, "
                f"T={self.lsh_entries})"
            )
        a = self.adaptive
        if a is not None and a.entry_slack_bits is not None \
                and a.min_entries > self.lsh_entries:
            problems.append(
                "adaptive.min_entries <= lsh_entries is required: the "
                "entry floor cannot exceed the candidate pool (got "
                f"min_entries={a.min_entries}, T={self.lsh_entries})"
            )
        return problems

    @classmethod
    def from_config(cls, cfg: "PageANNConfig", k: int = 10) -> "SearchParams":
        """The config's build-time defaults as a runtime parameter set."""
        return cls(
            k=k,
            beam_width=cfg.beam_width,
            io_batch=cfg.io_batch,
            max_hops=cfg.max_hops,
            lsh_entries=cfg.lsh_entries,
        )

    def replace(self, **kw) -> "SearchParams":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> dict:
        doc = dataclasses.asdict(self)
        doc["adaptive"] = (
            self.adaptive.to_json() if self.adaptive is not None else None
        )
        return doc

    @classmethod
    def from_json(cls, doc: dict) -> "SearchParams":
        doc = dict(doc)
        if doc.get("adaptive") is not None:
            doc["adaptive"] = AdaptiveParams.from_json(doc["adaptive"])
        return cls(**doc)


def resolve_search_params(
    default: SearchParams,
    k: int | None,
    params: "SearchParams | None",
) -> SearchParams:
    """The protocol-wide resolution rule for ``search(queries, k, params)``:
    ``params`` wins over the index default, an explicit ``k`` wins over
    ``params.k``. One definition so every ``VectorIndex`` implementation
    resolves identically."""
    p = params if params is not None else default
    if k is not None and k != p.k:
        p = p.replace(k=k)
    return p


_UNIT_BYTES = {
    "B": 1,
    "KB": 10**3, "MB": 10**6, "GB": 10**9, "TB": 10**12,
    "KIB": 2**10, "MIB": 2**20, "GIB": 2**30, "TIB": 2**40,
}


@dataclasses.dataclass(frozen=True)
class MemoryBudget:
    """Device-memory budget for the resident page region at load time.

    Exactly one of ``bytes`` (absolute budget for resident page records)
    or ``fraction`` (of the artifact's page file) must be set. Passing
    ``memory_budget=None`` to the load surface means "no budget": the whole
    page file is materialized on device, exactly today's behavior. A budget
    caps how many packed page records are pinned resident (chosen hottest
    first by the artifact's recorded access order); every other page is
    streamed from the host memmap per hop through the staging path.

    Frozen and hashable so a budget can ride static jit closures and be
    serialized losslessly into the artifact manifest (``to_json`` /
    ``from_json`` — the ``residency`` section).
    """

    bytes: int | None = None
    fraction: float | None = None

    def __post_init__(self):
        if (self.bytes is None) == (self.fraction is None):
            raise ValueError(
                "MemoryBudget needs exactly one of bytes= or fraction="
            )
        if self.bytes is not None:
            if not isinstance(self.bytes, int) or isinstance(self.bytes, bool):
                raise ValueError("MemoryBudget.bytes must be an int")
            if self.bytes <= 0:
                raise ValueError("MemoryBudget.bytes must be positive")
        if self.fraction is not None:
            if not 0.0 < float(self.fraction) <= 1.0:
                raise ValueError(
                    "MemoryBudget.fraction must be in (0, 1]"
                )
            object.__setattr__(self, "fraction", float(self.fraction))

    def resolve_pages(self, num_pages: int, page_bytes: int) -> int:
        """How many page records fit this budget: at least 1 (the search
        needs a non-empty resident array), at most every page."""
        if self.bytes is not None:
            fit = self.bytes // max(1, page_bytes)
        else:
            fit = int(num_pages * self.fraction)
        return max(1, min(int(num_pages), int(fit)))

    def to_json(self) -> dict:
        return {"bytes": self.bytes, "fraction": self.fraction}

    @classmethod
    def from_json(cls, doc: dict) -> "MemoryBudget":
        return cls(bytes=doc.get("bytes"), fraction=doc.get("fraction"))

    @classmethod
    def parse(cls, spec: "str | int | float | MemoryBudget") -> "MemoryBudget":
        """Parse a CLI-style budget: ``"512MB"`` / ``"1GiB"`` / a byte
        count, or a bare number in (0, 1] meaning a fraction of the page
        file (``"0.25"``)."""
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, bool):
            raise ValueError(f"cannot parse memory budget from {spec!r}")
        if isinstance(spec, int):
            return cls(bytes=spec)
        if isinstance(spec, float):
            return cls(fraction=spec)
        s = str(spec).strip()
        unit = ""
        num = s
        for i, c in enumerate(s):
            if c.isalpha():
                num, unit = s[:i], s[i:]
                break
        try:
            value = float(num)
        except ValueError:
            raise ValueError(f"cannot parse memory budget {spec!r}") from None
        if unit:
            mult = _UNIT_BYTES.get(unit.strip().upper())
            if mult is None:
                raise ValueError(
                    f"unknown memory budget unit {unit!r} in {spec!r} "
                    f"(use one of {sorted(_UNIT_BYTES)})"
                )
            return cls(bytes=int(value * mult))
        if value <= 1.0 and "." in num:
            return cls(fraction=value)
        return cls(bytes=int(value))


@dataclasses.dataclass(frozen=True)
class DeltaParams:
    """Knobs of the mutable-index delta tier (``repro.core.delta``).

    The delta tier keeps freshly inserted vectors in memory and deleted ids
    as tombstones; the page-aligned disk artifact stays frozen until
    compaction folds the delta back in. These knobs bound the two costs the
    tier introduces: the brute-force scan over the delta, and the top-k
    oversampling that compensates for tombstoned base results.
    """

    # delta live-vector count / base live-vector count above which
    # ``MutableIndex.insert`` triggers an automatic ``compact()`` (set to
    # None / rely on explicit compact() by passing auto_compact=False)
    compact_fraction: float = 0.25
    # base-search k is oversampled by the tombstone count rounded up to a
    # power of two so jit shapes stay bounded; this caps the bucket — past
    # it, heavily-deleted results may crowd out live ones until compaction
    max_tombstone_oversample: int = 256
    # initial delta buffer capacity (rows); grows by doubling
    min_capacity: int = 256

    def __post_init__(self):
        if not 0.0 < self.compact_fraction:
            raise ValueError("compact_fraction must be positive")
        if self.max_tombstone_oversample < 1:
            raise ValueError("max_tombstone_oversample must be >= 1")
        if self.min_capacity < 1:
            raise ValueError("min_capacity must be >= 1")


@dataclasses.dataclass(frozen=True)
class FilterParams:
    """Knobs of the filtered-search path (``repro.core.filter``).

    Filtered-out page members are scored to ``+inf`` inside the page
    scan, so a selective predicate needs a wider beam to surface enough
    passing candidates — the same pow2-bucketed oversampling the
    tombstone path uses, driven by the predicate's measured selectivity.
    """

    # beam_width is multiplied by the next power of two of
    # (1 / selectivity), capped here so jit shapes stay bounded; past the
    # cap a very selective filter may under-recall until the caller
    # widens the beam explicitly
    max_filter_oversample: int = 64

    def __post_init__(self):
        if self.max_filter_oversample < 1:
            raise ValueError("max_filter_oversample must be >= 1")


@dataclasses.dataclass(frozen=True)
class PageANNConfig:
    dim: int
    # --- Vamana vector-graph build (Sec 4.1 starts from a Vamana graph) ---
    graph_degree: int = 32          # R
    build_beam: int = 64            # candidate pool size during construction
    alpha: float = 1.2              # robust-prune slack
    build_rounds: int = 2           # 1st round alpha=1.0, 2nd round alpha
    # --- page-node graph (Alg. 1) ---
    page_bytes: int = 4096          # S_page: SSD page size the layout targets
    page_capacity: int | None = None  # n; derived from page_bytes when None
    hop_h: int = 2                  # h: candidate-selection hop radius
    page_degree: int = 48           # R_p: max external neighbors kept per page
    # --- PQ compression ---
    pq_subspaces: int = 16          # M
    pq_ksub: int = 256              # centroids per subspace (8-bit codes)
    pq_iters: int = 12              # k-means Lloyd iterations
    # --- LSH routing index (Sec 4.3) ---
    lsh_bits: int = 64              # B hyperplane bits
    lsh_sample: int = 1024          # S sampled vectors
    lsh_entries: int = 16           # T entry candidates (top-T Hamming)
    # --- search (Alg. 2): per-call defaults only — the runtime values live
    # in SearchParams and may differ on every search() call ---
    beam_width: int = 64            # L: candidate set size
    io_batch: int = 5               # b: batched I/O size (paper uses 5)
    max_hops: int = 64              # safety bound on while_loop
    # --- memory-disk coordination ---
    memory_mode: MemoryMode = MemoryMode.HYBRID
    memory_budget_bytes: int | None = None  # drives mode selection when set
    cache_pages: int = 0            # warmed page cache entries (Sec 4.3)
    # --- misc ---
    dtype_bytes: int = 4            # S_dtype: vector element size (f32)
    id_bytes: int = 4               # S_nbrID
    seed: int = 0

    def __post_init__(self):
        if self.dim <= 0:
            raise ValueError("dim must be positive")
        if self.pq_subspaces > self.dim:
            raise ValueError("pq_subspaces cannot exceed dim")
        if self.dim % self.pq_subspaces != 0:
            raise ValueError("dim must be divisible by pq_subspaces")
        if self.lsh_bits % 32 != 0:
            raise ValueError("lsh_bits must be a multiple of 32 (packed words)")
        if self.page_degree > 128:
            raise ValueError(
                "page_degree must be <= 128: the packed page record stores "
                "one neighbor per f32 lane per PQ subspace (layout.pack_"
                "page_records); the paper uses R_p = 48"
            )

    @property
    def pq_code_bytes(self) -> int:
        return self.pq_subspaces  # one uint8 per subspace

    def resolve_capacity(self) -> int:
        """Paper Sec 4.2 page-capacity equation, resolved for this config.

        N_nodes = (S_page - 2*S_num_nbrs - S_nbrID*N_nbrs - S_CV*N_CV)
                  / (D * S_dtype)

        N_CV (compressed vectors co-located on the page) depends on the
        memory-disk coordination mode: DISK_ONLY keeps a code for every
        neighbor on-page, MEM_ALL keeps none (codes live in memory and the
        freed bytes buy more vectors per page), HYBRID keeps half.
        """
        if self.page_capacity is not None:
            return self.page_capacity
        if self.memory_mode == MemoryMode.DISK_ONLY:
            n_cv = self.page_degree
        elif self.memory_mode == MemoryMode.HYBRID:
            n_cv = self.page_degree // 2
        else:
            n_cv = 0
        s_num_nbrs = 4
        fixed = 2 * s_num_nbrs + self.id_bytes * self.page_degree \
            + self.pq_code_bytes * n_cv
        cap = (self.page_bytes - fixed) // (self.dim * self.dtype_bytes)
        return max(1, int(cap))
