"""Host-side streaming page tier: the memmap as the source of truth.

The paper's regime is disk-resident search under a memory budget; this
module is the half of it that lives on the host. A :class:`PageFetcher`
wraps the ``np.memmap`` of ``pages.bin`` and serves per-hop record
requests from the jitted search loop (reached through
``compat.pure_callback_batched`` — one host round-trip per hop for the
whole vmapped query batch):

  * requested page ids arrive with arbitrary leading batch axes,
    ``PAD``/-1 marking slots the device does not need (resident pages,
    unselected batch lanes) — those rows come back zeroed without touching
    the file;
  * a bounded LRU **staging cache** of recently fetched records absorbs
    the re-reads a beam search naturally produces (the same hub pages are
    requested hop after hop, query after query), so a miss costs one page
    read, a re-request costs a memcpy;
  * ``pages_fetched`` / ``fetch_hits`` / ``fetch_wall_s`` counters make
    budget pressure observable end to end (``PageANNIndex.fetch_stats`` ->
    ``EngineMetrics``).

The fetcher is deliberately dumb about *placement*: which pages are
resident on device is decided once at load time
(``persist.load_pageann``); everything the device does not hold is this
module's problem, every hop.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

PAD = -1

# default staging-cache size (pages). Big enough to absorb the hub-page
# re-reads of a beam search over a small index, small enough that the
# host-side footprint stays a fraction of the resident region for any
# realistic page count.
DEFAULT_STAGE_PAGES = 256


class PageFetcher:
    """Thread-safe streaming reader over a memmapped page-record file.

    ``recs`` is the (P, rows, lanes) f32 source of truth (typically an
    ``np.memmap`` of ``pages.bin``; any ndarray works). Calling the
    fetcher with an int array of page ids returns the packed records as
    f32, shape ``ids.shape + (rows, lanes)``; ids < 0 yield zero records.

    Instances are hashable by identity on purpose: the jitted streaming
    search is cached per fetcher (``core.search.stream_search``), and two
    fetchers over different files must never share a compiled closure.
    """

    def __init__(
        self,
        recs: np.ndarray,
        *,
        stage_pages: int = DEFAULT_STAGE_PAGES,
    ):
        if recs.ndim != 3:
            raise ValueError(
                f"PageFetcher needs (P, rows, lanes) records, got {recs.shape}"
            )
        if stage_pages < 1:
            raise ValueError("stage_pages must be >= 1")
        self._recs = recs
        self._stage_pages = int(stage_pages)
        self._lock = threading.Lock()
        # page id -> (rows, lanes) f32 copy, most-recently-used last
        self._stage: collections.OrderedDict[int, np.ndarray] = (
            collections.OrderedDict()
        )
        self._pages_fetched = 0
        self._fetch_hits = 0
        self._fetch_wall_s = 0.0
        # trailing window of per-callback wall seconds — the exposition
        # layer's fetch-latency histogram feed (bounded, like the engine's
        # latency window)
        self._wall_window: collections.deque = collections.deque(maxlen=4096)
        # optional span tracer (duck-typed, see repro.obs.trace.Tracer);
        # attached by the serving engine so per-hop host fetches show up
        # as child spans of the dispatch that triggered them. The fetcher
        # stamps spans with the tracer's own clock.
        self.tracer = None

    @property
    def num_pages(self) -> int:
        return int(self._recs.shape[0])

    @property
    def record_shape(self) -> tuple[int, int]:
        return int(self._recs.shape[1]), int(self._recs.shape[2])

    def __call__(self, ids) -> np.ndarray:
        t0 = time.perf_counter()
        ids = np.asarray(ids)
        flat = ids.reshape(-1).astype(np.int64)
        rows, lanes = self.record_shape
        out = np.zeros((flat.size, rows, lanes), np.float32)
        with self._lock:
            fetched0 = self._pages_fetched
            for j, pid in enumerate(flat):
                if pid < 0:
                    continue
                pid = int(pid)
                rec = self._stage.get(pid)
                if rec is not None:
                    self._stage.move_to_end(pid)
                    self._fetch_hits += 1
                else:
                    # THE disk read: one page record off the memmap
                    rec = np.asarray(self._recs[pid], np.float32)
                    self._pages_fetched += 1
                    self._stage[pid] = rec
                    if len(self._stage) > self._stage_pages:
                        self._stage.popitem(last=False)     # evict LRU
                out[j] = rec
            wall = time.perf_counter() - t0
            self._fetch_wall_s += wall
            self._wall_window.append(wall)
            misses = self._pages_fetched - fetched0
        tr = self.tracer
        if tr is not None and tr.enabled:
            t1 = tr.now()
            tr.add("page_fetch", t1 - wall, t1, cat="host-fetch",
                   track="host-fetch",
                   args={"requested": int((flat >= 0).sum()),
                         "misses": misses})
        return out.reshape(ids.shape + (rows, lanes))

    # ------------------------------------------------------------- counters
    def fetch_stats(self) -> dict:
        """Cumulative counters: pages read off disk, staging-cache hits,
        and wall seconds spent inside the host callback — plus
        ``wall_window``, the bounded trailing window of per-callback wall
        seconds feeding the exposition layer's fetch-latency histogram."""
        with self._lock:
            return dict(
                pages_fetched=self._pages_fetched,
                fetch_hits=self._fetch_hits,
                fetch_wall_s=self._fetch_wall_s,
                wall_window=tuple(self._wall_window),
            )

    def reset_stats(self) -> None:
        with self._lock:
            self._pages_fetched = 0
            self._fetch_hits = 0
            self._fetch_wall_s = 0.0
            self._wall_window.clear()

    def __repr__(self) -> str:
        return (
            f"PageFetcher(pages={self.num_pages}, "
            f"stage_pages={self._stage_pages})"
        )
