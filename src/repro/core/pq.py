"""Product quantization: the compressed vector representation of the paper.

PageANN keeps PQ codes (a) inside page records for neighbor vectors
(DISK_ONLY / HYBRID coordination modes) and (b) in the in-memory tier
(HYBRID / MEM_ALL). Distances to the query are estimated with asymmetric
distance computation (ADC): per-query LUTs of squared distances between each
query sub-vector and every centroid, summed over subspaces via code lookups.

The ADC inner loop is the compute hot spot of next-hop selection; its TPU
kernel lives in ``repro.kernels.pq_adc`` with ``pq.adc_distance`` as oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("ksub", "iters"))
def _kmeans_1sub(xsub, key, *, ksub, iters):
    """Lloyd k-means for one PQ subspace. xsub: (N, dsub)."""
    n = xsub.shape[0]
    init = jax.random.choice(key, n, (ksub,), replace=n < ksub)
    cents = xsub[init]

    def step(cents, _):
        d = (
            (xsub * xsub).sum(-1)[:, None]
            - 2.0 * xsub @ cents.T
            + (cents * cents).sum(-1)[None, :]
        )
        assign = jnp.argmin(d, axis=1)
        one_hot = jax.nn.one_hot(assign, ksub, dtype=xsub.dtype)  # (N, K)
        counts = one_hot.sum(0)                                    # (K,)
        sums = one_hot.T @ xsub                                    # (K, dsub)
        new = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), cents
        )
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


def train_pq(
    x: np.ndarray, m: int, ksub: int = 256, iters: int = 12, seed: int = 0
) -> np.ndarray:
    """Train PQ codebooks. Returns (M, ksub, dsub) float32."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    dsub = d // m
    xs = x.reshape(n, m, dsub).transpose(1, 0, 2)  # (M, N, dsub)
    keys = jax.random.split(jax.random.PRNGKey(seed), m)
    cents = jax.vmap(
        lambda xsub, k: _kmeans_1sub(xsub, k, ksub=ksub, iters=iters)
    )(xs, keys)
    return np.asarray(cents)


@jax.jit
def pq_encode(x: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Encode vectors to PQ codes. x: (N, d) -> (N, M) uint8."""
    n, d = x.shape
    m, ksub, dsub = codebooks.shape
    xs = x.reshape(n, m, dsub)

    def enc(sub, cents):  # sub: (N, dsub), cents: (ksub, dsub)
        dist = (
            (sub * sub).sum(-1)[:, None]
            - 2.0 * sub @ cents.T
            + (cents * cents).sum(-1)[None, :]
        )
        return jnp.argmin(dist, axis=1)

    codes = jax.vmap(enc, in_axes=(1, 0), out_axes=1)(xs, codebooks)
    return codes.astype(jnp.uint8)


@jax.jit
def pq_lut(q: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Per-query ADC lookup table: (M, ksub) squared sub-distances."""
    m, ksub, dsub = codebooks.shape
    qs = q.reshape(m, 1, dsub)
    return ((qs - codebooks) ** 2).sum(-1)  # (M, ksub)


def adc_distance(codes: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric distance: sum LUT entries selected by codes.

    codes: (..., M) uint8; lut: (M, ksub) -> (...,) float32.
    This is the pure-jnp oracle mirrored by the Pallas kernel
    ``repro.kernels.pq_adc``.
    """
    idx = codes.astype(jnp.int32)                        # (..., M)
    vals = jax.vmap(lambda t, i: t[i], in_axes=(0, -1), out_axes=-1)(lut, idx)
    return vals.sum(-1)


@jax.jit
def pq_decode(codes: jnp.ndarray, codebooks: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct approximate vectors from codes (for diagnostics)."""
    m, ksub, dsub = codebooks.shape
    idx = codes.astype(jnp.int32)  # (N, M)
    parts = jax.vmap(lambda cb, i: cb[i], in_axes=(0, 1), out_axes=1)(
        codebooks, idx
    )  # (N, M, dsub)
    return parts.reshape(codes.shape[0], m * dsub)
