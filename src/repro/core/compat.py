"""Version-portable wrappers over the jax sharding API.

The mesh/shard_map surface moved between jax releases: ``shard_map`` lived
in ``jax.experimental.shard_map`` (with a ``check_rep`` flag) before being
promoted to ``jax.shard_map`` (flag renamed ``check_vma``), and
``jax.make_mesh`` only grew ``axis_types`` after 0.4.x. Everything in this
repo that touches a mesh goes through these two functions so the same code
lowers on both the pinned CI jax and newer TPU toolchains.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` without version-specific ``axis_types`` kwargs."""
    if hasattr(jax, "make_mesh"):
        try:
            axis_type = getattr(jax.sharding, "AxisType", None)
            if axis_type is not None:
                return jax.make_mesh(
                    tuple(axis_shapes), tuple(axis_names),
                    axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
                )
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
        except TypeError:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))


def shard_map(
    f: Callable, *, mesh: jax.sharding.Mesh, in_specs: Any, out_specs: Any
) -> Callable:
    """``shard_map`` with replication checking off (we mix collectives with
    per-shard reductions, which the static checker rejects either way)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
