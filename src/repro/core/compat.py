"""Version-portable wrappers over moving jax API surfaces.

The mesh/shard_map surface moved between jax releases: ``shard_map`` lived
in ``jax.experimental.shard_map`` (with a ``check_rep`` flag) before being
promoted to ``jax.shard_map`` (flag renamed ``check_vma``), and
``jax.make_mesh`` only grew ``axis_types`` after 0.4.x. Likewise
``jax.pure_callback`` batching moved from the boolean ``vectorized=`` flag
to the ``vmap_method=`` enum. Everything in this repo that touches these
surfaces goes through here so the same code lowers on both the pinned CI
jax and newer TPU toolchains.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax


@functools.cache
def _callback_batch_kwargs() -> dict:
    """How this jax spells "the callback handles batched args itself".

    ``vmap_method="expand_dims"`` (new spelling) and ``vectorized=True``
    (old spelling) agree for callbacks whose every argument is mapped: the
    host function is invoked ONCE per batched call with a leading batch
    axis on each argument and must return results with the same leading
    axis — exactly what the streaming page fetcher wants (one host
    round-trip per hop for the whole vmapped query batch, not one per
    query).
    """
    import inspect

    try:
        params = inspect.signature(jax.pure_callback).parameters
    except (TypeError, ValueError):
        return {"vectorized": True}
    if "vmap_method" in params:
        return {"vmap_method": "expand_dims"}
    return {"vectorized": True}


def pure_callback_batched(callback: Callable, result_shape_dtypes, *args):
    """``jax.pure_callback`` that batches under vmap with one host call.

    ``callback`` must accept arguments with arbitrary leading batch axes
    and return arrays with those axes prepended to the declared result
    shapes.
    """
    return jax.pure_callback(
        callback, result_shape_dtypes, *args, **_callback_batch_kwargs()
    )


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` without version-specific ``axis_types`` kwargs."""
    if hasattr(jax, "make_mesh"):
        try:
            axis_type = getattr(jax.sharding, "AxisType", None)
            if axis_type is not None:
                return jax.make_mesh(
                    tuple(axis_shapes), tuple(axis_names),
                    axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
                )
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
        except TypeError:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    from jax.experimental import mesh_utils

    devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
    return jax.sharding.Mesh(devices, tuple(axis_names))


def shard_map(
    f: Callable, *, mesh: jax.sharding.Mesh, in_specs: Any, out_specs: Any
) -> Callable:
    """``shard_map`` with replication checking off (we mix collectives with
    per-shard reductions, which the static checker rejects either way)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
