"""PageANN core: the paper's contribution as composable JAX modules."""
from repro.core.config import MemoryMode, PageANNConfig, SearchParams
from repro.core.index import PageANNIndex, recall_at_k
from repro.core.persist import load_index
from repro.core.protocol import VectorIndex

__all__ = [
    "MemoryMode",
    "PageANNConfig",
    "PageANNIndex",
    "SearchParams",
    "VectorIndex",
    "load_index",
    "recall_at_k",
]
