"""PageANN core: the paper's contribution as composable JAX modules."""
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.index import PageANNIndex, recall_at_k

__all__ = ["MemoryMode", "PageANNConfig", "PageANNIndex", "recall_at_k"]
