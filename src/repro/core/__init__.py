"""PageANN core: the paper's contribution as composable JAX modules."""
from repro.core.config import (
    AdaptiveParams,
    DeltaParams,
    FilterParams,
    MemoryBudget,
    MemoryMode,
    PageANNConfig,
    SearchParams,
)
from repro.core.delta import DeltaTier, MutableIndex
from repro.core.filter import FilterExpr, MetadataSchema, Num, Tag
from repro.core.index import PageANNIndex, recall_at_k
from repro.core.persist import IndexFormatError, load_index
from repro.core.protocol import MutableVectorIndex, VectorIndex

__all__ = [
    "AdaptiveParams",
    "DeltaParams",
    "DeltaTier",
    "FilterExpr",
    "FilterParams",
    "IndexFormatError",
    "MemoryBudget",
    "MemoryMode",
    "MetadataSchema",
    "MutableIndex",
    "MutableVectorIndex",
    "Num",
    "PageANNConfig",
    "PageANNIndex",
    "SearchParams",
    "Tag",
    "VectorIndex",
    "load_index",
    "recall_at_k",
]
