"""PageANN core: the paper's contribution as composable JAX modules."""
from repro.core.config import (
    AdaptiveParams,
    DeltaParams,
    MemoryBudget,
    MemoryMode,
    PageANNConfig,
    SearchParams,
)
from repro.core.delta import DeltaTier, MutableIndex
from repro.core.index import PageANNIndex, recall_at_k
from repro.core.persist import IndexFormatError, load_index
from repro.core.protocol import MutableVectorIndex, VectorIndex

__all__ = [
    "AdaptiveParams",
    "DeltaParams",
    "DeltaTier",
    "IndexFormatError",
    "MemoryBudget",
    "MemoryMode",
    "MutableIndex",
    "MutableVectorIndex",
    "PageANNConfig",
    "PageANNIndex",
    "SearchParams",
    "VectorIndex",
    "load_index",
    "recall_at_k",
]
