"""Metadata schemas and filter expressions for filtered search.

Production vector queries are rarely bare top-k: they carry predicates
("this user's docs", "created after T"). This module gives each
collection a declared :class:`MetadataSchema` (tag fields: small string
vocabularies; numeric fields: float64-representable scalars), stores the
per-vector metadata as packed **page-slot-aligned columns** (the same
``new_to_old`` scatter the page records use, so a page's metadata rows
sit at the page's slot offsets), and compiles a frozen/hashable
:class:`FilterExpr` into a :class:`CompiledFilter` — a pure-tuple static
jit argument the search threads through ``score_page_batch`` to mask
filtered-out members to ``+inf`` *inside* the page scan.

Layers:

  * ``MetadataSchema`` — declares the fields; validated like
    ``AdaptiveParams`` (every violation in one ``ValueError``);
    JSON round-trips through the index manifest.
  * ``Tag("field") == v`` / ``.isin(...)`` and ``Num("field").between/
    ge/le`` build ``FilterExpr`` clauses; ``&`` ANDs expressions.
    Expressions are frozen and hashable — the batching engine keys
    pending groups by them, and the index caches one compiled form per
    expression.
  * ``compile_filter(expr, schema, vocab)`` resolves field names to
    column indices and tag values to integer codes. Unknown *fields*
    are errors (reported together); unknown tag *values* simply match
    nothing — a predicate over a value no vector carries is a valid
    query with an empty answer, not a schema violation.
  * ``filter_mask`` (jnp) / ``filter_mask_np`` (numpy) evaluate a
    compiled filter over metadata columns. The numpy twin is the
    brute-force oracle and the selectivity probe for oversampling.

Encoding invariants (shared with the delta tier and persistence):

  * tag codes are ``>= 0``; **missing/pad = -1** (matches no clause);
  * numeric missing/pad = ``NaN`` (range comparisons are False);
  * a vocabulary maps each tag field to a tuple of values; codes are
    positions in that tuple. ``MutableIndex`` extends vocabularies
    append-only, so codes stay stable across inserts until compaction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

_MISSING_TAG = -1  # tag code for "no value": valid codes are >= 0


class MetaArrays(NamedTuple):
    """Packed metadata columns, page-slot-aligned like ``PageStore.vecs``.

    ``tags``: (rows, n_tag_fields) int32 codes (missing/pad = -1).
    ``nums``: (rows, n_num_fields) float32 (missing/pad = NaN).
    Either axis-1 may be 0 when the schema has no fields of that kind.
    """

    tags: Any
    nums: Any


# --------------------------------------------------------------------- schema
@dataclasses.dataclass(frozen=True)
class MetadataSchema:
    """Per-collection metadata declaration: which fields exist and their
    kinds. ``tags`` are categorical string fields (vocabulary-encoded);
    ``numerics`` are scalar float fields (range-filterable)."""

    tags: tuple[str, ...] = ()
    numerics: tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "tags", tuple(self.tags))
        object.__setattr__(self, "numerics", tuple(self.numerics))
        problems = []
        for kind, names in (("tags", self.tags), ("numerics", self.numerics)):
            for n in names:
                if not isinstance(n, str) or not n.isidentifier():
                    problems.append(
                        f"{kind} field names must be identifiers (got {n!r})"
                    )
            dup = sorted({n for n in names if names.count(n) > 1})
            if dup:
                problems.append(f"duplicate {kind} fields: {dup}")
        overlap = sorted(set(self.tags) & set(self.numerics))
        if overlap:
            problems.append(
                f"fields declared as both tag and numeric: {overlap}"
            )
        if not self.tags and not self.numerics:
            problems.append("schema must declare at least one field")
        if problems:
            raise ValueError(
                "invalid MetadataSchema: " + "; ".join(problems)
            )

    @property
    def fields(self) -> tuple[str, ...]:
        return self.tags + self.numerics

    def to_json(self) -> dict:
        return {"tags": list(self.tags), "numerics": list(self.numerics)}

    @classmethod
    def from_json(cls, obj: dict) -> "MetadataSchema":
        return cls(tags=tuple(obj.get("tags", ())),
                   numerics=tuple(obj.get("numerics", ())))


# ---------------------------------------------------------------- expressions
@dataclasses.dataclass(frozen=True)
class FilterExpr:
    """A conjunction of clauses over schema fields. Frozen and hashable:
    it keys the engine's pending groups and the index's compiled-filter
    cache, and (compiled) rides the jit signature as a static arg.

    ``tag_clauses``: ((field, (value, ...)), ...) — field's tag ∈ set.
    ``num_clauses``: ((field, lo, hi), ...) — lo <= field <= hi
    (``-inf``/``+inf`` for one-sided ranges). Clauses are sorted so two
    equal predicates hash equal regardless of construction order."""

    tag_clauses: tuple[tuple[str, tuple[str, ...]], ...] = ()
    num_clauses: tuple[tuple[str, float, float], ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self,
            "tag_clauses",
            tuple(sorted((f, tuple(sorted(vs)))
                         for f, vs in self.tag_clauses)),
        )
        object.__setattr__(
            self,
            "num_clauses",
            tuple(sorted((f, float(lo), float(hi))
                         for f, lo, hi in self.num_clauses)),
        )
        problems = []
        for f, vs in self.tag_clauses:
            if not vs:
                problems.append(f"tag clause on {f!r} has an empty value set")
            for v in vs:
                if not isinstance(v, str):
                    problems.append(
                        f"tag clause on {f!r} has a non-string value {v!r}"
                    )
        for f, lo, hi in self.num_clauses:
            if math.isnan(lo) or math.isnan(hi):
                problems.append(f"numeric clause on {f!r} has a NaN bound")
            elif lo > hi:
                problems.append(
                    f"numeric clause on {f!r} has lo > hi ({lo} > {hi})"
                )
        if not self.tag_clauses and not self.num_clauses:
            problems.append("filter must have at least one clause")
        if problems:
            raise ValueError("invalid FilterExpr: " + "; ".join(problems))

    def __and__(self, other: "FilterExpr") -> "FilterExpr":
        if not isinstance(other, FilterExpr):
            return NotImplemented
        return FilterExpr(
            tag_clauses=self.tag_clauses + other.tag_clauses,
            num_clauses=self.num_clauses + other.num_clauses,
        )

    @property
    def fields(self) -> tuple[str, ...]:
        return tuple(f for f, _ in self.tag_clauses) + tuple(
            f for f, _, _ in self.num_clauses
        )


class Tag:
    """Builder for tag-field clauses: ``Tag("user") == "alice"`` or
    ``Tag("lang").isin("en", "de")``."""

    __slots__ = ("field",)

    def __init__(self, field: str):
        self.field = field

    def __eq__(self, value) -> FilterExpr:  # type: ignore[override]
        return self.isin(value)

    def __hash__(self):  # __eq__ is repurposed; keep Tag hashable
        return hash(("Tag", self.field))

    def isin(self, *values) -> FilterExpr:
        if len(values) == 1 and isinstance(values[0], (list, tuple, set,
                                                       frozenset)):
            values = tuple(values[0])
        return FilterExpr(tag_clauses=((self.field, tuple(values)),))


class Num:
    """Builder for numeric-field clauses: ``Num("ts").between(a, b)``,
    ``.ge(lo)``, ``.le(hi)``."""

    __slots__ = ("field",)

    def __init__(self, field: str):
        self.field = field

    def between(self, lo: float, hi: float) -> FilterExpr:
        return FilterExpr(num_clauses=((self.field, float(lo), float(hi)),))

    def ge(self, lo: float) -> FilterExpr:
        return self.between(lo, math.inf)

    def le(self, hi: float) -> FilterExpr:
        return self.between(-math.inf, hi)


# ----------------------------------------------------------------- compiling
@dataclasses.dataclass(frozen=True)
class CompiledFilter:
    """A ``FilterExpr`` resolved against a schema + vocabulary: field
    names -> column indices, tag values -> integer codes. Pure nested
    tuples of ints/floats — hashable, so it rides the jit signature as a
    static argument (one compiled program per distinct predicate shape).

    ``tag_clauses``: ((col, (code, ...)), ...). An unknown tag value
    compiles to no code — if a clause's codes are empty the filter
    matches nothing (``empty`` is True and the mask is all-False).
    ``num_clauses``: ((col, lo, hi), ...)."""

    tag_clauses: tuple[tuple[int, tuple[int, ...]], ...] = ()
    num_clauses: tuple[tuple[int, float, float], ...] = ()

    @property
    def empty(self) -> bool:
        """True when some clause can match nothing (unknown tag value):
        the whole conjunction is unsatisfiable."""
        return any(not codes for _, codes in self.tag_clauses)


def compile_filter(
    expr: FilterExpr,
    schema: MetadataSchema | None,
    vocab: dict[str, tuple[str, ...]],
) -> CompiledFilter:
    """Resolve ``expr`` against ``schema``/``vocab``. Unknown or
    wrong-kind fields are errors — every violation reported in one
    ``ValueError``. Unknown tag *values* match nothing (empty codes)."""
    if schema is None:
        raise ValueError(
            "index has no MetadataSchema: build(..., schema=, metadata=) "
            "before searching with filter="
        )
    problems = []
    tag_pos = {f: i for i, f in enumerate(schema.tags)}
    num_pos = {f: i for i, f in enumerate(schema.numerics)}
    tag_clauses = []
    for f, vs in expr.tag_clauses:
        if f not in tag_pos:
            hint = " (declared numeric)" if f in num_pos else ""
            problems.append(f"unknown tag field {f!r}{hint}")
            continue
        codes = {v: i for i, v in enumerate(vocab.get(f, ()))}
        tag_clauses.append(
            (tag_pos[f], tuple(sorted(codes[v] for v in vs if v in codes)))
        )
    num_clauses = []
    for f, lo, hi in expr.num_clauses:
        if f not in num_pos:
            hint = " (declared tag)" if f in tag_pos else ""
            problems.append(f"unknown numeric field {f!r}{hint}")
            continue
        num_clauses.append((num_pos[f], lo, hi))
    if problems:
        raise ValueError(
            "filter does not match the collection schema: "
            + "; ".join(problems)
        )
    return CompiledFilter(tag_clauses=tuple(tag_clauses),
                          num_clauses=tuple(num_clauses))


# ----------------------------------------------------------------- evaluation
def filter_mask(cfilter: CompiledFilter, tags, nums):
    """jnp mask over metadata rows: True where every clause passes.
    ``tags`` (rows, T) int32, ``nums`` (rows, N) float32; missing values
    (-1 / NaN) never pass. Traced — ``cfilter`` must be static."""
    mask = jnp.ones(tags.shape[:-1], bool)
    for col, codes in cfilter.tag_clauses:
        t = tags[..., col]
        ok = jnp.zeros_like(t, dtype=bool)
        for c in codes:  # small unrolled OR: codes are a static tuple
            ok = ok | (t == c)
        mask = mask & ok
    for col, lo, hi in cfilter.num_clauses:
        x = nums[..., col]
        mask = mask & (x >= lo) & (x <= hi)  # NaN fails both
    return mask


def filter_mask_np(cfilter: CompiledFilter, tags, nums) -> np.ndarray:
    """Numpy twin of :func:`filter_mask` — the post-filter brute-force
    oracle and the host-side selectivity probe."""
    tags = np.asarray(tags)
    nums = np.asarray(nums)
    mask = np.ones(tags.shape[:-1], bool)
    for col, codes in cfilter.tag_clauses:
        mask &= np.isin(tags[..., col], np.asarray(codes, np.int32))
    with np.errstate(invalid="ignore"):
        for col, lo, hi in cfilter.num_clauses:
            x = nums[..., col]
            mask &= (x >= lo) & (x <= hi)
    return mask


# ------------------------------------------------------------------- encoding
def build_vocab(
    schema: MetadataSchema, columns: dict[str, Any]
) -> dict[str, tuple[str, ...]]:
    """Sorted vocabulary per tag field from the observed values."""
    vocab = {}
    for f in schema.tags:
        vals = columns.get(f)
        if vals is None:
            vocab[f] = ()
        else:
            vocab[f] = tuple(sorted({str(v) for v in vals if v is not None}))
    return vocab


def normalize_metadata(
    schema: MetadataSchema, metadata, n: int
) -> dict[str, list]:
    """Accept dict-of-columns or list-of-dicts; return dict-of-columns of
    length ``n`` with ``None`` for missing entries. Unknown fields and
    length mismatches are errors — every violation in one ValueError."""
    problems = []
    known = set(schema.fields)
    columns: dict[str, list] = {}
    if isinstance(metadata, dict):
        for f, vals in metadata.items():
            if f not in known:
                problems.append(f"unknown metadata field {f!r}")
                continue
            vals = list(vals)
            if len(vals) != n:
                problems.append(
                    f"metadata column {f!r} has {len(vals)} entries for "
                    f"{n} vectors"
                )
                continue
            columns[f] = vals
    else:
        rows = list(metadata)
        if len(rows) != n:
            problems.append(
                f"metadata has {len(rows)} rows for {n} vectors"
            )
        else:
            bad = sorted(
                {f for row in rows for f in row if f not in known}
            )
            if bad:
                problems.append(f"unknown metadata fields {bad}")
            else:
                for f in known:
                    columns[f] = [row.get(f) for row in rows]
    if problems:
        raise ValueError(
            "metadata does not match the schema: " + "; ".join(problems)
        )
    for f in known:
        columns.setdefault(f, [None] * n)
    return columns


def encode_metadata(
    schema: MetadataSchema,
    vocab: dict[str, tuple[str, ...]],
    columns: dict[str, list],
    n: int,
) -> MetaArrays:
    """Dict-of-columns -> packed code arrays (original-id order). Values
    absent from the vocabulary encode to the missing sentinel (-1): they
    can only appear via vocabularies that predate the value, where
    "matches nothing" is the correct semantics."""
    tags = np.full((n, len(schema.tags)), _MISSING_TAG, np.int32)
    for j, f in enumerate(schema.tags):
        codes = {v: i for i, v in enumerate(vocab.get(f, ()))}
        col = columns.get(f, [None] * n)
        for i, v in enumerate(col):
            if v is not None:
                tags[i, j] = codes.get(str(v), _MISSING_TAG)
    nums = np.full((n, len(schema.numerics)), np.nan, np.float32)
    for j, f in enumerate(schema.numerics):
        col = columns.get(f, [None] * n)
        for i, v in enumerate(col):
            if v is not None:
                nums[i, j] = float(v)
    return MetaArrays(tags=tags, nums=nums)


def decode_metadata(
    schema: MetadataSchema,
    vocab: dict[str, tuple[str, ...]],
    meta: MetaArrays,
) -> dict[str, list]:
    """Inverse of :func:`encode_metadata` (missing -> None). Used by
    compaction to re-encode delta metadata under a fresh vocabulary."""
    tags = np.asarray(meta.tags)
    nums = np.asarray(meta.nums)
    out: dict[str, list] = {}
    for j, f in enumerate(schema.tags):
        vals = vocab.get(f, ())
        out[f] = [
            vals[c] if 0 <= c < len(vals) else None
            for c in tags[:, j].tolist()
        ]
    for j, f in enumerate(schema.numerics):
        col = nums[:, j]
        out[f] = [None if math.isnan(v) else float(v) for v in col.tolist()]
    return out
