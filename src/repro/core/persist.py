"""On-disk index persistence: the serialized page file as the product.

The paper's contribution is a co-designed *disk* layout (Sec 4.2/4.3), so
the saved artifact mirrors it literally:

  <dir>/manifest.json   versioned JSON: kind, config, geometry, build stats
  <dir>/pages.bin       the packed page records (``PageStore.recs``) as a
                        raw page-aligned f32 binary — each page record is
                        ``rows * 128 * 4`` bytes with ``rows`` a multiple
                        of 8, i.e. a whole number of 4 KB disk pages —
                        opened with ``np.memmap`` on load
  <dir>/arrays.npz      numpy sidecars: memory tier, LSH router, id maps,
                        per-page counts and neighbor ids

``save_index`` / ``load_index`` round-trip a :class:`PageANNIndex` to
bit-identical ``SearchResult``s; ``load_index`` dispatches on the
manifest's ``kind`` so any :class:`repro.core.protocol.VectorIndex`
implementation (PageANN or the DiskANN/Starling baselines) reloads through
one entry point. Host-side views that the search path never touches
(``PageStore.vecs`` / ``PageStore.nbr_codes``) are *not* persisted — they
are unpacked from the page file itself (``layout.unpack_member_vectors`` /
``unpack_neighbor_codes``), keeping the artifact a single copy of the disk
tier. (MEM_ALL is the exception for codes: its records drop the code rows,
so the codes ride the npz.)
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import layout as layout_mod
from repro.core import search as search_mod
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.lsh import LSHIndex

FORMAT = "repro.vector_index"
VERSION = 1

MANIFEST = "manifest.json"
PAGES_BIN = "pages.bin"
ARRAYS_NPZ = "arrays.npz"


def is_index_dir(directory: str) -> bool:
    return os.path.isfile(os.path.join(directory, MANIFEST))


def write_manifest(directory: str, doc: dict) -> None:
    doc = dict(doc, format=FORMAT, version=VERSION)
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)


def read_manifest(directory: str) -> dict:
    path = os.path.join(directory, MANIFEST)
    if not os.path.isfile(path):
        raise FileNotFoundError(f"no index manifest at {path}")
    with open(path) as f:
        doc = json.load(f)
    if doc.get("format") != FORMAT:
        raise ValueError(f"{path}: not a {FORMAT} manifest")
    if doc.get("version") != VERSION:
        raise ValueError(
            f"{path}: format version {doc.get('version')} "
            f"(this build reads version {VERSION})"
        )
    return doc


def config_to_json(cfg: PageANNConfig) -> dict:
    doc = dataclasses.asdict(cfg)
    doc["memory_mode"] = cfg.memory_mode.value
    return doc


def config_from_json(doc: dict) -> PageANNConfig:
    doc = dict(doc)
    doc["memory_mode"] = MemoryMode(doc["memory_mode"])
    return PageANNConfig(**doc)


# ------------------------------------------------------------------ PageANN
def save_pageann(index, directory: str) -> None:
    """Write a built :class:`PageANNIndex` under ``directory``."""
    os.makedirs(directory, exist_ok=True)
    store, tier, lsh = index.store, index.tier, index.lsh

    recs = np.ascontiguousarray(np.asarray(store.recs, np.float32))
    recs.tofile(os.path.join(directory, PAGES_BIN))

    sidecars = {}
    if index.cfg.memory_mode == MemoryMode.MEM_ALL:
        # MEM_ALL records carry no code rows, so the host-side codes view
        # is not recoverable from pages.bin — persist it explicitly
        sidecars["nbr_codes"] = np.asarray(store.nbr_codes)
    np.savez(
        os.path.join(directory, ARRAYS_NPZ),
        **sidecars,
        member_count=np.asarray(store.member_count),
        nbr_ids=np.asarray(store.nbr_ids),
        nbr_count=np.asarray(store.nbr_count),
        new_to_old=np.asarray(store.new_to_old),
        old_to_new=np.asarray(store.old_to_new),
        mem_codes=np.asarray(tier.mem_codes),
        mem_mask=np.asarray(tier.mem_mask),
        mem_codebooks=np.asarray(tier.mem_codebooks),
        disk_codebooks=np.asarray(tier.disk_codebooks),
        cached_pages=np.asarray(tier.cached_pages),
        lsh_planes=np.asarray(lsh.planes),
        lsh_sample_ids=np.asarray(lsh.sample_ids),
        lsh_sample_codes=np.asarray(lsh.sample_codes),
        lsh_sample_pq=np.asarray(lsh.sample_pq),
    )

    pages, rows, lanes = recs.shape
    write_manifest(
        directory,
        dict(
            kind="pageann",
            config=config_to_json(index.cfg),
            pages=pages,
            record_rows=rows,
            record_lanes=lanes,
            page_record_bytes=rows * lanes * 4,
            capacity=store.capacity,
            dim=store.dim,
            stats=dataclasses.asdict(index.stats),
        ),
    )


def load_pageann(directory: str):
    """Reload a saved index; search results are bit-identical to the
    in-memory index that was saved."""
    from repro.core.index import BuildStats, PageANNIndex

    doc = read_manifest(directory)
    if doc["kind"] != "pageann":
        raise ValueError(f"{directory}: kind={doc['kind']!r}, not a PageANN index")
    cfg = config_from_json(doc["config"])

    # the literal paper disk layout: raw page-aligned records via memmap
    recs_mm = np.memmap(
        os.path.join(directory, PAGES_BIN),
        dtype=np.float32,
        mode="r",
        shape=(doc["pages"], doc["record_rows"], doc["record_lanes"]),
    )
    with np.load(os.path.join(directory, ARRAYS_NPZ)) as z:
        arrays = {name: z[name] for name in z.files}

    if "nbr_codes" in arrays:                     # MEM_ALL sidecar
        nbr_codes = arrays["nbr_codes"]
    else:                                         # recover from the records
        nbr_codes = layout_mod.unpack_neighbor_codes(
            recs_mm, doc["capacity"], doc["dim"],
            rp=arrays["nbr_ids"].shape[1], m=cfg.pq_subspaces,
        )
    store = layout_mod.PageStore(
        vecs=layout_mod.unpack_member_vectors(
            recs_mm, doc["capacity"], doc["dim"]
        ),
        member_count=jnp.asarray(arrays["member_count"]),
        nbr_ids=jnp.asarray(arrays["nbr_ids"]),
        nbr_codes=nbr_codes,
        nbr_count=jnp.asarray(arrays["nbr_count"]),
        recs=jnp.asarray(recs_mm),
        capacity=doc["capacity"],
        dim=doc["dim"],
        new_to_old=arrays["new_to_old"],
        old_to_new=arrays["old_to_new"],
    )
    tier = layout_mod.MemoryTier(
        mem_codes=jnp.asarray(arrays["mem_codes"]),
        mem_mask=jnp.asarray(arrays["mem_mask"]),
        mem_codebooks=jnp.asarray(arrays["mem_codebooks"]),
        disk_codebooks=jnp.asarray(arrays["disk_codebooks"]),
        cached_pages=jnp.asarray(arrays["cached_pages"]),
    )
    lsh = LSHIndex(
        planes=jnp.asarray(arrays["lsh_planes"]),
        sample_ids=jnp.asarray(arrays["lsh_sample_ids"]),
        sample_codes=jnp.asarray(arrays["lsh_sample_codes"]),
        sample_pq=jnp.asarray(arrays["lsh_sample_pq"]),
    )
    return PageANNIndex(
        cfg=cfg,
        store=store,
        tier=tier,
        lsh=lsh,
        data=search_mod.make_search_data(store, tier, lsh),
        stats=BuildStats(**doc["stats"]),
    )


# ----------------------------------------------------------------- dispatch
def load_index(directory: str):
    """Load whichever :class:`VectorIndex` implementation saved ``directory``."""
    from repro.core import baselines as bl

    kind = read_manifest(directory)["kind"]
    if kind == "pageann":
        return load_pageann(directory)
    if kind in bl.BASELINE_KINDS:
        return bl.load_baseline(directory)
    raise ValueError(f"{directory}: unknown index kind {kind!r}")
