"""On-disk index persistence: the serialized page file as the product.

The paper's contribution is a co-designed *disk* layout (Sec 4.2/4.3), so
the saved artifact mirrors it literally:

  <dir>/manifest.json   versioned JSON: kind, config, geometry, build stats
  <dir>/pages.bin       the packed page records (``PageStore.recs``) as a
                        raw page-aligned f32 binary — each page record is
                        ``rows * 128 * 4`` bytes with ``rows`` a multiple
                        of 8, i.e. a whole number of 4 KB disk pages —
                        opened with ``np.memmap`` on load
  <dir>/arrays.npz      numpy sidecars: memory tier, LSH router, id maps,
                        per-page counts and neighbor ids

``save_index`` / ``load_index`` round-trip a :class:`PageANNIndex` to
bit-identical ``SearchResult``s; ``load_index`` dispatches on the
manifest's ``kind`` so any :class:`repro.core.protocol.VectorIndex`
implementation (PageANN, the DiskANN/Starling baselines, or a mutable
index) reloads through one entry point. One level up,
``save_database`` / ``load_database`` persist a whole multi-collection
service as ``db.json`` (collection name -> subdirectory, versioned the
same way) over ordinary per-collection artifacts — the on-disk form of
:class:`repro.serve.service.VectorService`. A mutable index
(:class:`repro.core.delta.MutableIndex`) persists as kind="mutable": the
frozen base as a nested artifact under ``base/`` plus a ``delta.npz``
sidecar (inserted vectors + liveness + tombstones + external id map) and a
manifest ``generation`` counter; compaction replaces the whole directory
atomically (``swap_mutable``: sibling tmp dir + two renames). Unreadable
artifacts — truncated ``pages.bin``, garbled manifests, versions ahead of
this build — raise :class:`IndexFormatError` naming what was found vs
supported. Host-side views that the search path never touches
(``PageStore.vecs`` / ``PageStore.nbr_codes``) are *not* persisted — they
are unpacked from the page file itself (``layout.unpack_member_vectors`` /
``unpack_neighbor_codes``), keeping the artifact a single copy of the disk
tier. (MEM_ALL is the exception for codes: its records drop the code rows,
so the codes ride the npz.)
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import layout as layout_mod
from repro.core import search as search_mod
from repro.core.config import MemoryMode, PageANNConfig
from repro.core.lsh import LSHIndex

FORMAT = "repro.vector_index"
VERSION = 1

MANIFEST = "manifest.json"
PAGES_BIN = "pages.bin"
ARRAYS_NPZ = "arrays.npz"
META_NPZ = "meta.npz"
DELTA_NPZ = "delta.npz"
BASE_SUBDIR = "base"

# ---- database layout (a directory of named collections, see save_database)
DB_FORMAT = "repro.vector_database"
DB_VERSION = 1
DB_MANIFEST = "db.json"
DB_COLLECTIONS_SUBDIR = "collections"

# collection names double as artifact subdirectory names, so they are
# restricted to a filesystem- and manifest-safe alphabet up front — a
# rejected create_collection beats a corrupted db.json or a path traversal
_NAME_ALLOWED = (
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789._-"
)


def check_collection_name(name: str) -> str:
    """Validate a collection name (also used as its on-disk subdirectory):
    1-64 chars of [A-Za-z0-9._-], not starting with a dot or dash."""
    if (
        not isinstance(name, str)
        or not 0 < len(name) <= 64
        or name[0] in ".-"
        or any(c not in _NAME_ALLOWED for c in name)
    ):
        raise ValueError(
            f"invalid collection name {name!r}: need 1-64 chars of "
            "[A-Za-z0-9._-] not starting with '.' or '-'"
        )
    return name


class IndexFormatError(ValueError):
    """A saved index artifact this library cannot read: corrupted or
    truncated files, a missing/garbled manifest, or a format version ahead
    of what this build supports. Subclasses ``ValueError`` so older
    call sites catching that keep working."""


def is_index_dir(directory: str) -> bool:
    return os.path.isfile(os.path.join(directory, MANIFEST))


def write_manifest(directory: str, doc: dict) -> None:
    doc = dict(doc, format=FORMAT, version=VERSION)
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)


def read_manifest(directory: str) -> dict:
    path = os.path.join(directory, MANIFEST)
    if not os.path.isfile(path):
        raise FileNotFoundError(f"no index manifest at {path}")
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        raise IndexFormatError(f"{path}: manifest is not valid JSON: {e}")
    if doc.get("format") != FORMAT:
        raise IndexFormatError(f"{path}: not a {FORMAT} manifest")
    found = doc.get("version")
    if found != VERSION:
        ahead = isinstance(found, int) and found > VERSION
        hint = (
            "; artifact was written by a newer library — upgrade to read it"
            if ahead else ""
        )
        raise IndexFormatError(
            f"{path}: found format version {found}, this build supports "
            f"version {VERSION}{hint}"
        )
    return doc


def _check_pages_bin(directory: str, doc: dict) -> str:
    """The page file must exist and hold exactly the manifest's geometry —
    a truncated copy must fail loudly here, not as a numpy reshape error
    deep in ``np.memmap``."""
    path = os.path.join(directory, PAGES_BIN)
    if not os.path.isfile(path):
        raise IndexFormatError(f"{path}: missing page file")
    want = doc["pages"] * doc["record_rows"] * doc["record_lanes"] * 4
    got = os.path.getsize(path)
    if got != want:
        raise IndexFormatError(
            f"{path}: corrupted or truncated page file — {got} bytes on "
            f"disk, manifest geometry needs {want} "
            f"({doc['pages']} pages x {doc['page_record_bytes']} B)"
        )
    return path


def _schema_to_json(index) -> dict | None:
    """The manifest ``schema`` section: field declaration + tag
    vocabulary. ``None`` when the index carries no metadata."""
    schema = getattr(index, "schema", None)
    if schema is None:
        return None
    doc = schema.to_json()
    doc["vocab"] = {f: list(vs) for f, vs in index.vocab.items()}
    return doc


def _load_meta(directory: str, doc: dict, store):
    """Reconstruct (schema, vocab, meta, meta_host) from the manifest
    ``schema`` section + ``meta.npz`` sidecar. The two must agree — a
    sidecar swapped in from another collection (or a manifest edited by
    hand) fails here as :class:`IndexFormatError`, not as a shape error
    deep inside the first filtered search."""
    from repro.core import filter as filter_mod
    from repro.core.filter import MetaArrays, MetadataSchema

    schema_doc = doc.get("schema")
    path = os.path.join(directory, META_NPZ)
    if schema_doc is None:
        if os.path.isfile(path):
            raise IndexFormatError(
                f"{path}: metadata sidecar present but the manifest has "
                "no schema section"
            )
        return None, {}, None, None
    if not os.path.isfile(path):
        raise IndexFormatError(
            f"{path}: manifest declares a metadata schema but the "
            "metadata sidecar is missing"
        )
    try:
        schema = MetadataSchema.from_json(schema_doc)
        vocab = {
            f: tuple(vs) for f, vs in schema_doc.get("vocab", {}).items()
        }
    except (TypeError, ValueError, AttributeError) as e:
        raise IndexFormatError(
            f"{directory}: garbled manifest schema section: {e}"
        )
    unknown = sorted(set(vocab) - set(schema.tags))
    if unknown:
        raise IndexFormatError(
            f"{directory}: manifest vocab names fields not in the "
            f"schema: {unknown}"
        )
    with np.load(path) as z:
        if not {"tags", "nums"} <= set(z.files):
            raise IndexFormatError(
                f"{path}: metadata sidecar is missing arrays "
                f"(found {sorted(z.files)}, need ['nums', 'tags'])"
            )
        slot_tags = np.asarray(z["tags"], np.int32)
        slot_nums = np.asarray(z["nums"], np.float32)
    rows = int(np.asarray(store.new_to_old).shape[0])  # pages * capacity
    want_tags = (rows, len(schema.tags))
    want_nums = (rows, len(schema.numerics))
    if slot_tags.shape != want_tags or slot_nums.shape != want_nums:
        raise IndexFormatError(
            f"{path}: metadata shapes {slot_tags.shape}/{slot_nums.shape} "
            f"disagree with the manifest schema — expected "
            f"{want_tags}/{want_nums}"
        )
    host_tags, host_nums = layout_mod.unreassign_metadata(
        slot_tags, slot_nums, store
    )
    return (
        schema,
        vocab,
        MetaArrays(tags=jnp.asarray(slot_tags), nums=jnp.asarray(slot_nums)),
        MetaArrays(tags=host_tags, nums=host_nums),
    )


def config_to_json(cfg: PageANNConfig) -> dict:
    doc = dataclasses.asdict(cfg)
    doc["memory_mode"] = cfg.memory_mode.value
    return doc


def config_from_json(doc: dict) -> PageANNConfig:
    doc = dict(doc)
    doc["memory_mode"] = MemoryMode(doc["memory_mode"])
    return PageANNConfig(**doc)


# ------------------------------------------------------------------ PageANN
def save_pageann(index, directory: str) -> None:
    """Write a built :class:`PageANNIndex` under ``directory``."""
    os.makedirs(directory, exist_ok=True)
    store, tier, lsh = index.store, index.tier, index.lsh

    # a streamed store's device ``recs`` holds only the resident subset;
    # the host memmap is the full page file and the source of truth
    recs_full = store.recs_host if store.recs_host is not None else store.recs
    recs = np.ascontiguousarray(np.asarray(recs_full, np.float32))
    recs.tofile(os.path.join(directory, PAGES_BIN))

    sidecars = {}
    if index.cfg.memory_mode == MemoryMode.MEM_ALL:
        # MEM_ALL records carry no code rows, so the host-side codes view
        # is not recoverable from pages.bin — persist it explicitly
        sidecars["nbr_codes"] = np.asarray(store.nbr_codes)
    page_order = getattr(index, "page_order", None)
    if page_order is not None:
        # full hotness ordering (warm_cache access counts, hottest first):
        # the residency policy a budgeted load pins pages by
        sidecars["page_order"] = np.asarray(page_order, np.int32)
    np.savez(
        os.path.join(directory, ARRAYS_NPZ),
        **sidecars,
        member_count=np.asarray(store.member_count),
        nbr_ids=np.asarray(store.nbr_ids),
        nbr_count=np.asarray(store.nbr_count),
        new_to_old=np.asarray(store.new_to_old),
        old_to_new=np.asarray(store.old_to_new),
        mem_codes=np.asarray(tier.mem_codes),
        mem_mask=np.asarray(tier.mem_mask),
        mem_codebooks=np.asarray(tier.mem_codebooks),
        disk_codebooks=np.asarray(tier.disk_codebooks),
        cached_pages=np.asarray(tier.cached_pages),
        lsh_planes=np.asarray(lsh.planes),
        lsh_sample_ids=np.asarray(lsh.sample_ids),
        lsh_sample_codes=np.asarray(lsh.sample_codes),
        lsh_sample_pq=np.asarray(lsh.sample_pq),
    )
    if getattr(index, "schema", None) is not None:
        # page-slot-aligned metadata columns ride their own sidecar: the
        # same row order as pages.bin, so a page's metadata is one
        # contiguous slice at the page's slot offsets
        np.savez(
            os.path.join(directory, META_NPZ),
            tags=np.asarray(index.meta.tags, np.int32),
            nums=np.asarray(index.meta.nums, np.float32),
        )

    pages, rows, lanes = recs.shape
    write_manifest(
        directory,
        dict(
            kind="pageann",
            config=config_to_json(index.cfg),
            pages=pages,
            record_rows=rows,
            record_lanes=lanes,
            page_record_bytes=rows * lanes * 4,
            capacity=store.capacity,
            dim=store.dim,
            stats=dataclasses.asdict(index.stats),
            # warm-cache persistence: the hot page ids ride the manifest so
            # a loaded server starts with the builder's warmed cache
            hot_pages=np.asarray(tier.cached_pages).tolist(),
            # residency metadata: how THIS index was loaded/built. The
            # budget round-trips so a re-saved streamed index records its
            # provenance; a fresh load still chooses its own budget.
            residency=dict(
                memory_budget=(
                    index.memory_budget.to_json()
                    if getattr(index, "memory_budget", None) is not None
                    else None
                ),
                resident_pages=store.resident_pages,
                total_pages=pages,
            ),
            # autotuned operating points (index.autotune): measured
            # {params, recall, qps, ...} entries plus which one serving
            # should resolve as the default SearchParams
            tuned=_tuned_to_json(index),
            # metadata declaration + tag vocabulary (None: no metadata);
            # the encoded columns themselves live in meta.npz
            schema=_schema_to_json(index),
        ),
    )


def _tuned_to_json(index) -> dict:
    points = []
    for m in getattr(index, "tuned", []) or []:
        doc = {
            key: val for key, val in m.items() if key != "params"
        }
        doc["params"] = m["params"].to_json()
        points.append(doc)
    default = getattr(index, "tuned_default", None)
    return dict(
        default=default.to_json() if default is not None else None,
        points=points,
    )


def _tuned_from_json(doc: dict | None) -> tuple[list, "SearchParams | None"]:
    from repro.core.config import SearchParams

    if not doc:            # pre-adaptive artifacts carry no tuned section
        return [], None
    points = []
    for entry in doc.get("points", []):
        m = dict(entry)
        m["params"] = SearchParams.from_json(m["params"])
        points.append(m)
    default = doc.get("default")
    return points, (
        SearchParams.from_json(default) if default is not None else None
    )


def _page_order_of(doc: dict, arrays: dict) -> np.ndarray:
    """Full residency priority, hottest page first: the persisted
    ``page_order`` sidecar (warm_cache access counts) when the artifact
    carries one, else the manifest's hot pages followed by the rest in id
    order — a valid (if unmeasured) policy for pre-streaming artifacts."""
    pages = int(doc["pages"])
    if "page_order" in arrays:
        return np.asarray(arrays["page_order"], np.int32)
    hot = np.asarray(doc.get("hot_pages", []), np.int32)
    rest = np.setdiff1d(np.arange(pages, dtype=np.int32), hot)
    return np.concatenate([hot, rest])[:pages]


def load_pageann(directory: str, *, memory_budget=None):
    """Reload a saved index; search results are bit-identical to the
    in-memory index that was saved.

    ``memory_budget`` (a :class:`repro.core.config.MemoryBudget`, or None)
    caps the device-resident page-record region: the hottest pages that fit
    are pinned on device, every other page stays in the ``pages.bin``
    memmap and is fetched per hop through a :class:`core.stream.PageFetcher`
    host callback. Results stay bit-identical to a fully resident load —
    only where the record bytes are gathered from changes. ``None`` (the
    default) is always fully resident, today's behavior."""
    from repro.core import stream as stream_mod
    from repro.core.config import MemoryBudget
    from repro.core.index import BuildStats, PageANNIndex

    doc = read_manifest(directory)
    if doc["kind"] != "pageann":
        raise ValueError(f"{directory}: kind={doc['kind']!r}, not a PageANN index")
    cfg = config_from_json(doc["config"])

    # the literal paper disk layout: raw page-aligned records via memmap
    pages_path = _check_pages_bin(directory, doc)
    recs_mm = np.memmap(
        pages_path,
        dtype=np.float32,
        mode="r",
        shape=(doc["pages"], doc["record_rows"], doc["record_lanes"]),
    )
    with np.load(os.path.join(directory, ARRAYS_NPZ)) as z:
        arrays = {name: z[name] for name in z.files}

    if "nbr_codes" in arrays:                     # MEM_ALL sidecar
        nbr_codes = arrays["nbr_codes"]
    else:                                         # recover from the records
        nbr_codes = layout_mod.unpack_neighbor_codes(
            recs_mm, doc["capacity"], doc["dim"],
            rp=arrays["nbr_ids"].shape[1], m=cfg.pq_subspaces,
        )

    page_order = _page_order_of(doc, arrays)
    num_pages = int(doc["pages"])
    fetcher = None
    if memory_budget is not None:
        memory_budget = MemoryBudget.parse(memory_budget)
        n_res = memory_budget.resolve_pages(
            num_pages, int(doc["page_record_bytes"])
        )
    else:
        n_res = num_pages
    if n_res >= num_pages:
        # everything fits: plain fully resident load (identity residency,
        # no fetcher) — shares compiled executables with unbudgeted loads
        resident_map = None
        recs_dev = jnp.asarray(recs_mm)
        recs_host = None
    else:
        # pin the hottest pages that fit; sort the kept ids so the device
        # region preserves relative page order (gather locality)
        resident_ids = np.sort(page_order[:n_res])
        rmap = np.full(num_pages, stream_mod.PAD, np.int32)
        rmap[resident_ids] = np.arange(n_res, dtype=np.int32)
        resident_map = jnp.asarray(rmap)
        recs_dev = jnp.asarray(np.asarray(recs_mm[resident_ids], np.float32))
        recs_host = recs_mm
        fetcher = stream_mod.PageFetcher(recs_mm)

    store = layout_mod.PageStore(
        vecs=layout_mod.unpack_member_vectors(
            recs_mm, doc["capacity"], doc["dim"]
        ),
        member_count=jnp.asarray(arrays["member_count"]),
        nbr_ids=jnp.asarray(arrays["nbr_ids"]),
        nbr_codes=nbr_codes,
        nbr_count=jnp.asarray(arrays["nbr_count"]),
        recs=recs_dev,
        capacity=doc["capacity"],
        dim=doc["dim"],
        new_to_old=arrays["new_to_old"],
        old_to_new=arrays["old_to_new"],
        resident_map=resident_map,
        recs_host=recs_host,
    )
    # warm-cache persistence: the manifest's hot page ids pre-populate the
    # cache so a restarted server serves the first query warm (the npz copy
    # is the fallback for artifacts saved before hot_pages existed)
    hot = np.asarray(
        doc.get("hot_pages", arrays["cached_pages"]), np.int32
    )
    tier = layout_mod.MemoryTier(
        mem_codes=jnp.asarray(arrays["mem_codes"]),
        mem_mask=jnp.asarray(arrays["mem_mask"]),
        mem_codebooks=jnp.asarray(arrays["mem_codebooks"]),
        disk_codebooks=jnp.asarray(arrays["disk_codebooks"]),
        cached_pages=jnp.asarray(np.sort(hot)),
    )
    lsh = LSHIndex(
        planes=jnp.asarray(arrays["lsh_planes"]),
        sample_ids=jnp.asarray(arrays["lsh_sample_ids"]),
        sample_codes=jnp.asarray(arrays["lsh_sample_codes"]),
        sample_pq=jnp.asarray(arrays["lsh_sample_pq"]),
    )
    # stats.disk_bytes reports the persisted artifact as it sits on disk,
    # not a recomputation from device arrays (see BuildStats docstring)
    stats = BuildStats(**doc["stats"])
    stats.disk_bytes = os.path.getsize(pages_path)
    stats.resident_pages = store.resident_pages
    stats.resident_bytes = store.resident_bytes
    tuned, tuned_default = _tuned_from_json(doc.get("tuned"))
    schema, vocab, meta, meta_host = _load_meta(directory, doc, store)
    return PageANNIndex(
        cfg=cfg,
        store=store,
        tier=tier,
        lsh=lsh,
        data=search_mod.make_search_data(store, tier, lsh),
        stats=stats,
        fetcher=fetcher,
        page_order=page_order,
        memory_budget=memory_budget,
        tuned=tuned,
        tuned_default=tuned_default,
        schema=schema,
        vocab=vocab,
        meta=meta,
        meta_host=meta_host,
    )


# ----------------------------------------------------------------- mutable
def save_mutable(state, directory: str) -> None:
    """Write a :class:`repro.core.delta.MutableIndex` state under
    ``directory``: the frozen base as a full nested artifact plus a
    ``delta.npz`` sidecar (inserted vectors, liveness, tombstones, external
    id map) — a restarted server reloads the dirty index losslessly."""
    os.makedirs(directory, exist_ok=True)
    state.base.save(os.path.join(directory, BASE_SUBDIR))
    dv = state.delta
    c = dv.count
    extra = {}
    if getattr(state.base, "schema", None) is not None:
        extra = dict(
            delta_tags=np.asarray(dv.tags[:c], np.int32),
            delta_nums=np.asarray(dv.nums[:c], np.float32),
        )
    np.savez(
        os.path.join(directory, DELTA_NPZ),
        delta_vecs=np.asarray(dv.vecs[:c], np.float32),
        delta_ids=np.asarray(dv.ids[:c], np.int64),
        delta_live=np.asarray(dv.live[:c], bool),
        tombstones=np.asarray(state.tombstones, np.int64),
        base_ids=np.asarray(state.base_ids, np.int64),
        **extra,
    )
    write_manifest(
        directory,
        dict(
            kind="mutable",
            base_kind=read_manifest(os.path.join(directory, BASE_SUBDIR))[
                "kind"
            ],
            dim=state.base.dim,
            generation=state.generation,
            base_rows=int(state.base_ids.size),
            delta_rows=int(c),
            delta_live=int(dv.n_live),
            tombstones=int(state.tombstones.size),
            # the UNIFIED vocabulary (base + values seen only in delta
            # inserts) — delta tag codes are positions in these tuples
            vocab=(
                {f: list(vs) for f, vs in state.vocab.items()}
                if state.vocab is not None else None
            ),
        ),
    )


def swap_mutable(state, directory: str) -> None:
    """Replace the artifact at ``directory`` with ``state`` (the
    compaction swap): write a sibling tmp dir, then two renames. Both
    sides of the swap are always intact on disk — no reader ever sees a
    half-written directory, and in-process readers holding memmaps of the
    old files keep valid fds. The canonical path is briefly absent between
    the two renames: a crash in that window leaves the previous artifact
    complete under ``<dir>.old.<gen>`` (and the new one under
    ``<dir>.tmp.<gen>``); the next swap — or a manual rename — recovers
    it. Stale ``.tmp``/``.old`` siblings from any crashed earlier swap are
    swept first."""
    import glob
    import shutil

    clean = directory.rstrip(os.sep)
    for leftover in glob.glob(f"{glob.escape(clean)}.tmp.*") + glob.glob(
        f"{glob.escape(clean)}.old.*"
    ):
        if os.path.isdir(leftover):
            shutil.rmtree(leftover)
    tmp = f"{clean}.tmp.{state.generation}"
    old = f"{clean}.old.{state.generation}"
    save_mutable(state, tmp)
    os.rename(clean, old)
    os.rename(tmp, clean)
    shutil.rmtree(old)


def load_mutable(directory: str, *, memory_budget=None):
    """Reload a saved mutable index (base + delta sidecar); searches on
    the loaded index are bit-identical to the saved dirty state.
    ``memory_budget`` applies to the frozen base tier (the delta tier is
    in-memory by construction)."""
    from repro.core.delta import MutableIndex

    doc = read_manifest(directory)
    if doc["kind"] != "mutable":
        raise ValueError(
            f"{directory}: kind={doc['kind']!r}, not a mutable index"
        )
    base = load_index(
        os.path.join(directory, BASE_SUBDIR), memory_budget=memory_budget
    )
    npz_path = os.path.join(directory, DELTA_NPZ)
    if not os.path.isfile(npz_path):
        raise IndexFormatError(f"{npz_path}: missing delta sidecar")
    with np.load(npz_path) as z:
        arrays = {name: z[name] for name in z.files}

    index = MutableIndex(base, base_ids=arrays["base_ids"])
    live = arrays["delta_live"]
    if live.size:
        # restore the append log verbatim (the log may hold dead rows for
        # superseded/deleted ids): slot numbering — and thus scan output —
        # is bit-identical to the saved index
        c = int(live.size)
        tier = index._delta
        tier._grow(c)
        tier._vecs[:c] = arrays["delta_vecs"]
        tier._ids[:c] = arrays["delta_ids"]
        tier._live[:c] = live
        if "delta_tags" in arrays:
            tier._tags[:c] = arrays["delta_tags"]
            tier._nums[:c] = arrays["delta_nums"]
        tier._count = c
        tier._slot_of = {
            int(arrays["delta_ids"][i]): i for i in range(c) if live[i]
        }
        tier._view = None
    vocab_doc = doc.get("vocab")
    if vocab_doc is not None:
        # the persisted UNIFIED vocabulary supersedes the base's copy the
        # constructor installed — delta tag codes index into this one
        index._vocab = {f: tuple(vs) for f, vs in vocab_doc.items()}
    index._state = index._state._replace(
        tombstones=np.asarray(arrays["tombstones"], np.int64),
        delta=index._delta.snapshot(),
        generation=int(doc.get("generation", 0)),
        vocab=dict(index._vocab) if vocab_doc is not None else (
            index._state.vocab
        ),
    )
    index._next_id = int(
        max(
            arrays["base_ids"].max(initial=-1),
            arrays["delta_ids"].max(initial=-1),
        )
        + 1
    )
    index._directory = directory
    return index


# ----------------------------------------------------------------- database
def is_database_dir(directory: str) -> bool:
    return os.path.isfile(os.path.join(directory, DB_MANIFEST))


def read_db_manifest(directory: str) -> dict:
    """Read and validate ``db.json`` (versioned exactly like index
    manifests: wrong format / garbled JSON / version-ahead all raise
    :class:`IndexFormatError` naming found vs supported)."""
    path = os.path.join(directory, DB_MANIFEST)
    if not os.path.isfile(path):
        raise FileNotFoundError(f"no database manifest at {path}")
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        raise IndexFormatError(f"{path}: database manifest is not valid JSON: {e}")
    if doc.get("format") != DB_FORMAT:
        raise IndexFormatError(f"{path}: not a {DB_FORMAT} manifest")
    found = doc.get("version")
    if found != DB_VERSION:
        ahead = isinstance(found, int) and found > DB_VERSION
        hint = (
            "; database was written by a newer library — upgrade to read it"
            if ahead else ""
        )
        raise IndexFormatError(
            f"{path}: found database version {found}, this build supports "
            f"version {DB_VERSION}{hint}"
        )
    if not isinstance(doc.get("collections"), dict):
        raise IndexFormatError(f"{path}: manifest has no collections table")
    return doc


def _collection_subdir(name: str) -> str:
    # stored with a literal "/" so db.json is platform-independent
    return f"{DB_COLLECTIONS_SUBDIR}/{name}"


def save_database(collections, directory: str) -> None:
    """Persist a whole multi-collection service under one directory:

      <dir>/db.json                versioned JSON: collection name -> subdir
      <dir>/collections/<name>/    one full per-collection index artifact
                                   (whatever kind each index persists as)

    ``collections`` maps name -> any ``VectorIndex`` with ``save``.  For a
    FRESH directory the manifest is written last (atomically: tmp +
    rename), so a crash mid-save leaves a directory that ``load_database``
    refuses (no db.json) rather than a silently partial database.
    Re-saving over an existing database overwrites the per-collection
    artifacts in place under the still-valid old manifest — for an atomic
    replacement of a live database, save to a fresh sibling directory and
    rename (the ``swap_mutable`` pattern).  Round-trips through
    :func:`load_database` / ``repro.serve.VectorService.load``.
    """
    for name in collections:
        check_collection_name(name)
    os.makedirs(directory, exist_ok=True)
    table = {}
    for name, index in sorted(collections.items()):
        sub = _collection_subdir(name)
        index.save(os.path.join(directory, DB_COLLECTIONS_SUBDIR, name))
        table[name] = sub
    doc = dict(format=DB_FORMAT, version=DB_VERSION, collections=table)
    path = os.path.join(directory, DB_MANIFEST)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def load_database(directory: str, *, memory_budget=None) -> dict:
    """Reload every collection of a saved database: name -> loaded
    ``VectorIndex`` (each dispatched through :func:`load_index` on its
    manifest kind). Searches on the loaded indexes are bit-identical to
    the saved ones. ``memory_budget`` applies PER COLLECTION (each
    collection's page tier is capped independently).

    Artifact paths are derived from the VALIDATED collection names, never
    from manifest values: a tampered ``db.json`` mapping a name outside
    ``collections/`` is rejected, not followed."""
    doc = read_db_manifest(directory)
    out = {}
    for name, sub in sorted(doc["collections"].items()):
        check_collection_name(name)
        want = _collection_subdir(name)
        if sub != want:
            raise IndexFormatError(
                f"{directory}: collection {name!r} maps to unexpected "
                f"path {sub!r} (expected {want!r})"
            )
        out[name] = load_index(
            os.path.join(directory, DB_COLLECTIONS_SUBDIR, name),
            memory_budget=memory_budget,
        )
    return out


# ----------------------------------------------------------------- dispatch
def load_index(directory: str, *, memory_budget=None):
    """Load whichever :class:`VectorIndex` implementation saved ``directory``.

    ``memory_budget`` (``MemoryBudget`` | bytes | fraction | spec string |
    None) caps the device-resident page region of indexes with a page tier
    (PageANN, and the base tier of a mutable index); ``None`` keeps
    everything resident. Baseline kinds have no page tier and reject a
    budget loudly rather than silently ignoring it."""
    from repro.core import baselines as bl

    kind = read_manifest(directory)["kind"]
    if kind == "pageann":
        return load_pageann(directory, memory_budget=memory_budget)
    if kind == "mutable":
        return load_mutable(directory, memory_budget=memory_budget)
    if kind == "sharded":
        # lazy: repro.dist sits above core and imports this module
        from repro.dist.sharded import ShardedPageStore

        return ShardedPageStore.load(directory, memory_budget=memory_budget)
    if kind in bl.BASELINE_KINDS:
        if memory_budget is not None:
            raise ValueError(
                f"{directory}: kind={kind!r} baseline indexes are fully "
                "in-memory; memory_budget is not supported"
            )
        return bl.load_baseline(directory)
    raise ValueError(f"{directory}: unknown index kind {kind!r}")
