"""Mutable index: in-memory delta tier + tombstones over a frozen base.

The persisted page-aligned artifact (``core.persist``) is immutable — the
paper's layout is compiled at build time. This module makes the *index*
mutable without touching that hot path, the way LSM-ish disk-graph systems
(FreshDiskANN-style) do:

  * :class:`DeltaTier` — an append-only in-memory buffer of freshly
    inserted vectors. It has no graph: queries brute-force-scan it through
    the batched L2 kernel path (``kernels.ops.delta_scan``), exact by
    construction. Buffers grow by doubling and the scanned slice is padded
    to a power of two, so the jitted scan compiles O(log n) shapes.
  * tombstones — deleted ids are masked out of base-search results (the
    disk artifact is never rewritten per delete). The base search is
    oversampled by the tombstone count rounded to a power of two
    (:class:`repro.core.config.DeltaParams.max_tombstone_oversample` caps
    the bucket) so masking cannot leave fewer than k live results.
  * :class:`MutableIndex` — a :class:`repro.core.protocol.VectorIndex`
    that fans each query out to the persisted page-file search and the
    delta scan, masks tombstoned base hits, and merges the two top-k
    streams with ``lax.top_k`` (``core.search.merge_topk_streams``).
    ``insert`` / ``delete`` / ``compact`` make it writable; results carry
    EXTERNAL ids (stable across compactions).
  * ``compact()`` — rebuilds the base over (base ∪ inserts − deletes)
    through the existing page_graph/layout pipeline and, when the index is
    persisted, atomically swaps the on-disk artifact (tmp dir + rename,
    manifest generation counter — see ``persist.save_mutable``).

Concurrency model: every piece of state a search touches lives in ONE
immutable :class:`_MutableState` tuple; ``search`` reads the current tuple
(a single atomic attribute load) and never takes the lock, so searches
in-flight across an ``insert``/``delete``/``compact`` always see a fully
consistent (base, tombstones, delta) snapshot — never a half-swapped
artifact. Writers serialize on the index lock; ``compact`` holds it for
the rebuild, so writes (not reads) stall during compaction.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import filter as filter_mod
from repro.core import search as search_mod
from repro.core.config import DeltaParams, SearchParams, resolve_search_params
from repro.core.filter import CompiledFilter, FilterExpr, MetaArrays
from repro.kernels import ops

PAD = -1


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class _DeviceCache:
    """Lazily materialized device copy of one delta snapshot.

    Writers would otherwise pay an O(delta) host->device upload per
    mutation while holding the index lock; instead the first *search*
    against a fresh snapshot uploads once, and every later search shares
    the buffers. Correct because the host vecs slice is append-only (rows
    past the snapshot's count may fill in later, but the live mask — a
    copy frozen at snapshot time — masks them dead in the scan).
    """

    def __init__(self, vecs: np.ndarray, live: np.ndarray):
        self._vecs = vecs
        self._live = live
        self._lock = threading.Lock()
        self._dev: tuple[jnp.ndarray, jnp.ndarray] | None = None

    def get(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        with self._lock:
            if self._dev is None:
                self._dev = (jnp.asarray(self._vecs), jnp.asarray(self._live))
            return self._dev


class DeltaView(NamedTuple):
    """An immutable snapshot of the delta tier (what a search reads).

    Host arrays are copies (``ids``/``live``) or append-only buffer slices
    whose rows past ``count`` are masked dead (``vecs``); the device copy
    is materialized lazily by the first search and shared until the next
    write. The padded length is a power of two so the jitted scan compiles
    a bounded number of shapes.
    """

    count: int                # rows appended (live or dead)
    n_live: int               # rows not superseded/deleted
    vecs: np.ndarray          # (Cpad, d) f32 host buffer slice
    ids: np.ndarray           # (Cpad,) int64 external ids, PAD padded
    live: np.ndarray          # (Cpad,) bool
    device: _DeviceCache      # lazy (vecs_dev, live_dev)
    tags: np.ndarray          # (Cpad, T) int32 tag codes, -1 padded
    nums: np.ndarray          # (Cpad, N) f32 numerics, NaN padded


class DeltaTier:
    """Append-only fresh-vector store with external-id upsert semantics.

    Not thread-safe by itself: :class:`MutableIndex` serializes writers and
    hands searches immutable :class:`DeltaView` snapshots. Re-inserting a
    live external id kills the superseded row (last write wins); ``kill``
    marks rows dead without reclaiming them — compaction is the reclaim.
    """

    def __init__(self, dim: int, capacity: int = 256, *,
                 n_tags: int = 0, n_nums: int = 0):
        cap = _pow2(max(int(capacity), 8))
        self.dim = int(dim)
        self.n_tags = int(n_tags)
        self.n_nums = int(n_nums)
        self._vecs = np.zeros((cap, self.dim), np.float32)
        self._ids = np.full((cap,), PAD, np.int64)
        self._live = np.zeros((cap,), bool)
        # metadata columns share the encoding invariants of the page-
        # aligned base tier: missing tag = -1, missing numeric = NaN, so
        # un-annotated (and padded) rows match no filter clause
        self._tags = np.full((cap, self.n_tags), -1, np.int32)
        self._nums = np.full((cap, self.n_nums), np.nan, np.float32)
        self._count = 0
        self._slot_of: dict[int, int] = {}   # live external id -> row
        self._view: DeltaView | None = None

    def __len__(self) -> int:
        return len(self._slot_of)

    @property
    def live_count(self) -> int:
        return len(self._slot_of)

    @property
    def memory_bytes(self) -> int:
        return int(self._vecs.nbytes + self._ids.nbytes + self._live.nbytes)

    def _grow(self, need: int) -> None:
        cap = self._ids.shape[0]
        if need <= cap:
            return
        new_cap = _pow2(need)
        # fresh buffers + copy: snapshots taken before the grow keep the old
        # buffer, whose first `count` rows never change again
        vecs = np.zeros((new_cap, self.dim), np.float32)
        ids = np.full((new_cap,), PAD, np.int64)
        live = np.zeros((new_cap,), bool)
        tags = np.full((new_cap, self.n_tags), -1, np.int32)
        nums = np.full((new_cap, self.n_nums), np.nan, np.float32)
        c = self._count
        vecs[:c], ids[:c], live[:c] = self._vecs[:c], self._ids[:c], self._live[:c]
        tags[:c], nums[:c] = self._tags[:c], self._nums[:c]
        self._vecs, self._ids, self._live = vecs, ids, live
        self._tags, self._nums = tags, nums

    def insert(self, vectors: np.ndarray, ids: np.ndarray, *,
               tags: np.ndarray | None = None,
               nums: np.ndarray | None = None) -> None:
        vectors = np.ascontiguousarray(vectors, np.float32).reshape(-1, self.dim)
        ids = np.asarray(ids, np.int64).reshape(-1)
        if vectors.shape[0] != ids.shape[0]:
            raise ValueError(
                f"{vectors.shape[0]} vectors for {ids.shape[0]} ids"
            )
        if np.unique(ids).shape[0] != ids.shape[0]:
            raise ValueError("duplicate ids within one insert batch")
        if (ids < 0).any():
            raise ValueError("ids must be non-negative")
        if (ids > np.iinfo(np.int32).max).any():
            # the device-side top-k merge carries ids as int32 (x64 is off
            # in jax); a wider id would silently wrap in search results
            raise ValueError("ids must fit int32 (the merge path's id space)")
        self.kill(ids)                        # last write wins
        n = ids.shape[0]
        self._grow(self._count + n)
        rows = slice(self._count, self._count + n)
        self._vecs[rows] = vectors
        self._ids[rows] = ids
        self._live[rows] = True
        if tags is not None:
            self._tags[rows] = np.asarray(tags, np.int32).reshape(
                n, self.n_tags
            )
        if nums is not None:
            self._nums[rows] = np.asarray(nums, np.float32).reshape(
                n, self.n_nums
            )
        for j, i in enumerate(ids.tolist()):
            self._slot_of[int(i)] = self._count + j
        self._count += n
        self._view = None

    def kill(self, ids: np.ndarray) -> int:
        """Mark rows of these external ids dead; returns how many were live."""
        killed = 0
        for i in np.asarray(ids, np.int64).reshape(-1).tolist():
            slot = self._slot_of.pop(int(i), None)
            if slot is not None:
                self._live[slot] = False
                killed += 1
        if killed:
            self._view = None
        return killed

    def snapshot(self) -> DeltaView:
        if self._view is None:
            cpad = _pow2(max(self._count, 8))
            vecs = self._vecs[:cpad]
            live = self._live[:cpad].copy()
            self._view = DeltaView(
                count=self._count,
                n_live=len(self._slot_of),
                vecs=vecs,
                ids=self._ids[:cpad].copy(),
                live=live,
                device=_DeviceCache(vecs, live),
                tags=self._tags[:cpad],
                nums=self._nums[:cpad],
            )
        return self._view


def scan_delta(
    view: DeltaView, queries: np.ndarray, k: int,
    cfilter: CompiledFilter | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k of the delta tier: (ids (Q, kk), dists (Q, kk)) with
    kk = min(k, padded rows); empty (Q, 0) streams when nothing is live.
    Non-finite distances carry PAD ids (fewer than kk live rows).
    ``cfilter`` masks rows failing the predicate exactly like dead rows —
    freshly inserted vectors are filterable immediately, no compaction
    needed. The row mask is evaluated host-side (the delta is in-memory
    and small by construction) so the jitted scan sees one extra (C,)
    bool input, not a recompiling static."""
    qn = queries.shape[0]
    if view.n_live == 0 or k == 0:
        return (
            np.full((qn, 0), PAD, np.int64),
            np.full((qn, 0), np.inf, np.float32),
        )
    vecs_dev, live_dev = view.device.get()
    kk = min(k, vecs_dev.shape[0])
    mask = None
    if cfilter is not None:
        mask = jnp.asarray(
            filter_mod.filter_mask_np(cfilter, view.tags, view.nums)
        )
    dists, slots = ops.delta_scan(
        jnp.asarray(queries, jnp.float32), vecs_dev, live_dev, kk, mask=mask
    )
    dists = np.asarray(dists)
    ids = view.ids[np.asarray(slots)]
    return np.where(np.isfinite(dists), ids, PAD), dists


class _MutableState(NamedTuple):
    """Everything a search reads, swapped atomically as one tuple."""

    base: Any                 # the frozen VectorIndex (PageANNIndex)
    base_ids: np.ndarray      # (n,) int64: base row -> external id
    identity: bool            # base_ids is arange(n) (no translation needed)
    tombstones: np.ndarray    # sorted int64 external ids deleted from base
    delta: DeltaView
    generation: int           # compaction counter (mirrors the manifest)
    vocab: dict | None = None  # unified tag vocabulary (None: no schema)


@dataclasses.dataclass
class MutableStats:
    """Footprint/shape of the mutable wrapper; ``base`` is the base index's
    own stats object (on-disk bytes included — see ``BuildStats.disk_bytes``)."""

    base: Any
    base_rows: int
    base_live: int
    delta_live: int
    tombstones: int
    delta_fraction: float
    generation: int
    delta_memory_bytes: int


class MutableIndex:
    """A writable :class:`VectorIndex` over a frozen base + delta tier.

    ``search`` results carry EXTERNAL ids: stable across compactions, equal
    to base row ids for an unwrapped index (``base_ids`` defaults to
    ``arange``). Compaction requires the base to expose ``cfg``,
    ``vectors_by_original_id()`` and a ``build`` classmethod —
    :class:`repro.core.index.PageANNIndex` does.
    """

    def __init__(
        self,
        base,
        base_ids: np.ndarray | None = None,
        *,
        params: DeltaParams | None = None,
        auto_compact: bool = True,
    ):
        if base_ids is None:
            store = getattr(base, "store", None)
            n = getattr(store, "num_vectors", None)
            if n is None:                      # baselines: stats carries it
                n = getattr(base.stats, "num_vectors", None)
            if n is None:
                raise ValueError(
                    "cannot infer the base row count; pass base_ids"
                )
            base_ids = np.arange(n, dtype=np.int64)
        base_ids = np.asarray(base_ids, np.int64).reshape(-1)
        if base_ids.size and int(base_ids.max()) > np.iinfo(np.int32).max:
            raise ValueError(
                "external ids must fit int32 (the merge path's id space)"
            )
        self.delta_params = params or DeltaParams()
        self.auto_compact = auto_compact
        self._lock = threading.RLock()
        self._directory: str | None = None
        # unified append-only vocabulary: starts as the base's, grows as
        # inserts carry unseen tag values. Base codes never move, so the
        # base tier keeps compiling filters against its own vocab while
        # the delta tier encodes/compiles against this superset.
        self._vocab: dict[str, tuple[str, ...]] = dict(
            getattr(base, "vocab", None) or {}
        )
        self._delta = self._new_delta(base)
        self._next_id = int(base_ids.max()) + 1 if base_ids.size else 0
        self._state = _MutableState(
            base=base,
            base_ids=base_ids,
            identity=bool(
                np.array_equal(base_ids, np.arange(base_ids.size))
            ),
            tombstones=np.empty((0,), np.int64),
            delta=self._delta.snapshot(),
            generation=0,
            vocab=(
                dict(self._vocab)
                if getattr(base, "schema", None) is not None else None
            ),
        )

    def _new_delta(self, base) -> DeltaTier:
        schema = getattr(base, "schema", None)
        return DeltaTier(
            base.dim,
            self.delta_params.min_capacity,
            n_tags=len(schema.tags) if schema is not None else 0,
            n_nums=len(schema.numerics) if schema is not None else 0,
        )

    # ------------------------------------------------------------ protocol
    @property
    def base(self):
        return self._state.base

    @property
    def schema(self):
        return getattr(self._state.base, "schema", None)

    @property
    def vocab(self) -> dict[str, tuple[str, ...]]:
        """The unified (base + delta) tag vocabulary."""
        return dict(self._vocab)

    @property
    def dim(self) -> int:
        return self._state.base.dim

    @property
    def default_params(self) -> SearchParams:
        return self._state.base.default_params

    @property
    def generation(self) -> int:
        return self._state.generation

    @property
    def num_live(self) -> int:
        s = self._state
        return s.base_ids.size - s.tombstones.size + s.delta.n_live

    @property
    def delta_fraction(self) -> float:
        """Delta live rows / base live rows — the compaction trigger."""
        s = self._state
        base_live = max(1, s.base_ids.size - s.tombstones.size)
        return s.delta.n_live / base_live

    @property
    def stats(self) -> MutableStats:
        s = self._state
        return MutableStats(
            base=s.base.stats,
            base_rows=int(s.base_ids.size),
            base_live=int(s.base_ids.size - s.tombstones.size),
            delta_live=s.delta.n_live,
            tombstones=int(s.tombstones.size),
            delta_fraction=self.delta_fraction,
            generation=s.generation,
            delta_memory_bytes=self._delta.memory_bytes,
        )

    # -------------------------------------------------------------- search
    def _oversample(self, tombstones: int) -> int:
        """Extra base-k covering tombstoned hits, bucketed to powers of two
        so the jit compile count stays logarithmic in the delete load."""
        if tombstones == 0:
            return 0
        b = 8
        cap = self.delta_params.max_tombstone_oversample
        while b < tombstones and b < cap:
            b <<= 1
        return min(b, cap)

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
        *,
        mesh=None,
        filter: FilterExpr | None = None,
        filter_params=None,
    ) -> search_mod.SearchResult:
        """Unified fresh+disk search over (base ∪ inserts − deletes).

        Lock-free: reads one immutable state snapshot, so it interleaves
        with writers and compaction without ever observing partial state.
        ``filter`` applies to BOTH tiers: the base search pushes it into
        the page scan (its own vocabulary), the delta scan masks rows
        under the unified vocabulary — an insert is filterable before any
        compaction.
        """
        s = self._state
        p = resolve_search_params(s.base.default_params, k, params)
        kwargs = {} if mesh is None else {"mesh": mesh}
        delta_cf = None
        if filter is not None:
            kwargs.update(filter=filter, filter_params=filter_params)
            # compiled eagerly (not only when the delta is non-empty) so a
            # bad predicate fails the same way at any write load; against
            # the SNAPSHOT's vocab so it matches the delta codes it scans
            delta_cf = filter_mod.compile_filter(
                filter, getattr(s.base, "schema", None), s.vocab or {}
            )

        if s.tombstones.size == 0 and s.delta.n_live == 0:
            res = s.base.search(queries, params=p, **kwargs)
            if s.identity:
                return res                     # pure-read path, untouched
            return res._replace(ids=self._translate(s, np.asarray(res.ids)))

        k_base = p.k + self._oversample(s.tombstones.size)
        res = s.base.search(queries, params=p.replace(k=k_base), **kwargs)

        ext = self._translate(s, np.asarray(res.ids))
        dead = (
            np.isin(ext, s.tombstones) if s.tombstones.size
            else np.zeros(ext.shape, bool)
        )
        base_d = np.where(
            dead | (ext < 0), np.inf, np.asarray(res.dists, np.float32)
        )
        base_ids = np.where(dead, PAD, ext)

        delta_ids, delta_d = scan_delta(
            s.delta, np.asarray(queries), p.k, cfilter=delta_cf
        )
        ids, dists = search_mod.merge_topk_streams(
            jnp.asarray(base_ids.astype(np.int32)),
            jnp.asarray(base_d),
            jnp.asarray(delta_ids.astype(np.int32)),
            jnp.asarray(delta_d),
            k=p.k,
        )
        return search_mod.SearchResult(
            ids=np.asarray(ids),
            dists=np.asarray(dists),
            ios=np.asarray(res.ios),
            hops=np.asarray(res.hops),
            cache_hits=np.asarray(res.cache_hits),
        )

    @staticmethod
    def _translate(s: _MutableState, raw: np.ndarray) -> np.ndarray:
        """Base row ids -> external ids, PAD preserved."""
        if s.identity:
            return raw
        valid = raw >= 0
        ext = np.full(raw.shape, PAD, np.int64)
        ext[valid] = s.base_ids[raw[valid]]
        return ext

    # -------------------------------------------------------------- writes
    def insert(
        self,
        vectors: np.ndarray,
        ids: np.ndarray | None = None,
        *,
        metadata=None,
    ) -> np.ndarray:
        """Append vectors to the delta tier; returns their external ids.

        Re-inserting an existing id is an upsert: the base copy is
        tombstoned / the previous delta row killed, and the new vector
        wins. May trigger an automatic ``compact()`` when the delta
        exceeds ``DeltaParams.compact_fraction`` of the base.

        ``metadata`` (dict-of-columns or list-of-dicts, validated against
        the base's :class:`MetadataSchema`) makes the new rows filterable
        immediately. Unseen tag values extend the unified vocabulary
        append-only, so existing codes — and the base tier's compiled
        filters — stay valid until compaction re-encodes everything.
        """
        vectors = np.ascontiguousarray(vectors, np.float32).reshape(
            -1, self.dim
        )
        columns = None
        if metadata is not None:
            schema = self.schema
            if schema is None:
                raise ValueError(
                    "insert metadata= requires the base index to have a "
                    "MetadataSchema (build it with schema=)"
                )
            columns = filter_mod.normalize_metadata(
                schema, metadata, vectors.shape[0]
            )
        with self._lock:
            s = self._state
            tags = nums = None
            if columns is not None:
                enc = self._encode_with_unified_vocab(
                    self.schema, columns, vectors.shape[0]
                )
                tags, nums = enc.tags, enc.nums
            if ids is None:
                ids = np.arange(
                    self._next_id, self._next_id + vectors.shape[0],
                    dtype=np.int64,
                )
            ids = np.asarray(ids, np.int64).reshape(-1)
            self._delta.insert(vectors, ids, tags=tags, nums=nums)
            self._next_id = max(self._next_id, int(ids.max()) + 1)
            in_base = np.isin(ids, s.base_ids)
            tombs = (
                np.union1d(s.tombstones, ids[in_base])
                if in_base.any() else s.tombstones
            )
            self._state = s._replace(
                tombstones=tombs,
                delta=self._delta.snapshot(),
                vocab=dict(self._vocab) if s.vocab is not None else None,
            )
            if (
                self.auto_compact
                and self.delta_fraction > self.delta_params.compact_fraction
            ):
                self._compact_locked()
        return ids

    def _encode_with_unified_vocab(
        self, schema, columns: dict, n: int
    ) -> MetaArrays:
        """Extend the unified vocabulary with unseen tag values (appended,
        never reordered — base codes stay stable) and encode. Caller holds
        the index lock."""
        for f in schema.tags:
            have = set(self._vocab.get(f, ()))
            new = sorted(
                {str(v) for v in columns.get(f, ()) if v is not None} - have
            )
            if new:
                self._vocab[f] = self._vocab.get(f, ()) + tuple(new)
        return filter_mod.encode_metadata(schema, self._vocab, columns, n)

    def delete(self, ids: np.ndarray) -> int:
        """Remove ids from the live set; returns how many were live.

        Base-resident ids become tombstones (masked at search time until
        compaction rewrites the artifact); delta rows are killed in place.
        Unknown ids are ignored.
        """
        ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
        with self._lock:
            s = self._state
            killed = self._delta.kill(ids)
            in_base = ids[np.isin(ids, s.base_ids)]
            fresh = (
                in_base[~np.isin(in_base, s.tombstones)]
                if s.tombstones.size else in_base
            )
            removed = killed + int(fresh.size)
            # an upserted id is both delta-live and already tombstoned in
            # the base: its delta kill counts once, the tombstone stands
            tombs = (
                np.union1d(s.tombstones, in_base)
                if in_base.size else s.tombstones
            )
            self._state = s._replace(
                tombstones=tombs, delta=self._delta.snapshot()
            )
        return removed

    # ---------------------------------------------------------- compaction
    def compact(self) -> bool:
        """Fold (base ∪ inserts − deletes) into a fresh base artifact.

        Rebuilds through the full page_graph/layout pipeline with the
        base's own config — results afterwards are identical to a cold
        build over the merged dataset. If the index is persisted, the new
        artifact is written to a tmp dir and atomically renamed over the
        old one (manifest generation counter bumped); in-flight searches
        keep their snapshot of the old state throughout. Returns False
        when there is nothing to fold in.
        """
        with self._lock:
            return self._compact_locked()

    def _compact_locked(self) -> bool:
        s = self._state
        if s.delta.n_live == 0 and s.tombstones.size == 0:
            return False
        x_base = s.base.vectors_by_original_id()
        keep = (
            ~np.isin(s.base_ids, s.tombstones)
            if s.tombstones.size else np.ones(s.base_ids.size, bool)
        )
        c = s.delta.count
        live = s.delta.live[:c]
        merged_x = np.concatenate(
            [x_base[keep], s.delta.vecs[:c][live]], axis=0
        )
        merged_ids = np.concatenate(
            [s.base_ids[keep], s.delta.ids[:c][live]], axis=0
        )
        schema = getattr(s.base, "schema", None)
        build_kwargs = {}
        if schema is not None:
            # decode both tiers to values (base under its vocab, delta
            # under the unified one) and let the rebuild mint a fresh
            # vocabulary — compaction is the code-space reclaim
            base_cols = s.base.metadata_by_original_id()
            delta_cols = filter_mod.decode_metadata(
                schema, self._vocab,
                MetaArrays(tags=s.delta.tags[:c], nums=s.delta.nums[:c]),
            )
            build_kwargs = dict(
                schema=schema,
                metadata={
                    f: list(itertools.compress(base_cols[f], keep))
                    + list(itertools.compress(delta_cols[f], live))
                    for f in schema.fields
                },
            )
        new_base = type(s.base).build(merged_x, s.base.cfg, **build_kwargs)
        self._vocab = dict(getattr(new_base, "vocab", None) or {})
        self._delta = self._new_delta(new_base)
        new_state = _MutableState(
            base=new_base,
            base_ids=merged_ids,
            identity=bool(
                np.array_equal(merged_ids, np.arange(merged_ids.size))
            ),
            tombstones=np.empty((0,), np.int64),
            delta=self._delta.snapshot(),
            generation=s.generation + 1,
            vocab=dict(self._vocab) if schema is not None else None,
        )
        if self._directory is not None:
            from repro.core import persist

            persist.swap_mutable(new_state, self._directory)
        self._state = new_state
        return True

    # ------------------------------------------------------------ lifecycle
    def save(self, directory: str) -> None:
        """Persist base + delta sidecar (inserts, tombstones, id map), so a
        restarted server loses nothing — dirty (uncompacted) state
        round-trips to bit-identical search results."""
        from repro.core import persist

        with self._lock:
            persist.save_mutable(self._state, directory)
            self._directory = directory

    @classmethod
    def load(cls, directory: str, *, memory_budget=None) -> "MutableIndex":
        """``memory_budget`` caps the frozen base tier's device-resident
        page region (see :meth:`PageANNIndex.load`); the delta tier is
        in-memory by construction."""
        from repro.core import persist

        return persist.load_mutable(directory, memory_budget=memory_budget)

    def fetch_stats(self) -> dict:
        """Streaming-tier counters of the frozen base (zeros when the base
        is fully resident or has no streaming tier)."""
        fn = getattr(self._state.base, "fetch_stats", None)
        if fn is None:
            return dict(pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0)
        return fn()
