"""Mutable index: in-memory delta tier + tombstones over a frozen base.

The persisted page-aligned artifact (``core.persist``) is immutable — the
paper's layout is compiled at build time. This module makes the *index*
mutable without touching that hot path, the way LSM-ish disk-graph systems
(FreshDiskANN-style) do:

  * :class:`DeltaTier` — an append-only in-memory buffer of freshly
    inserted vectors. It has no graph: queries brute-force-scan it through
    the batched L2 kernel path (``kernels.ops.delta_scan``), exact by
    construction. Buffers grow by doubling and the scanned slice is padded
    to a power of two, so the jitted scan compiles O(log n) shapes.
  * tombstones — deleted ids are masked out of base-search results (the
    disk artifact is never rewritten per delete). The base search is
    oversampled by the tombstone count rounded to a power of two
    (:class:`repro.core.config.DeltaParams.max_tombstone_oversample` caps
    the bucket) so masking cannot leave fewer than k live results.
  * :class:`MutableIndex` — a :class:`repro.core.protocol.VectorIndex`
    that fans each query out to the persisted page-file search and the
    delta scan, masks tombstoned base hits, and merges the two top-k
    streams with ``lax.top_k`` (``core.search.merge_topk_streams``).
    ``insert`` / ``delete`` / ``compact`` make it writable; results carry
    EXTERNAL ids (stable across compactions).
  * ``compact()`` — rebuilds the base over (base ∪ inserts − deletes)
    through the existing page_graph/layout pipeline and, when the index is
    persisted, atomically swaps the on-disk artifact (tmp dir + rename,
    manifest generation counter — see ``persist.save_mutable``).

Concurrency model: every piece of state a search touches lives in ONE
immutable :class:`_MutableState` tuple; ``search`` reads the current tuple
(a single atomic attribute load) and never takes the lock, so searches
in-flight across an ``insert``/``delete``/``compact`` always see a fully
consistent (base, tombstones, delta) snapshot — never a half-swapped
artifact. Writers serialize on the index lock; ``compact`` holds it for
the rebuild, so writes (not reads) stall during compaction.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import search as search_mod
from repro.core.config import DeltaParams, SearchParams, resolve_search_params
from repro.kernels import ops

PAD = -1


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class _DeviceCache:
    """Lazily materialized device copy of one delta snapshot.

    Writers would otherwise pay an O(delta) host->device upload per
    mutation while holding the index lock; instead the first *search*
    against a fresh snapshot uploads once, and every later search shares
    the buffers. Correct because the host vecs slice is append-only (rows
    past the snapshot's count may fill in later, but the live mask — a
    copy frozen at snapshot time — masks them dead in the scan).
    """

    def __init__(self, vecs: np.ndarray, live: np.ndarray):
        self._vecs = vecs
        self._live = live
        self._lock = threading.Lock()
        self._dev: tuple[jnp.ndarray, jnp.ndarray] | None = None

    def get(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        with self._lock:
            if self._dev is None:
                self._dev = (jnp.asarray(self._vecs), jnp.asarray(self._live))
            return self._dev


class DeltaView(NamedTuple):
    """An immutable snapshot of the delta tier (what a search reads).

    Host arrays are copies (``ids``/``live``) or append-only buffer slices
    whose rows past ``count`` are masked dead (``vecs``); the device copy
    is materialized lazily by the first search and shared until the next
    write. The padded length is a power of two so the jitted scan compiles
    a bounded number of shapes.
    """

    count: int                # rows appended (live or dead)
    n_live: int               # rows not superseded/deleted
    vecs: np.ndarray          # (Cpad, d) f32 host buffer slice
    ids: np.ndarray           # (Cpad,) int64 external ids, PAD padded
    live: np.ndarray          # (Cpad,) bool
    device: _DeviceCache      # lazy (vecs_dev, live_dev)


class DeltaTier:
    """Append-only fresh-vector store with external-id upsert semantics.

    Not thread-safe by itself: :class:`MutableIndex` serializes writers and
    hands searches immutable :class:`DeltaView` snapshots. Re-inserting a
    live external id kills the superseded row (last write wins); ``kill``
    marks rows dead without reclaiming them — compaction is the reclaim.
    """

    def __init__(self, dim: int, capacity: int = 256):
        cap = _pow2(max(int(capacity), 8))
        self.dim = int(dim)
        self._vecs = np.zeros((cap, self.dim), np.float32)
        self._ids = np.full((cap,), PAD, np.int64)
        self._live = np.zeros((cap,), bool)
        self._count = 0
        self._slot_of: dict[int, int] = {}   # live external id -> row
        self._view: DeltaView | None = None

    def __len__(self) -> int:
        return len(self._slot_of)

    @property
    def live_count(self) -> int:
        return len(self._slot_of)

    @property
    def memory_bytes(self) -> int:
        return int(self._vecs.nbytes + self._ids.nbytes + self._live.nbytes)

    def _grow(self, need: int) -> None:
        cap = self._ids.shape[0]
        if need <= cap:
            return
        new_cap = _pow2(need)
        # fresh buffers + copy: snapshots taken before the grow keep the old
        # buffer, whose first `count` rows never change again
        vecs = np.zeros((new_cap, self.dim), np.float32)
        ids = np.full((new_cap,), PAD, np.int64)
        live = np.zeros((new_cap,), bool)
        c = self._count
        vecs[:c], ids[:c], live[:c] = self._vecs[:c], self._ids[:c], self._live[:c]
        self._vecs, self._ids, self._live = vecs, ids, live

    def insert(self, vectors: np.ndarray, ids: np.ndarray) -> None:
        vectors = np.ascontiguousarray(vectors, np.float32).reshape(-1, self.dim)
        ids = np.asarray(ids, np.int64).reshape(-1)
        if vectors.shape[0] != ids.shape[0]:
            raise ValueError(
                f"{vectors.shape[0]} vectors for {ids.shape[0]} ids"
            )
        if np.unique(ids).shape[0] != ids.shape[0]:
            raise ValueError("duplicate ids within one insert batch")
        if (ids < 0).any():
            raise ValueError("ids must be non-negative")
        if (ids > np.iinfo(np.int32).max).any():
            # the device-side top-k merge carries ids as int32 (x64 is off
            # in jax); a wider id would silently wrap in search results
            raise ValueError("ids must fit int32 (the merge path's id space)")
        self.kill(ids)                        # last write wins
        n = ids.shape[0]
        self._grow(self._count + n)
        rows = slice(self._count, self._count + n)
        self._vecs[rows] = vectors
        self._ids[rows] = ids
        self._live[rows] = True
        for j, i in enumerate(ids.tolist()):
            self._slot_of[int(i)] = self._count + j
        self._count += n
        self._view = None

    def kill(self, ids: np.ndarray) -> int:
        """Mark rows of these external ids dead; returns how many were live."""
        killed = 0
        for i in np.asarray(ids, np.int64).reshape(-1).tolist():
            slot = self._slot_of.pop(int(i), None)
            if slot is not None:
                self._live[slot] = False
                killed += 1
        if killed:
            self._view = None
        return killed

    def snapshot(self) -> DeltaView:
        if self._view is None:
            cpad = _pow2(max(self._count, 8))
            vecs = self._vecs[:cpad]
            live = self._live[:cpad].copy()
            self._view = DeltaView(
                count=self._count,
                n_live=len(self._slot_of),
                vecs=vecs,
                ids=self._ids[:cpad].copy(),
                live=live,
                device=_DeviceCache(vecs, live),
            )
        return self._view


def scan_delta(
    view: DeltaView, queries: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k of the delta tier: (ids (Q, kk), dists (Q, kk)) with
    kk = min(k, padded rows); empty (Q, 0) streams when nothing is live.
    Non-finite distances carry PAD ids (fewer than kk live rows)."""
    qn = queries.shape[0]
    if view.n_live == 0 or k == 0:
        return (
            np.full((qn, 0), PAD, np.int64),
            np.full((qn, 0), np.inf, np.float32),
        )
    vecs_dev, live_dev = view.device.get()
    kk = min(k, vecs_dev.shape[0])
    dists, slots = ops.delta_scan(
        jnp.asarray(queries, jnp.float32), vecs_dev, live_dev, kk
    )
    dists = np.asarray(dists)
    ids = view.ids[np.asarray(slots)]
    return np.where(np.isfinite(dists), ids, PAD), dists


class _MutableState(NamedTuple):
    """Everything a search reads, swapped atomically as one tuple."""

    base: Any                 # the frozen VectorIndex (PageANNIndex)
    base_ids: np.ndarray      # (n,) int64: base row -> external id
    identity: bool            # base_ids is arange(n) (no translation needed)
    tombstones: np.ndarray    # sorted int64 external ids deleted from base
    delta: DeltaView
    generation: int           # compaction counter (mirrors the manifest)


@dataclasses.dataclass
class MutableStats:
    """Footprint/shape of the mutable wrapper; ``base`` is the base index's
    own stats object (on-disk bytes included — see ``BuildStats.disk_bytes``)."""

    base: Any
    base_rows: int
    base_live: int
    delta_live: int
    tombstones: int
    delta_fraction: float
    generation: int
    delta_memory_bytes: int


class MutableIndex:
    """A writable :class:`VectorIndex` over a frozen base + delta tier.

    ``search`` results carry EXTERNAL ids: stable across compactions, equal
    to base row ids for an unwrapped index (``base_ids`` defaults to
    ``arange``). Compaction requires the base to expose ``cfg``,
    ``vectors_by_original_id()`` and a ``build`` classmethod —
    :class:`repro.core.index.PageANNIndex` does.
    """

    def __init__(
        self,
        base,
        base_ids: np.ndarray | None = None,
        *,
        params: DeltaParams | None = None,
        auto_compact: bool = True,
    ):
        if base_ids is None:
            store = getattr(base, "store", None)
            n = getattr(store, "num_vectors", None)
            if n is None:                      # baselines: stats carries it
                n = getattr(base.stats, "num_vectors", None)
            if n is None:
                raise ValueError(
                    "cannot infer the base row count; pass base_ids"
                )
            base_ids = np.arange(n, dtype=np.int64)
        base_ids = np.asarray(base_ids, np.int64).reshape(-1)
        if base_ids.size and int(base_ids.max()) > np.iinfo(np.int32).max:
            raise ValueError(
                "external ids must fit int32 (the merge path's id space)"
            )
        self.delta_params = params or DeltaParams()
        self.auto_compact = auto_compact
        self._lock = threading.RLock()
        self._directory: str | None = None
        self._delta = DeltaTier(base.dim, self.delta_params.min_capacity)
        self._next_id = int(base_ids.max()) + 1 if base_ids.size else 0
        self._state = _MutableState(
            base=base,
            base_ids=base_ids,
            identity=bool(
                np.array_equal(base_ids, np.arange(base_ids.size))
            ),
            tombstones=np.empty((0,), np.int64),
            delta=self._delta.snapshot(),
            generation=0,
        )

    # ------------------------------------------------------------ protocol
    @property
    def base(self):
        return self._state.base

    @property
    def dim(self) -> int:
        return self._state.base.dim

    @property
    def default_params(self) -> SearchParams:
        return self._state.base.default_params

    @property
    def generation(self) -> int:
        return self._state.generation

    @property
    def num_live(self) -> int:
        s = self._state
        return s.base_ids.size - s.tombstones.size + s.delta.n_live

    @property
    def delta_fraction(self) -> float:
        """Delta live rows / base live rows — the compaction trigger."""
        s = self._state
        base_live = max(1, s.base_ids.size - s.tombstones.size)
        return s.delta.n_live / base_live

    @property
    def stats(self) -> MutableStats:
        s = self._state
        return MutableStats(
            base=s.base.stats,
            base_rows=int(s.base_ids.size),
            base_live=int(s.base_ids.size - s.tombstones.size),
            delta_live=s.delta.n_live,
            tombstones=int(s.tombstones.size),
            delta_fraction=self.delta_fraction,
            generation=s.generation,
            delta_memory_bytes=self._delta.memory_bytes,
        )

    # -------------------------------------------------------------- search
    def _oversample(self, tombstones: int) -> int:
        """Extra base-k covering tombstoned hits, bucketed to powers of two
        so the jit compile count stays logarithmic in the delete load."""
        if tombstones == 0:
            return 0
        b = 8
        cap = self.delta_params.max_tombstone_oversample
        while b < tombstones and b < cap:
            b <<= 1
        return min(b, cap)

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
        *,
        mesh=None,
    ) -> search_mod.SearchResult:
        """Unified fresh+disk search over (base ∪ inserts − deletes).

        Lock-free: reads one immutable state snapshot, so it interleaves
        with writers and compaction without ever observing partial state.
        """
        s = self._state
        p = resolve_search_params(s.base.default_params, k, params)
        kwargs = {} if mesh is None else {"mesh": mesh}

        if s.tombstones.size == 0 and s.delta.n_live == 0:
            res = s.base.search(queries, params=p, **kwargs)
            if s.identity:
                return res                     # pure-read path, untouched
            return res._replace(ids=self._translate(s, np.asarray(res.ids)))

        k_base = p.k + self._oversample(s.tombstones.size)
        res = s.base.search(queries, params=p.replace(k=k_base), **kwargs)

        ext = self._translate(s, np.asarray(res.ids))
        dead = (
            np.isin(ext, s.tombstones) if s.tombstones.size
            else np.zeros(ext.shape, bool)
        )
        base_d = np.where(
            dead | (ext < 0), np.inf, np.asarray(res.dists, np.float32)
        )
        base_ids = np.where(dead, PAD, ext)

        delta_ids, delta_d = scan_delta(s.delta, np.asarray(queries), p.k)
        ids, dists = search_mod.merge_topk_streams(
            jnp.asarray(base_ids.astype(np.int32)),
            jnp.asarray(base_d),
            jnp.asarray(delta_ids.astype(np.int32)),
            jnp.asarray(delta_d),
            k=p.k,
        )
        return search_mod.SearchResult(
            ids=np.asarray(ids),
            dists=np.asarray(dists),
            ios=np.asarray(res.ios),
            hops=np.asarray(res.hops),
            cache_hits=np.asarray(res.cache_hits),
        )

    @staticmethod
    def _translate(s: _MutableState, raw: np.ndarray) -> np.ndarray:
        """Base row ids -> external ids, PAD preserved."""
        if s.identity:
            return raw
        valid = raw >= 0
        ext = np.full(raw.shape, PAD, np.int64)
        ext[valid] = s.base_ids[raw[valid]]
        return ext

    # -------------------------------------------------------------- writes
    def insert(
        self, vectors: np.ndarray, ids: np.ndarray | None = None
    ) -> np.ndarray:
        """Append vectors to the delta tier; returns their external ids.

        Re-inserting an existing id is an upsert: the base copy is
        tombstoned / the previous delta row killed, and the new vector
        wins. May trigger an automatic ``compact()`` when the delta
        exceeds ``DeltaParams.compact_fraction`` of the base.
        """
        vectors = np.ascontiguousarray(vectors, np.float32).reshape(
            -1, self.dim
        )
        with self._lock:
            s = self._state
            if ids is None:
                ids = np.arange(
                    self._next_id, self._next_id + vectors.shape[0],
                    dtype=np.int64,
                )
            ids = np.asarray(ids, np.int64).reshape(-1)
            self._delta.insert(vectors, ids)    # validates shape/dups
            self._next_id = max(self._next_id, int(ids.max()) + 1)
            in_base = np.isin(ids, s.base_ids)
            tombs = (
                np.union1d(s.tombstones, ids[in_base])
                if in_base.any() else s.tombstones
            )
            self._state = s._replace(
                tombstones=tombs, delta=self._delta.snapshot()
            )
            if (
                self.auto_compact
                and self.delta_fraction > self.delta_params.compact_fraction
            ):
                self._compact_locked()
        return ids

    def delete(self, ids: np.ndarray) -> int:
        """Remove ids from the live set; returns how many were live.

        Base-resident ids become tombstones (masked at search time until
        compaction rewrites the artifact); delta rows are killed in place.
        Unknown ids are ignored.
        """
        ids = np.unique(np.asarray(ids, np.int64).reshape(-1))
        with self._lock:
            s = self._state
            killed = self._delta.kill(ids)
            in_base = ids[np.isin(ids, s.base_ids)]
            fresh = (
                in_base[~np.isin(in_base, s.tombstones)]
                if s.tombstones.size else in_base
            )
            removed = killed + int(fresh.size)
            # an upserted id is both delta-live and already tombstoned in
            # the base: its delta kill counts once, the tombstone stands
            tombs = (
                np.union1d(s.tombstones, in_base)
                if in_base.size else s.tombstones
            )
            self._state = s._replace(
                tombstones=tombs, delta=self._delta.snapshot()
            )
        return removed

    # ---------------------------------------------------------- compaction
    def compact(self) -> bool:
        """Fold (base ∪ inserts − deletes) into a fresh base artifact.

        Rebuilds through the full page_graph/layout pipeline with the
        base's own config — results afterwards are identical to a cold
        build over the merged dataset. If the index is persisted, the new
        artifact is written to a tmp dir and atomically renamed over the
        old one (manifest generation counter bumped); in-flight searches
        keep their snapshot of the old state throughout. Returns False
        when there is nothing to fold in.
        """
        with self._lock:
            return self._compact_locked()

    def _compact_locked(self) -> bool:
        s = self._state
        if s.delta.n_live == 0 and s.tombstones.size == 0:
            return False
        x_base = s.base.vectors_by_original_id()
        keep = (
            ~np.isin(s.base_ids, s.tombstones)
            if s.tombstones.size else np.ones(s.base_ids.size, bool)
        )
        c = s.delta.count
        live = s.delta.live[:c]
        merged_x = np.concatenate(
            [x_base[keep], s.delta.vecs[:c][live]], axis=0
        )
        merged_ids = np.concatenate(
            [s.base_ids[keep], s.delta.ids[:c][live]], axis=0
        )
        new_base = type(s.base).build(merged_x, s.base.cfg)
        self._delta = DeltaTier(self.dim, self.delta_params.min_capacity)
        new_state = _MutableState(
            base=new_base,
            base_ids=merged_ids,
            identity=bool(
                np.array_equal(merged_ids, np.arange(merged_ids.size))
            ),
            tombstones=np.empty((0,), np.int64),
            delta=self._delta.snapshot(),
            generation=s.generation + 1,
        )
        if self._directory is not None:
            from repro.core import persist

            persist.swap_mutable(new_state, self._directory)
        self._state = new_state
        return True

    # ------------------------------------------------------------ lifecycle
    def save(self, directory: str) -> None:
        """Persist base + delta sidecar (inserts, tombstones, id map), so a
        restarted server loses nothing — dirty (uncompacted) state
        round-trips to bit-identical search results."""
        from repro.core import persist

        with self._lock:
            persist.save_mutable(self._state, directory)
            self._directory = directory

    @classmethod
    def load(cls, directory: str, *, memory_budget=None) -> "MutableIndex":
        """``memory_budget`` caps the frozen base tier's device-resident
        page region (see :meth:`PageANNIndex.load`); the delta tier is
        in-memory by construction."""
        from repro.core import persist

        return persist.load_mutable(directory, memory_budget=memory_budget)

    def fetch_stats(self) -> dict:
        """Streaming-tier counters of the frozen base (zeros when the base
        is fully resident or has no streaming tier)."""
        fn = getattr(self._state.base, "fetch_stats", None)
        if fn is None:
            return dict(pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0)
        return fn()
