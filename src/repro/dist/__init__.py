"""Data-sharded scale-out: partition one collection's pages across shards.

:mod:`repro.core.distributed` holds the device-level machinery (shard_map
over a mesh, all_gather merge); this package wraps it in the index
lifecycle contract so a sharded collection plugs into
``BatchingEngine``/``VectorService``/``persist`` exactly like a single
:class:`~repro.core.index.PageANNIndex` — build, search, save as
``shard-<i>/`` artifacts under one ``kind="sharded"`` manifest, reload
through ``load_index``.
"""
from repro.dist.sharded import ShardedPageStore, shard_params_for

__all__ = ["ShardedPageStore", "shard_params_for"]
