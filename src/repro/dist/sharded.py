"""Data-sharded PageANN collection: S complete sub-indexes over slices of
one dataset, presented as a single ``VectorIndex``.

Independent sharding (paper §7): every query runs against ALL shards and
the per-shard top-k streams merge with
:func:`repro.core.search.merge_topk_streams`.  Because the true global
top-k is a subset of the union of per-shard top-k (each shard holds a
disjoint slice of the corpus and returns its k best), the merge is exact —
recall differences vs the unsharded index come only from per-shard beam
search quality, which is why :func:`shard_params_for` can shrink the
per-shard beam: each shard searches a 1/S-size corpus, and the beam needed
for a given recall shrinks with the corpus.  Recall parity vs the
unsharded build is CI-gated (``benchmarks/scaleout.py``,
``tests/test_sharded_store.py``).

Two execution paths share one artifact:

* **host fan-out** (default, works on any device count): sequential
  per-shard ``batch_search`` calls + host-side id translation + device
  merge.  This is the serving path on a single-device box.
* **mesh fan-out** (``search(..., mesh=)``): the stacked
  :class:`~repro.core.distributed.ShardedIndex` dispatched through
  ``shard_map`` — one collective merge per query batch, for real
  multi-device meshes.

Persistence: ``save`` writes each sub-index as a full PageANN artifact
under ``shard-<i>/`` plus ``shards.npz`` (the global-id slice per shard)
under one ``kind="sharded"`` manifest; ``repro.core.persist.load_index``
dispatches back here, and ``memory_budget`` applies per shard.
"""
from __future__ import annotations

import dataclasses
import math
import os

import jax.numpy as jnp
import numpy as np

from repro.core import distributed as dist
from repro.core import persist
from repro.core.config import PageANNConfig, SearchParams
from repro.core.index import PageANNIndex
from repro.core.config import resolve_search_params
from repro.core.search import PAD, SearchResult, merge_topk_streams

SHARD_SUBDIR = "shard-{i}"
SHARDS_NPZ = "shards.npz"


def shard_params_for(base: SearchParams, num_shards: int) -> SearchParams:
    """Per-shard search knobs for a 1/S-size corpus.

    The exact cross-shard merge means each shard only has to be accurate
    about ITS slice, and a smaller corpus needs a smaller beam for the
    same recall — this is where data sharding buys throughput even on one
    device (each query does less total page-scoring work).  The scaling
    here (beam halved per doubling of shards, floored at the legal
    minimum; smaller io_batch so the shorter walks waste less speculative
    I/O) was measured on the benchmark corpus at recall parity; the
    parity gate in ``benchmarks/scaleout.py`` keeps it honest for other
    configs.
    """
    if num_shards <= 1:
        return base
    beam = max(
        base.k, base.lsh_entries,
        math.ceil(base.beam_width / (2 * num_shards)),
    )
    return base.replace(
        beam_width=beam,
        io_batch=min(base.io_batch, 3),
        max_hops=max(16, base.max_hops // 2),
    )


@dataclasses.dataclass
class ShardedPageStore:
    """S per-shard :class:`PageANNIndex` sub-indexes + their global-id
    slices, speaking the ``VectorIndex`` protocol."""

    shards: list
    parts: list                      # list[np.ndarray] global ids per shard
    cfg: PageANNConfig

    def __post_init__(self):
        if len(self.shards) != len(self.parts):
            raise ValueError(
                f"{len(self.shards)} shards but {len(self.parts)} id slices"
            )
        if len(self.shards) < 1:
            raise ValueError("need at least one shard")

    # ------------------------------------------------------------- build
    @classmethod
    def build(
        cls, x: np.ndarray, cfg: PageANNConfig, num_shards: int
    ) -> "ShardedPageStore":
        """Balanced random partition (seeded by the config), one full
        PageANN build per shard."""
        x = np.asarray(x, np.float32)
        parts = dist.partition_vectors(x, num_shards, cfg.seed)
        shards = [PageANNIndex.build(x[p], cfg) for p in parts]
        return cls(shards=shards, parts=list(parts), cfg=cfg)

    # ---------------------------------------------------------- protocol
    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def dim(self) -> int:
        return self.cfg.dim

    @property
    def default_params(self) -> SearchParams:
        """UNSHARDED-space defaults: callers think in whole-collection
        knobs; the per-shard scaling happens inside ``search``."""
        return SearchParams.from_config(self.cfg)

    def resolve_params(
        self, k: int | None, params: SearchParams | None
    ) -> SearchParams:
        return resolve_search_params(self.default_params, k, params)

    @property
    def stats(self) -> dict:
        """Aggregate footprint over the fleet of shards (dict so the
        service stats flattener namespaces the fields as-is)."""
        subs = [s.stats for s in self.shards]
        return dict(
            num_shards=self.num_shards,
            num_vectors=sum(len(p) for p in self.parts),
            pages=sum(st.pages for st in subs),
            disk_bytes=sum(st.disk_bytes for st in subs),
            memory_bytes=sum(st.memory_bytes for st in subs),
            resident_pages=sum(st.resident_pages for st in subs),
        )

    def fetch_stats(self) -> dict:
        out = dict(pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0)
        for s in self.shards:
            fs = s.fetch_stats()
            for key in out:
                out[key] += fs.get(key, 0)
        return out

    # ------------------------------------------------------------ search
    def _translate(self, shard: int, local_ids: np.ndarray) -> np.ndarray:
        """Shard-local ORIGINAL ids -> global dataset ids, PAD kept."""
        part = self.parts[shard]
        out = np.full(local_ids.shape, PAD, np.int64)
        valid = local_ids >= 0
        out[valid] = part[local_ids[valid]]
        return out

    def search(
        self,
        queries: np.ndarray,
        k: int | None = None,
        params: SearchParams | None = None,
        *,
        mesh=None,
    ) -> SearchResult:
        """Fan a query batch out to every shard, merge per-shard top-k.

        Returns GLOBAL dataset ids.  ``ios``/``cache_hits`` sum over
        shards (total fleet I/O per query); ``hops`` is the max across
        shards (the critical path).  With ``mesh=`` the fan-out runs as
        one shard_map program over the mesh's ``data`` axis instead of a
        host-side loop.
        """
        p = self.resolve_params(k, params)
        if mesh is not None:
            return self._mesh_search(queries, p, mesh)
        sp = shard_params_for(p, self.num_shards)
        merged_ids = merged_d = None
        ios = hops = hits = None
        for i, sub in enumerate(self.shards):
            # per-shard searches return shard-local ORIGINAL ids; k stays
            # the caller's k (the exact-merge property needs each shard's
            # full k best, no more)
            r = sub.search(queries, k=p.k, params=sp)
            gids = self._translate(i, np.asarray(r.ids))
            # PAD must carry +inf into the merge (merge_topk_streams
            # re-masks non-finite winners back to PAD)
            d = np.where(gids < 0, np.inf, np.asarray(r.dists))
            gi = jnp.asarray(gids, jnp.int32)
            dj = jnp.asarray(d, jnp.float32)
            if merged_ids is None:
                merged_ids, merged_d = gi, dj
                ios = np.asarray(r.ios).copy()
                hops = np.asarray(r.hops).copy()
                hits = np.asarray(r.cache_hits).copy()
            else:
                merged_ids, merged_d = merge_topk_streams(
                    merged_ids, merged_d, gi, dj, k=p.k
                )
                ios += np.asarray(r.ios)
                hops = np.maximum(hops, np.asarray(r.hops))
                hits += np.asarray(r.cache_hits)
        ids = np.asarray(merged_ids, np.int64)
        d = np.asarray(merged_d)
        if merged_d is not None and self.num_shards == 1:
            # single shard: nothing was merged, mask PAD distances for the
            # same contract as the merged path
            d = np.where(ids < 0, np.inf, d)
        return SearchResult(
            ids=ids, dists=d, ios=ios, hops=hops, cache_hits=hits
        )

    def _mesh_search(self, queries, p: SearchParams, mesh) -> SearchResult:
        """shard_map fan-out over the mesh's ``data`` axis — the
        multi-device path; requires ``mesh`` with axes ("data", "model")
        and data-axis size == num_shards."""
        data_size = mesh.shape.get("data")
        if data_size != self.num_shards:
            raise ValueError(
                f"mesh data axis is {data_size} but index has "
                f"{self.num_shards} shards"
            )
        sp = shard_params_for(p, self.num_shards)
        sh = self.to_sharded_index()
        fn, _ = dist.make_sharded_search(
            mesh, self.cfg, sh.capacity, k=p.k, params=sp
        )
        with mesh:
            ids, tag, d, ios = fn(sh.data, jnp.asarray(queries, jnp.float32))
        local = np.asarray(ids)
        gids = dist.translate_ids(sh, local, np.asarray(tag))
        # per-shard local ids were already translated to the shard's
        # reassigned space by dist; map through each shard's slice to
        # global dataset ids
        out = np.full(gids.shape, PAD, np.int64)
        valid = gids >= 0
        tags = np.asarray(tag)
        for s in range(self.num_shards):
            m = valid & (tags == s)
            out[m] = self.parts[s][gids[m]]
        dd = np.where(out < 0, np.inf, np.asarray(d))
        qn = out.shape[0]
        zeros = np.zeros((qn,), np.int64)
        return SearchResult(
            ids=out, dists=dd, ios=np.asarray(ios), hops=zeros,
            cache_hits=zeros,
        )

    def to_sharded_index(self) -> dist.ShardedIndex:
        """Stack the sub-indexes into the shard_map input layout.  The
        stacked ``new_to_old`` maps shard-local reassigned ids back to
        shard-local ORIGINAL ids (indexes into ``parts[s]``)."""
        fake_parts = [np.arange(len(p), dtype=np.int64) for p in self.parts]
        return dist.stack_shards(self.shards, fake_parts)

    # ----------------------------------------------------------- persist
    def save(self, directory: str) -> None:
        """``shard-<i>/`` full PageANN artifacts + ``shards.npz`` id
        slices under one ``kind="sharded"`` manifest (written last, so a
        crash mid-save leaves a directory ``load_index`` refuses)."""
        os.makedirs(directory, exist_ok=True)
        for i, sub in enumerate(self.shards):
            sub.save(os.path.join(directory, SHARD_SUBDIR.format(i=i)))
        np.savez(
            os.path.join(directory, SHARDS_NPZ),
            **{f"part_{i}": np.asarray(p, np.int64)
               for i, p in enumerate(self.parts)},
        )
        persist.write_manifest(directory, dict(
            kind="sharded",
            num_shards=self.num_shards,
            config=persist.config_to_json(self.cfg),
        ))

    @classmethod
    def load(
        cls, directory: str, *, memory_budget=None
    ) -> "ShardedPageStore":
        """Reload; bit-identical per shard, ``memory_budget`` caps each
        shard's resident page tier independently."""
        doc = persist.read_manifest(directory)
        if doc.get("kind") != "sharded":
            raise persist.IndexFormatError(
                f"{directory}: manifest kind is {doc.get('kind')!r}, "
                "not 'sharded'"
            )
        num = doc["num_shards"]
        if not isinstance(num, int) or num < 1:
            raise persist.IndexFormatError(
                f"{directory}: bad num_shards {num!r}"
            )
        npz_path = os.path.join(directory, SHARDS_NPZ)
        if not os.path.exists(npz_path):
            raise persist.IndexFormatError(f"{directory}: missing {SHARDS_NPZ}")
        with np.load(npz_path) as z:
            parts = [z[f"part_{i}"] for i in range(num)]
        shards = [
            PageANNIndex.load(
                os.path.join(directory, SHARD_SUBDIR.format(i=i)),
                memory_budget=memory_budget,
            )
            for i in range(num)
        ]
        cfg = persist.config_from_json(doc["config"])
        return cls(shards=shards, parts=parts, cfg=cfg)
