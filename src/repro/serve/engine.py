"""Request-batching serving frontend for PageANN search.

The jitted search is fixed-shape: one compiled executable per (batch, k)
pair. A serving workload, though, is a stream of single queries arriving at
arbitrary times. This engine bridges the two — the paper's "query threads"
as a batching frontend:

  * ``submit`` enqueues one query and returns a future;
  * a batch dispatches when ``batch_size`` requests are pending, when
    ``timeout_ms`` elapses after the first pending request, or on an
    explicit ``flush`` — whichever comes first. The search runs in the
    thread that triggered the dispatch (the batch-completing submitter,
    the timer, or the flusher), so one submit() in every ``batch_size``
    pays the search latency inline — amortized, not hidden;
  * ragged batches are zero-padded to the fixed ``batch_size`` shape (one
    executable, no recompiles) and the pad rows' results are dropped;
  * results are demultiplexed back to futures in submission order, with
    per-request latency and aggregate QPS / mean-I/O counters.

The engine lock covers only queue and counter bookkeeping — the search
itself runs outside it, so other threads keep enqueuing (and the next
batch keeps filling) while a batch computes.

The backend is any ``fn(queries (B, d)) -> SearchResult``-like pytree with
a leading batch axis — ``core.search.batch_search`` on one device,
``core.search.shard_search`` across a mesh (``from_index(mesh=...)``).
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, NamedTuple

import jax
import numpy as np


class RequestResult(NamedTuple):
    """One request's slice of the batch result, plus serving metadata."""

    result: Any          # per-request pytree (leaves: leading axis removed)
    latency_ms: float    # submit -> demux wall time
    batch_size: int      # how many real requests shared the dispatch
    batch_index: int     # which dispatch served it (0-based)


class EngineMetrics(NamedTuple):
    requests: int
    batches: int
    qps: float                 # completed requests / wall time since first submit
    latency_ms_mean: float     # over the trailing latency window
    latency_ms_p50: float
    latency_ms_p99: float
    mean_ios: float            # mean disk page reads per request
    mean_batch_occupancy: float  # real requests per dispatched batch
    padded_fraction: float     # pad rows / dispatched rows


class _Pending(NamedTuple):
    future: Future
    query: np.ndarray
    t_submit: float


class BatchingEngine:
    def __init__(
        self,
        search_fn: Callable[[np.ndarray], Any],
        *,
        dim: int,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        latency_window: int = 8192,
        dtype=np.float32,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self._search_fn = search_fn
        self._dim = dim
        self._batch_size = batch_size
        self._timeout_ms = timeout_ms
        self._dtype = dtype
        self._clock = clock
        self._lock = threading.RLock()
        self._pending: list[_Pending] = []
        self._timer: threading.Timer | None = None
        self._timer_gen = 0     # invalidates stale timers (see _flush_due)
        self._closed = False
        # aggregate counters (window-bounded where they would otherwise grow)
        self._latencies_ms: collections.deque = collections.deque(
            maxlen=latency_window
        )
        self._completed = 0
        self._total_ios = 0.0
        self._batches = 0
        self._dispatched_rows = 0
        self._padded_rows = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    # ------------------------------------------------------------- requests
    def submit(self, query: np.ndarray) -> Future:
        """Enqueue one (d,) query; returns a Future[RequestResult]."""
        q = np.asarray(query, self._dtype).reshape(-1)
        if q.shape[0] != self._dim:
            raise ValueError(f"query dim {q.shape[0]} != engine dim {self._dim}")
        fut: Future = Future()
        batch = None
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._t_first is None:
                self._t_first = self._clock()
            self._pending.append(_Pending(fut, q, self._clock()))
            if len(self._pending) >= self._batch_size:
                batch = self._take_locked()
            elif self._timeout_ms is not None and self._timer is None:
                gen = self._timer_gen
                self._timer = threading.Timer(
                    self._timeout_ms / 1e3, self._flush_due, args=(gen,)
                )
                self._timer.daemon = True
                self._timer.start()
        if batch is not None:
            self._run_batch(batch)
        return fut

    def flush(self) -> None:
        """Dispatch whatever is pending, padding the ragged batch."""
        while True:
            with self._lock:
                batch = self._take_locked() if self._pending else None
            if batch is None:
                return
            self._run_batch(batch)

    def search(self, queries: np.ndarray) -> list[RequestResult]:
        """Synchronous convenience: submit a (Q, d) batch, flush, gather."""
        futs = [self.submit(q) for q in np.asarray(queries)]
        self.flush()
        return [f.result() for f in futs]

    def close(self) -> None:
        self.flush()
        with self._lock:
            self._closed = True
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None

    # ------------------------------------------------------------- dispatch
    def _flush_due(self, gen: int) -> None:
        """Timer callback. A timer that raced a size-triggered dispatch (its
        generation was retired by _take_locked before it got the lock) must
        no-op, or it would prematurely flush the NEXT batch."""
        with self._lock:
            if gen != self._timer_gen or self._closed:
                return
            self._timer = None
            batch = self._take_locked() if self._pending else None
        if batch is not None:
            self._run_batch(batch)

    def _take_locked(self) -> tuple[int, list[_Pending]]:
        """Pop up to batch_size pending requests and retire the live timer.
        Caller must hold the lock; the batch index is assigned here so
        dispatch order matches take order even with concurrent submitters."""
        take = self._pending[: self._batch_size]
        self._pending = self._pending[self._batch_size:]
        self._timer_gen += 1
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        batch_index = self._batches
        self._batches += 1
        return batch_index, take

    def _run_batch(self, batch: tuple[int, list[_Pending]]) -> None:
        """Pad, search (outside the lock), record counters, demux."""
        batch_index, take = batch
        n = len(take)
        padded = np.zeros((self._batch_size, self._dim), self._dtype)
        for i, p in enumerate(take):
            padded[i] = p.query
        try:
            out = self._search_fn(padded)
            out = jax.tree.map(np.asarray, out)
        except Exception as e:
            # a backend failure must reach every waiter through its future —
            # not hang them, and not vanish into the timer thread's
            # excepthook (submit/flush never raise backend errors)
            with self._lock:
                self._dispatched_rows += self._batch_size
                self._padded_rows += self._batch_size - n
            for p in take:
                p.future.set_exception(e)
            return

        t_done = self._clock()
        ios = getattr(out, "ios", None)
        latencies = [(t_done - p.t_submit) * 1e3 for p in take]
        with self._lock:
            self._dispatched_rows += self._batch_size
            self._padded_rows += self._batch_size - n
            self._t_last = t_done
            self._completed += n
            self._latencies_ms.extend(latencies)
            if ios is not None:
                self._total_ios += float(np.sum(ios[:n]))
        for i, p in enumerate(take):
            row = jax.tree.map(lambda a: a[i], out)
            p.future.set_result(
                RequestResult(
                    result=row,
                    latency_ms=latencies[i],
                    batch_size=n,
                    batch_index=batch_index,
                )
            )

    # -------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            done = self._completed
            wall = (
                (self._t_last - self._t_first)
                if done and self._t_last is not None
                else 0.0
            )
            return EngineMetrics(
                requests=done,
                batches=self._batches,
                qps=done / wall if wall > 0 else float(done and np.inf),
                latency_ms_mean=float(lat.mean()) if len(lat) else 0.0,
                latency_ms_p50=float(np.percentile(lat, 50)) if len(lat) else 0.0,
                latency_ms_p99=float(np.percentile(lat, 99)) if len(lat) else 0.0,
                mean_ios=self._total_ios / done if done else 0.0,
                mean_batch_occupancy=(
                    (self._dispatched_rows - self._padded_rows) / self._batches
                    if self._batches
                    else 0.0
                ),
                padded_fraction=(
                    self._padded_rows / self._dispatched_rows
                    if self._dispatched_rows
                    else 0.0
                ),
            )

    # ------------------------------------------------------------- builders
    @classmethod
    def from_index(
        cls,
        index,
        *,
        k: int = 10,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        mesh=None,
        **kwargs,
    ) -> "BatchingEngine":
        """Engine over a built ``PageANNIndex``; results carry ORIGINAL ids.

        ``mesh=None`` dispatches ``batch_search`` on the default device;
        passing a mesh (see ``launch.mesh``) dispatches ``shard_search``
        with the query batch split across it.
        """
        from repro.core import search as search_mod

        kw = search_mod.search_kwargs(index.cfg, index.store.capacity)

        def fn(queries: np.ndarray):
            import jax.numpy as jnp

            qj = jnp.asarray(queries)
            if mesh is None:
                res = search_mod.batch_search(qj, index.data, k=k, **kw)
            else:
                res = search_mod.shard_search(
                    qj, index.data, mesh=mesh, k=k, **kw
                )
            return res._replace(ids=index.translate_ids(res.ids))

        return cls(
            fn,
            dim=index.cfg.dim,
            batch_size=batch_size,
            timeout_ms=timeout_ms,
            **kwargs,
        )
