"""Request-batching serving core, collection-agnostic.

The jitted search is fixed-shape: one compiled executable per (batch, k,
SearchParams, index geometry) signature. A serving workload, though, is a
stream of single queries arriving at arbitrary times with per-request
knobs, possibly aimed at different *collections* (per-tenant corpora,
per-modality embeddings) served by one process. This engine bridges the
two — the paper's "query threads" as a batching frontend:

  * one or more named **collections** register a search backend each
    (``add_collection``); ``submit`` enqueues one query (optionally with
    its own ``k``/``SearchParams``/``collection``) and returns a future;
  * requests are grouped by ``(collection, k-bin, params, filter)``: each
    distinct group fills its own fixed-shape batch, so per-request knobs
    never force a recompile of an already-warm executable — and requests
    carrying different filter predicates (static args of the compiled
    program) never share a dispatch. Per-request ``k`` is
    rounded UP to the engine's ``k_bins`` grid (results trimmed back to
    the requested k), so the number of compiled shapes — and the padding a
    small k pays — stays bounded no matter how many distinct k values
    clients send;
  * the **compiled executable is keyed by geometry**, not by collection:
    a shared :class:`repro.serve.compile_cache.CompileCache` tracks
    (geometry, batch, resolved params) signatures, so two collections
    with identical geometry dispatch through one warm executable — the
    second collection compiles nothing (hit/miss counters ride
    ``metrics()``);
  * a group dispatches when ``batch_size`` of its requests are pending,
    when ``timeout_ms`` elapses after the first pending request, or on an
    explicit ``flush`` — whichever comes first. The search runs in the
    thread that triggered the dispatch, so one submit() in every
    ``batch_size`` pays the search latency inline — amortized, not hidden;
  * ragged batches are zero-padded to the fixed ``batch_size`` shape (one
    executable per group, no recompiles) and the pad rows' results dropped;
  * results are demultiplexed back to futures in submission order, with
    per-request latency and aggregate QPS / mean-I/O counters.

The engine lock covers only queue and counter bookkeeping — the search
itself runs outside it, so other threads keep enqueuing (and the next
batch keeps filling) while a batch computes.

A collection backend is any ``fn(queries (B, d), k, params) ->
SearchResult``-like pytree with a leading batch axis. ``from_index``
remains the one-collection convenience: it wraps anything speaking the
:class:`repro.core.protocol.VectorIndex` protocol under the collection
name ``"default"``, so pre-multi-collection call sites keep working
unchanged. The database-level surface (create/attach/drop/save/load of
whole collections) lives one layer up in
:class:`repro.serve.service.VectorService`.

The engine is a context manager; ``close()`` flushes pending groups and
is idempotent.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.core.config import SearchParams
from repro.serve.compile_cache import CompileCache, geometry_of, unshared_token

DEFAULT_COLLECTION = "default"


class RequestResult(NamedTuple):
    """One request's slice of the batch result, plus serving metadata."""

    result: Any          # per-request pytree (leaves: leading axis removed)
    latency_ms: float    # submit -> demux wall time
    batch_size: int      # how many real requests shared the dispatch
    batch_index: int     # which dispatch served it (0-based)
    cached: bool = False  # served from the semantic cache, no dispatch


class EngineMetrics(NamedTuple):
    """One lock-consistent snapshot of the engine's serving counters.

    ``metrics()`` captures EVERY source — engine counters and windows,
    compile-cache hit/miss totals, and each streamed collection's live
    fetch counters — under one acquisition of the engine lock, at one
    snapshot instant. Monotonicity contract: the cumulative counters
    (``requests``, ``batches``, ``inserts``, ``deletes``,
    ``compactions``, ``early_exits``, ``compile_*``, ``pages_fetched``,
    ``fetch_hits``, ``fetch_wall_s``, ``semantic_*``) never decrease
    across successive snapshots of one engine, and no counter can run
    ahead of the ``requests`` it belongs to within a snapshot — safe to
    export as Prometheus counters and ``rate()`` over. The remaining
    fields (qps, latency/hops/ios aggregates, occupancy) are gauges
    derived from bounded trailing windows and move both ways.
    """

    requests: int
    batches: int
    # completed requests / wall-clock between the first submit and the most
    # recent demux. 0.0 until at least one dispatch has completed AND a
    # nonzero wall has elapsed — a single instantaneous batch (or a mocked
    # clock) has no measurable wall, and reporting inf for it poisoned
    # downstream aggregation.
    qps: float
    latency_ms_mean: float     # over the trailing latency window
    latency_ms_p50: float
    latency_ms_p99: float
    mean_ios: float            # mean disk page reads per request
    mean_batch_occupancy: float  # real requests per dispatched batch
    padded_fraction: float     # pad rows / dispatched rows
    inserts: int = 0           # vectors written through engine.insert
    deletes: int = 0           # ids removed through engine.delete
    compactions: int = 0       # compact() calls that folded the delta
    collections: int = 0       # registered collections
    compile_hits: int = 0      # dispatches served by an already-warm executable
    compile_misses: int = 0    # dispatches that compiled a new executable
    compiled_executables: int = 0  # distinct (geometry, batch, params) signatures
    # streaming page tier (summed over collections with a MemoryBudget):
    pages_fetched: int = 0     # page records read off the host memmap
    fetch_hits: int = 0        # page requests served by the staging cache
    fetch_wall_s: float = 0.0  # wall seconds inside the host fetch callback
    # traversal cost per request (trailing window over SearchResult
    # counters) — where adaptive early termination shows up in serving
    mean_hops: float = 0.0     # mean while_loop hops per request
    p99_hops: float = 0.0
    p99_ios: float = 0.0
    # requests whose search exited before the resolved params' max_hops
    # (early termination, beam exhaustion, or convergence)
    early_exits: int = 0
    # requests whose deadline_ms passed while still queued: completed
    # exceptionally with TimeoutError, never dispatched (admission
    # control's load-shedding signal)
    sheds: int = 0
    # semantic query cache (populated by VectorService when one is
    # installed; the bare engine reports zeros)
    semantic_hits: int = 0          # submits served from the cache
    semantic_misses: int = 0        # submits that fell through to a dispatch
    semantic_evictions: int = 0     # entries dropped by LRU or TTL
    semantic_invalidations: int = 0  # entries dropped by writes


class _Pending(NamedTuple):
    future: Future
    query: np.ndarray
    k: int               # the k the caller asked for (<= the group's k bin)
    t_submit: float
    rid: int             # engine-wide request id (trace span track key)
    # absolute engine-clock time after which this request is shed instead
    # of dispatched (None = wait forever). Expiry applies only while
    # QUEUED: once taken into a batch the request completes normally.
    deadline: float | None = None


class _Collection(NamedTuple):
    """One named backend behind the shared batching core."""

    name: str
    search_fn: Callable[[np.ndarray, int, SearchParams | None], Any]
    dim: int
    default_k: int
    default_params: SearchParams | None
    geometry: tuple      # compile-cache geometry key (see compile_cache)
    resolve_fn: Callable | None   # (k, params) -> resolved SearchParams
    insert_fn: Callable | None
    delete_fn: Callable | None
    compact_fn: Callable | None
    # () -> {pages_fetched, fetch_hits, fetch_wall_s}; None when the
    # backend has no streaming page tier
    fetch_stats_fn: Callable | None = None
    # whether search_fn takes a 4th positional arg (a FilterExpr): True
    # for index-backed collections whose search exposes filter=; raw
    # three-arg closures reject filtered submits up front
    accepts_filter: bool = False
    # QoS dispatch weight: when several groups are due, the one with the
    # highest priority * queue-age dispatches first (weighted aging —
    # high-priority collections win contended slots, low-priority ones
    # age their way in instead of starving)
    priority: float = 1.0


class BatchingEngine:
    def __init__(
        self,
        search_fn: Callable[[np.ndarray, int, SearchParams | None], Any]
        | None = None,
        *,
        dim: int | None = None,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        default_k: int | None = None,
        default_params: SearchParams | None = None,
        k_bins: tuple[int, ...] | None = None,
        latency_window: int = 8192,
        dtype=np.float32,
        clock: Callable[[], float] = time.perf_counter,
        insert_fn: Callable[[np.ndarray, Any], np.ndarray] | None = None,
        delete_fn: Callable[[Any], int] | None = None,
        compact_fn: Callable[[], bool] | None = None,
        compile_cache: CompileCache | None = None,
        tracer=None,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if k_bins is not None and (not k_bins or min(k_bins) < 1):
            raise ValueError("k_bins must be a non-empty tuple of positive ints")
        self._batch_size = batch_size
        self._timeout_ms = timeout_ms
        self._k_bins = tuple(sorted(k_bins)) if k_bins else None
        self._dtype = dtype
        self._clock = clock
        self._lock = threading.RLock()
        self._collections: dict[str, _Collection] = {}
        # (collection, k_bin, params, filter) -> pending requests of that group
        self._pending: dict[tuple, list[_Pending]] = {}
        self._timer: threading.Timer | None = None
        self._timer_gen = 0     # invalidates stale timers (see _flush_due)
        self._closed = False
        self._compile_cache = compile_cache or CompileCache()
        # request tracing (duck-typed — anything with .enabled/.add; see
        # repro.obs.trace.Tracer). Spans are stamped with the ENGINE's
        # injected clock via tracer.add, so a fake engine clock yields a
        # coherent trace. None = tracing off with zero hot-path cost.
        self._tracer = tracer
        self._rid = 0
        # aggregate counters (window-bounded where they would otherwise grow)
        self._latencies_ms: collections.deque = collections.deque(
            maxlen=latency_window
        )
        # per-request traversal cost (SearchResult hops/ios), same window
        self._hops_win: collections.deque = collections.deque(
            maxlen=latency_window
        )
        self._ios_win: collections.deque = collections.deque(
            maxlen=latency_window
        )
        self._early_exits = 0
        self._sheds = 0
        self._inserts = 0
        self._deletes = 0
        self._compactions = 0
        self._completed = 0
        self._total_ios = 0.0
        self._batches = 0
        self._dispatched_rows = 0
        self._padded_rows = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        if search_fn is not None:
            # one-collection compatibility construction: the raw backend
            # becomes the "default" collection
            if dim is None:
                raise ValueError("dim is required when search_fn is given")
            self.add_collection(
                DEFAULT_COLLECTION,
                search_fn,
                dim=dim,
                default_k=default_k,
                default_params=default_params,
                insert_fn=insert_fn,
                delete_fn=delete_fn,
                compact_fn=compact_fn,
            )
        elif any(
            f is not None
            for f in (dim, default_k, default_params, insert_fn, delete_fn,
                      compact_fn)
        ):
            raise ValueError(
                "per-collection arguments need search_fn (or use "
                "add_collection on an empty engine)"
            )

    # ------------------------------------------------------- context manager
    def __enter__(self) -> "BatchingEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ---------------------------------------------------------- collections
    def add_collection(
        self,
        name: str,
        search_fn: Callable[[np.ndarray, int, SearchParams | None], Any]
        | None = None,
        *,
        index=None,
        dim: int | None = None,
        default_k: int | None = None,
        default_params: SearchParams | None = None,
        insert_fn: Callable | None = None,
        delete_fn: Callable | None = None,
        compact_fn: Callable | None = None,
        geometry: tuple | None = None,
        resolve_fn: Callable | None = None,
        mesh=None,
        priority: float = 1.0,
    ) -> None:
        """Register a named collection on the shared batching core.

        Either pass a raw ``search_fn`` + ``dim``, or ``index=`` anything
        speaking the :class:`repro.core.protocol.VectorIndex` protocol —
        its search/write surface and compile-cache geometry are derived
        automatically (a ``MutableVectorIndex`` wires insert/delete/
        compact; a ``PageANNIndex`` with ``mesh=`` dispatches
        ``shard_search`` over it).
        """
        if not name or not isinstance(name, str):
            raise ValueError("collection name must be a non-empty string")
        priority = float(priority)
        if not priority > 0:
            raise ValueError("priority must be > 0")
        accepts_filter = False
        if index is not None:
            if search_fn is not None:
                raise ValueError("pass either search_fn or index, not both")
            import inspect

            accepts_filter = "filter" in inspect.signature(
                index.search
            ).parameters

            def search_fn(queries, k_bin, p, flt=None, _index=index,
                          _mesh=mesh):
                kw = {}
                if _mesh is not None:
                    kw["mesh"] = _mesh
                if flt is not None:
                    kw["filter"] = flt
                return _index.search(queries, k=k_bin, params=p, **kw)

            dim = index.dim
            if default_params is None:
                default_params = getattr(index, "default_params", None)
            geometry = geometry if geometry is not None else geometry_of(index)
            if mesh is not None:
                # a mesh-dispatched collection compiles shard_search, not
                # batch_search: same index geometry, different executable —
                # the mesh must be part of the compile identity
                geometry = geometry + (("mesh", mesh),)
            if resolve_fn is None:
                resolve_fn = getattr(index, "resolve_params", None)
            insert_fn = insert_fn or getattr(index, "insert", None)
            delete_fn = delete_fn or getattr(index, "delete", None)
            compact_fn = compact_fn or getattr(index, "compact", None)
            fetch_stats_fn = getattr(index, "fetch_stats", None)
            # streamed indexes: hang the engine's tracer on the host-side
            # page fetcher so per-hop fetch callbacks show up as child
            # spans of the dispatch that triggered them
            fetcher = getattr(index, "fetcher", None)
            if fetcher is not None and self._tracer is not None:
                fetcher.tracer = self._tracer
        else:
            fetch_stats_fn = None
        if search_fn is None or dim is None:
            raise ValueError("add_collection needs (search_fn, dim) or index=")
        # same precedence as resolve_search_params: an explicit default_k
        # wins, otherwise the configured params speak, otherwise k=10
        if default_k is None:
            default_k = default_params.k if default_params is not None else 10
        if geometry is None:
            # a raw closure's compiled identity is the closure itself
            geometry = ("fn", unshared_token(search_fn))
        col = _Collection(
            name=name,
            search_fn=search_fn,
            dim=int(dim),
            default_k=int(default_k),
            default_params=default_params,
            geometry=geometry,
            resolve_fn=resolve_fn,
            insert_fn=insert_fn,
            delete_fn=delete_fn,
            compact_fn=compact_fn,
            fetch_stats_fn=fetch_stats_fn,
            accepts_filter=accepts_filter,
            priority=priority,
        )
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if name in self._collections:
                raise ValueError(f"collection {name!r} already exists")
            self._collections[name] = col

    def remove_collection(self, name: str) -> None:
        """Unregister ``name`` after dispatching its pending groups. Later
        submits to it raise ``KeyError``; other collections are untouched.

        Loops flush -> check-empty-under-lock -> pop, because a concurrent
        ``submit`` that resolved the collection before this call may enqueue
        *between* a flush and the pop; popping only once the collection's
        pending set is observed empty under the lock (after which submit's
        own under-lock registration re-check raises) guarantees no future
        is stranded undispatched."""
        with self._lock:
            if name not in self._collections:
                raise KeyError(f"no collection {name!r}")
        while True:
            self.flush(collection=name)
            with self._lock:
                if not any(
                    grp and key[0] == name
                    for key, grp in self._pending.items()
                ):
                    self._collections.pop(name, None)
                    return

    def collections(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._collections))

    def _resolve_collection(self, name: str | None) -> _Collection:
        """Route a request: an explicit name must exist; ``None`` falls back
        to the sole registered collection (or one literally named
        "default"), so one-collection engines keep the old call shape."""
        with self._lock:
            if name is not None:
                try:
                    return self._collections[name]
                except KeyError:
                    raise KeyError(
                        f"no collection {name!r}; have "
                        f"{sorted(self._collections)}"
                    ) from None
            if len(self._collections) == 1:
                return next(iter(self._collections.values()))
            if DEFAULT_COLLECTION in self._collections:
                return self._collections[DEFAULT_COLLECTION]
            if not self._collections:
                raise RuntimeError("engine has no collections")
            raise ValueError(
                "multiple collections are registered; pass collection= "
                f"(one of {sorted(self._collections)})"
            )

    # ------------------------------------------------------------- requests
    def _bin_k(self, k: int) -> int:
        """Round k up to the engine's k grid (bounded compiled shapes)."""
        if self._k_bins is None:
            return k
        for b in self._k_bins:
            if b >= k:
                return b
        return k  # above the grid: its own exact shape

    def submit(
        self,
        query: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        collection: str | None = None,
        filter=None,
        deadline_ms: float | None = None,
    ) -> Future:
        """Enqueue one (d,) query; returns a Future[RequestResult].

        ``k``/``params`` default to the target collection's; requests
        sharing a (collection, k-bin, params, filter) group share one
        fixed-shape dispatch. The filter expression is part of the group
        key: a batch is a SINGLE backend call, and the predicate is a
        static argument of its compiled program — two requests with
        different predicates can never share a dispatch.

        ``deadline_ms`` bounds QUEUE time: a request still pending when
        its deadline passes completes exceptionally with ``TimeoutError``
        (counted as ``sheds`` in :class:`EngineMetrics`) instead of
        waiting forever. Once taken into a batch it completes normally —
        the deadline sheds load, it does not cancel dispatched work.
        """
        if deadline_ms is not None and not deadline_ms > 0:
            raise ValueError("deadline_ms must be > 0")
        col = self._resolve_collection(collection)
        if filter is not None and not col.accepts_filter:
            raise ValueError(
                f"collection {col.name!r} does not support filtered "
                "search (raw search_fn backends take no filter)"
            )
        q = np.asarray(query, self._dtype).reshape(-1)
        if q.shape[0] != col.dim:
            raise ValueError(
                f"query dim {q.shape[0]} != collection {col.name!r} dim "
                f"{col.dim}"
            )
        if k is None:
            # an explicit SearchParams speaks for the request: its k wins
            # over the collection default unless the kwarg overrides it
            k = params.k if params is not None else col.default_k
        k = int(k)
        if k < 1:
            raise ValueError("k must be >= 1")
        params = params if params is not None else col.default_params
        key = (col.name, self._bin_k(k), params, filter)
        fut: Future = Future()
        batch = None
        tr = self._tracer
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if col.name not in self._collections:
                # lost a race with remove_collection after resolving the
                # collection: refuse rather than strand the future in a
                # group nothing will ever dispatch
                raise KeyError(f"no collection {col.name!r}")
            if self._t_first is None:
                self._t_first = self._clock()
            self._rid += 1
            rid = self._rid
            t_submit = self._clock()
            deadline = (
                t_submit + deadline_ms / 1e3 if deadline_ms is not None
                else None
            )
            group = self._pending.setdefault(key, [])
            group.append(_Pending(fut, q, k, t_submit, rid, deadline))
            if len(group) >= self._batch_size:
                batch, shed = self._take_locked(key)
            else:
                shed = ()
                self._arm_timer_locked()
        if tr is not None and tr.enabled:
            tr.add("submit", t_submit, t_submit, cat="request",
                   track=f"req-{rid}",
                   args={"collection": col.name, "k": k})
        self._fail_shed(shed)
        if batch is not None:
            self._run_batch(key, batch)
        return fut

    def flush(self, collection: str | None = None) -> None:
        """Dispatch whatever is pending — in every group, or only the named
        collection's groups — padding ragged batches. When several groups
        are eligible the highest ``priority * queue-age`` dispatches
        first (weighted aging: see ``add_collection(priority=)``)."""
        while True:
            with self._lock:
                key = self._next_key_locked(collection)
                batch, shed = (
                    self._take_locked(key) if key is not None else (None, ())
                )
            self._fail_shed(shed)
            if batch is None:
                return
            self._run_batch(key, batch)

    def _next_key_locked(self, collection: str | None = None):
        """Pick the next pending group to dispatch: weighted aging over
        collection priorities. Caller must hold the lock."""
        now = self._clock()
        best_key, best_rank = None, -1.0
        for key, grp in self._pending.items():
            if not grp or (collection is not None and key[0] != collection):
                continue
            col = self._collections.get(key[0])
            weight = col.priority if col is not None else 1.0
            # +1ms age floor so brand-new groups still rank by priority
            rank = weight * (now - grp[0].t_submit + 1e-3)
            if rank > best_rank:
                best_key, best_rank = key, rank
        return best_key

    def search(
        self,
        queries: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        collection: str | None = None,
        filter=None,
    ) -> list[RequestResult]:
        """Synchronous convenience: submit a (Q, d) batch, flush, gather."""
        futs = [
            self.submit(
                q, k=k, params=params, collection=collection, filter=filter
            )
            for q in np.asarray(queries)
        ]
        self.flush(collection=collection)
        return [f.result() for f in futs]

    # --------------------------------------------------------------- writes
    # Write requests run inline against the collection's mutable backend;
    # the backend (``core.delta.MutableIndex``) publishes each mutation as
    # ONE atomic state swap, so in-flight search dispatches — which
    # snapshot that state lock-free at backend-call time — interleave
    # safely: a search sees either the pre- or post-write index, never a
    # half-applied one.

    def insert(
        self, vectors: np.ndarray, ids=None, *,
        collection: str | None = None, metadata=None,
    ) -> np.ndarray:
        """Insert vectors into a collection's mutable backend; returns their
        external ids. Raises if the collection wraps an immutable index.
        ``metadata`` (validated against the backend's schema) makes the new
        rows filterable immediately."""
        col = self._resolve_collection(collection)
        if col.insert_fn is None:
            raise RuntimeError(
                f"collection {col.name!r} does not support insert"
            )
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
        vectors = np.asarray(vectors, self._dtype).reshape(-1, col.dim)
        tr = self._tracer
        tracing = tr is not None and tr.enabled
        t0 = self._clock() if tracing else 0.0
        out = (
            col.insert_fn(vectors, ids, metadata=metadata)
            if metadata is not None
            else col.insert_fn(vectors, ids)
        )
        if tracing:
            tr.add("insert", t0, self._clock(), cat="write", track="writes",
                   args={"collection": col.name, "rows": vectors.shape[0]})
        with self._lock:
            self._inserts += vectors.shape[0]
        return out

    def delete(self, ids, *, collection: str | None = None) -> int:
        """Delete ids from a collection's mutable backend; returns how many
        were live."""
        col = self._resolve_collection(collection)
        if col.delete_fn is None:
            raise RuntimeError(
                f"collection {col.name!r} does not support delete"
            )
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
        tr = self._tracer
        tracing = tr is not None and tr.enabled
        t0 = self._clock() if tracing else 0.0
        removed = col.delete_fn(ids)
        if tracing:
            tr.add("delete", t0, self._clock(), cat="write", track="writes",
                   args={"collection": col.name, "removed": int(removed)})
        with self._lock:
            self._deletes += removed
        return removed

    def compact(self, *, collection: str | None = None) -> bool:
        """Fold a collection's delta tier into a fresh base artifact.
        Pending searches keep completing against the pre-compaction
        snapshot while the rebuild runs."""
        col = self._resolve_collection(collection)
        if col.compact_fn is None:
            raise RuntimeError(
                f"collection {col.name!r} does not support compact"
            )
        tr = self._tracer
        tracing = tr is not None and tr.enabled
        t0 = self._clock() if tracing else 0.0
        did = col.compact_fn()
        if tracing:
            tr.add("compact", t0, self._clock(), cat="write", track="writes",
                   args={"collection": col.name, "compacted": bool(did)})
        if did:
            with self._lock:
                self._compactions += 1
        return did

    def close(self) -> None:
        """Flush pending groups and shut down. Idempotent — a second
        ``close()`` (e.g. explicit call inside a ``with`` block) is a
        no-op."""
        with self._lock:
            if self._closed:
                return
        self.flush()
        with self._lock:
            self._closed = True
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None

    # ------------------------------------------------------------- dispatch
    def _flush_due(self, gen: int) -> None:
        """Timer callback: dispatch only the groups whose OLDEST request has
        aged past the timeout, then re-arm for whatever remains — a timer
        fired by one stale group must not flush a just-arrived group into a
        near-empty padded batch. A timer that raced a size-triggered
        dispatch (its generation was retired by _take_locked before it got
        the lock) must no-op, or it would prematurely flush the NEXT
        batch."""
        with self._lock:
            if gen != self._timer_gen or self._closed:
                return
            self._timer = None
        timeout_s = (
            self._timeout_ms / 1e3 if self._timeout_ms is not None else None
        )
        while True:
            with self._lock:
                now = self._clock()
                # reap requests whose per-request deadline expired while
                # queued — they complete with TimeoutError, not a dispatch
                shed = self._reap_expired_locked(now)
                key = None
                if timeout_s is not None:
                    due = [
                        key
                        for key, grp in self._pending.items()
                        if grp and now - grp[0].t_submit >= timeout_s
                    ]
                    if due:
                        # among due groups, weighted priority picks first
                        key = max(
                            due,
                            key=lambda kk: (
                                getattr(
                                    self._collections.get(kk[0]), "priority",
                                    1.0,
                                )
                                * (now - self._pending[kk][0].t_submit)
                            ),
                        )
                if key is not None:
                    batch, shed2 = self._take_locked(key)
                    shed += shed2
                else:
                    batch = None
                    self._arm_timer_locked()
            self._fail_shed(shed)
            if batch is None:
                return
            self._run_batch(key, batch)

    def _reap_expired_locked(self, now: float) -> list[_Pending]:
        """Drop every queued request whose deadline has passed; returns
        them for the caller to fail OUTSIDE the lock (Future callbacks run
        inline). Caller must hold the lock."""
        shed: list[_Pending] = []
        for key in list(self._pending):
            grp = self._pending[key]
            keep = [p for p in grp if p.deadline is None or p.deadline > now]
            if len(keep) != len(grp):
                shed.extend(
                    p for p in grp if p.deadline is not None
                    and p.deadline <= now
                )
                if keep:
                    self._pending[key] = keep
                else:
                    self._pending.pop(key, None)
        self._sheds += len(shed)
        return shed

    def _fail_shed(self, shed) -> None:
        """Complete shed requests exceptionally — never under the engine
        lock (``Future.set_exception`` runs done-callbacks inline)."""
        tr = self._tracer
        for p in shed:
            if tr is not None and tr.enabled:
                now = self._clock()
                tr.add("shed", p.t_submit, now, cat="request",
                       track=f"req-{p.rid}")
            p.future.set_exception(
                TimeoutError(
                    f"request {p.rid} deadline passed after "
                    f"{(self._clock() - p.t_submit) * 1e3:.1f}ms in queue"
                )
            )

    def _arm_timer_locked(self) -> None:
        """Start the timeout timer if requests are pending and none is live.
        The delay is measured from the OLDEST pending submit, not reset to
        the full duration — otherwise steady full-batch traffic in one
        group would push a sparse group's deadline out forever. Pending
        per-request deadlines arm the timer too (even with no engine
        timeout configured), so an expired request is reaped promptly
        rather than on the next unrelated dispatch. Caller must hold the
        lock."""
        if (
            self._timer is not None
            or self._closed
            or not any(self._pending.values())
        ):
            return
        now = self._clock()
        delays = []
        if self._timeout_ms is not None:
            oldest = min(
                p.t_submit for grp in self._pending.values() for p in grp
            )
            delays.append(self._timeout_ms / 1e3 - (now - oldest))
        deadlines = [
            p.deadline
            for grp in self._pending.values()
            for p in grp
            if p.deadline is not None
        ]
        if deadlines:
            delays.append(min(deadlines) - now)
        if not delays:
            return
        delay = max(0.0, min(delays))
        gen = self._timer_gen
        self._timer = threading.Timer(
            delay, self._flush_due, args=(gen,)
        )
        self._timer.daemon = True
        self._timer.start()

    def _take_locked(
        self, key: tuple
    ) -> tuple[tuple[int, list[_Pending]] | None, list[_Pending]]:
        """Pop up to batch_size pending requests of one group and retire the
        live timer — re-arming it when OTHER groups still hold pending
        requests, so a size-triggered dispatch of one (collection, k-bin,
        params) group never strands another group's waiters. Requests
        whose deadline already passed are pruned here (returned as the
        second element for the caller to fail outside the lock), so an
        expired request never consumes a batch slot. Caller must hold the
        lock; the batch index is assigned here so dispatch order matches
        take order even with concurrent submitters. Returns
        ``((batch_index, take), shed)``; the batch is None when pruning
        left nothing to dispatch."""
        group = self._pending.get(key, [])
        now = self._clock()
        shed = [
            p for p in group if p.deadline is not None and p.deadline <= now
        ]
        if shed:
            self._sheds += len(shed)
            group = [
                p for p in group
                if p.deadline is None or p.deadline > now
            ]
        take = group[: self._batch_size]
        rest = group[self._batch_size:]
        if rest:
            self._pending[key] = rest
        else:
            # drop drained keys: distinct (collection, k, params)
            # combinations must not accumulate empty entries in a
            # long-lived server
            self._pending.pop(key, None)
        self._timer_gen += 1
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._arm_timer_locked()
        if not take:
            return None, shed
        batch_index = self._batches
        self._batches += 1
        return (batch_index, take), shed

    def _run_batch(self, key: tuple, batch: tuple[int, list[_Pending]]) -> None:
        """Pad, search (outside the lock), record counters, demux."""
        name, k_bin, params, flt = key
        batch_index, take = batch
        n = len(take)
        tr = self._tracer
        tracing = tr is not None and tr.enabled
        t_take = self._clock() if tracing else 0.0
        with self._lock:
            col = self._collections.get(name)
        if col is None:
            # the collection was dropped between take and run (concurrent
            # remove_collection): fail this group's waiters, not the engine
            exc = RuntimeError(f"collection {name!r} was dropped")
            with self._lock:
                self._dispatched_rows += self._batch_size
                self._padded_rows += self._batch_size - n
            for p in take:
                p.future.set_exception(exc)
            return
        padded = np.zeros((self._batch_size, col.dim), self._dtype)
        for i, p in enumerate(take):
            padded[i] = p.query
        # compiled-executable accounting: the cache key is the collection's
        # GEOMETRY (not its name) plus everything else static in the jit
        # signature — batch shape and the resolved runtime knobs — so two
        # same-geometry collections register as one executable
        try:
            resolved = (
                col.resolve_fn(k_bin, params)
                if col.resolve_fn is not None
                else (k_bin, params)
            )
        except Exception:
            resolved = (k_bin, params)
        warm = self._compile_cache.note(
            col.geometry + (self._batch_size, resolved)
            + ((("filter", flt),) if flt is not None else ())
        )
        if tracing:
            t_pad = self._clock()
            tr.add("batch_assemble", t_take, t_pad, cat="engine",
                   track="engine",
                   args={"collection": name, "batch_index": batch_index,
                         "n": n})
            for p in take:
                tr.add("queue_wait", p.t_submit, t_take, cat="request",
                       track=f"req-{p.rid}")
        t_call = self._clock() if tracing else 0.0
        try:
            out = (
                col.search_fn(padded, k_bin, params, flt)
                if col.accepts_filter
                else col.search_fn(padded, k_bin, params)
            )
            out = jax.tree.map(np.asarray, out)
        except Exception as e:
            # a backend failure must reach every waiter of THIS group
            # through its future — not hang them, not vanish into the timer
            # thread's excepthook, and not poison other groups' dispatches
            # (submit/flush never raise backend errors)
            with self._lock:
                self._dispatched_rows += self._batch_size
                self._padded_rows += self._batch_size - n
            for p in take:
                p.future.set_exception(e)
            return

        t_done = self._clock()
        if tracing:
            # a cold dispatch's wall includes trace+compile: overlay a
            # "compile" span on the dispatch that paid it
            tr.add("device_dispatch", t_call, t_done, cat="engine",
                   track="engine",
                   args={"collection": name, "batch_index": batch_index,
                         "n": n, "compiled": not warm})
            if not warm:
                tr.add("compile", t_call, t_done, cat="compile",
                       track="engine", args={"collection": name})
        ios = getattr(out, "ios", None)
        hops = getattr(out, "hops", None)
        latencies = [(t_done - p.t_submit) * 1e3 for p in take]
        with self._lock:
            self._dispatched_rows += self._batch_size
            self._padded_rows += self._batch_size - n
            self._t_last = t_done
            self._completed += n
            self._latencies_ms.extend(latencies)
            if ios is not None:
                self._total_ios += float(np.sum(ios[:n]))
                self._ios_win.extend(np.asarray(ios[:n]).ravel().tolist())
            if hops is not None:
                self._hops_win.extend(np.asarray(hops[:n]).ravel().tolist())
                if isinstance(resolved, SearchParams):
                    # requests that exited the hop loop before the resolved
                    # params' bound: adaptive early termination (or natural
                    # beam exhaustion) visibly saving page reads
                    self._early_exits += int(
                        np.sum(np.asarray(hops[:n]) < resolved.max_hops)
                    )
        for i, p in enumerate(take):
            row = jax.tree.map(lambda a: a[i], out)
            if p.k < k_bin:
                # k was rounded up to the bin: trim the result axes back
                row = jax.tree.map(
                    lambda a: a[: p.k]
                    if getattr(a, "ndim", 0) >= 1 and a.shape[0] == k_bin
                    else a,
                    row,
                )
            p.future.set_result(
                RequestResult(
                    result=row,
                    latency_ms=latencies[i],
                    batch_size=n,
                    batch_index=batch_index,
                )
            )
        if tracing:
            t_end = self._clock()
            tr.add("demux", t_done, t_end, cat="engine", track="engine",
                   args={"batch_index": batch_index, "n": n})
            for i, p in enumerate(take):
                tr.add("request", p.t_submit, t_end, cat="request",
                       track=f"req-{p.rid}",
                       args={"latency_ms": latencies[i],
                             "batch_index": batch_index})

    # -------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        """One atomic, lock-consistent snapshot (see ``EngineMetrics``).

        Everything — windows, counters, compile-cache stats, and each
        streamed collection's live fetch counters — is captured under a
        SINGLE acquisition of the engine lock, so a snapshot taken while
        the dispatch/timer threads run never mixes a group of counters
        from before a batch with a group from after it (two separate
        lock sections here used to let ``fetch_wall_s`` run ahead of the
        ``requests`` it belonged to). The compile-cache and fetcher
        locks are leaf locks — their holders never call back into the
        engine — so taking them under the engine lock cannot deadlock.
        """
        with self._lock:
            cc = self._compile_cache.stats()
            pages_fetched = fetch_hits = 0
            fetch_wall_s = 0.0
            for c in self._collections.values():
                if c.fetch_stats_fn is None:
                    continue
                fs = c.fetch_stats_fn()
                pages_fetched += int(fs.get("pages_fetched", 0))
                fetch_hits += int(fs.get("fetch_hits", 0))
                fetch_wall_s += float(fs.get("fetch_wall_s", 0.0))
            lat = np.asarray(self._latencies_ms, np.float64)
            hops_win = np.asarray(self._hops_win, np.float64)
            ios_win = np.asarray(self._ios_win, np.float64)
            done = self._completed
            wall = (
                (self._t_last - self._t_first)
                if done and self._t_last is not None
                else 0.0
            )
            return EngineMetrics(
                requests=done,
                batches=self._batches,
                qps=done / wall if wall > 0 else 0.0,
                latency_ms_mean=float(lat.mean()) if len(lat) else 0.0,
                latency_ms_p50=float(np.percentile(lat, 50)) if len(lat) else 0.0,
                latency_ms_p99=float(np.percentile(lat, 99)) if len(lat) else 0.0,
                mean_ios=self._total_ios / done if done else 0.0,
                mean_batch_occupancy=(
                    (self._dispatched_rows - self._padded_rows) / self._batches
                    if self._batches
                    else 0.0
                ),
                padded_fraction=(
                    self._padded_rows / self._dispatched_rows
                    if self._dispatched_rows
                    else 0.0
                ),
                inserts=self._inserts,
                deletes=self._deletes,
                compactions=self._compactions,
                collections=len(self._collections),
                compile_hits=cc.hits,
                compile_misses=cc.misses,
                compiled_executables=cc.unique,
                pages_fetched=pages_fetched,
                fetch_hits=fetch_hits,
                fetch_wall_s=fetch_wall_s,
                mean_hops=float(hops_win.mean()) if len(hops_win) else 0.0,
                p99_hops=(
                    float(np.percentile(hops_win, 99)) if len(hops_win) else 0.0
                ),
                p99_ios=(
                    float(np.percentile(ios_win, 99)) if len(ios_win) else 0.0
                ),
                early_exits=self._early_exits,
                sheds=self._sheds,
            )

    def metrics_windows(self) -> dict:
        """The raw trailing windows behind the quantile gauges, as one
        atomic snapshot: ``latency_ms`` / ``hops`` / ``ios`` (the
        bounded per-request deques) plus ``fetch_wall_s`` (per-callback
        wall seconds from every streamed collection's fetcher, itself
        window-bounded). Feed of the exposition layer's histograms —
        window-scoped distributions, not cumulative series."""
        with self._lock:
            wall: list = []
            for c in self._collections.values():
                if c.fetch_stats_fn is None:
                    continue
                wall.extend(c.fetch_stats_fn().get("wall_window", ()))
            return dict(
                latency_ms=np.asarray(self._latencies_ms, np.float64),
                hops=np.asarray(self._hops_win, np.float64),
                ios=np.asarray(self._ios_win, np.float64),
                fetch_wall_s=np.asarray(wall, np.float64),
            )

    # ------------------------------------------------------------- builders
    @classmethod
    def from_index(
        cls,
        index,
        *,
        k: int | None = None,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        params: SearchParams | None = None,
        k_bins: tuple[int, ...] | None = None,
        mesh=None,
        **kwargs,
    ) -> "BatchingEngine":
        """One-collection engine over any built/loaded ``VectorIndex``;
        results carry ORIGINAL vector ids.

        Thin compatibility wrapper over the multi-collection core: the
        index is registered as the collection named ``"default"``, so the
        pre-service call shape (``submit`` with no collection) keeps
        working. The backend is the protocol's ``index.search(queries, k,
        params)`` — PageANN, DiskANN, Starling, or a ``MutableIndex``
        alike. When the index speaks the ``MutableVectorIndex`` writes
        (insert/delete/compact), the engine exposes them as request types
        that interleave safely with in-flight searches. For a
        ``PageANNIndex``, passing a mesh (see ``launch.mesh``) dispatches
        ``shard_search`` with the query batch split across it.
        """
        eng = cls(
            batch_size=batch_size,
            timeout_ms=timeout_ms,
            k_bins=k_bins,
            **kwargs,
        )
        eng.add_collection(
            DEFAULT_COLLECTION,
            index=index,
            default_k=k,
            default_params=params,
            mesh=mesh,
        )
        return eng
