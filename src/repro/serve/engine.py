"""Request-batching serving frontend for any ``VectorIndex`` backend.

The jitted search is fixed-shape: one compiled executable per (batch, k,
SearchParams) triple. A serving workload, though, is a stream of single
queries arriving at arbitrary times with per-request knobs. This engine
bridges the two — the paper's "query threads" as a batching frontend:

  * ``submit`` enqueues one query (optionally with its own ``k`` and
    ``SearchParams``) and returns a future;
  * requests are grouped by (k-bin, params): each distinct group fills its
    own fixed-shape batch, so per-request knobs never force a recompile of
    an already-warm executable. Per-request ``k`` is rounded UP to the
    engine's ``k_bins`` grid (results trimmed back to the requested k), so
    the number of compiled shapes — and the padding a small k pays — stays
    bounded no matter how many distinct k values clients send;
  * a group dispatches when ``batch_size`` of its requests are pending,
    when ``timeout_ms`` elapses after the first pending request, or on an
    explicit ``flush`` — whichever comes first. The search runs in the
    thread that triggered the dispatch, so one submit() in every
    ``batch_size`` pays the search latency inline — amortized, not hidden;
  * ragged batches are zero-padded to the fixed ``batch_size`` shape (one
    executable per group, no recompiles) and the pad rows' results dropped;
  * results are demultiplexed back to futures in submission order, with
    per-request latency and aggregate QPS / mean-I/O counters.

The engine lock covers only queue and counter bookkeeping — the search
itself runs outside it, so other threads keep enqueuing (and the next
batch keeps filling) while a batch computes.

The backend is any ``fn(queries (B, d), k, params) -> SearchResult``-like
pytree with a leading batch axis. ``from_index`` wraps anything speaking
the :class:`repro.core.protocol.VectorIndex` protocol — ``PageANNIndex``
(optionally sharded over a mesh) or the DiskANN/Starling baselines.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.core.config import SearchParams


class RequestResult(NamedTuple):
    """One request's slice of the batch result, plus serving metadata."""

    result: Any          # per-request pytree (leaves: leading axis removed)
    latency_ms: float    # submit -> demux wall time
    batch_size: int      # how many real requests shared the dispatch
    batch_index: int     # which dispatch served it (0-based)


class EngineMetrics(NamedTuple):
    requests: int
    batches: int
    qps: float                 # completed requests / wall time since first submit
    latency_ms_mean: float     # over the trailing latency window
    latency_ms_p50: float
    latency_ms_p99: float
    mean_ios: float            # mean disk page reads per request
    mean_batch_occupancy: float  # real requests per dispatched batch
    padded_fraction: float     # pad rows / dispatched rows
    inserts: int = 0           # vectors written through engine.insert
    deletes: int = 0           # ids removed through engine.delete
    compactions: int = 0       # compact() calls that folded the delta


class _Pending(NamedTuple):
    future: Future
    query: np.ndarray
    k: int               # the k the caller asked for (<= the group's k bin)
    t_submit: float


class BatchingEngine:
    def __init__(
        self,
        search_fn: Callable[[np.ndarray, int, SearchParams | None], Any],
        *,
        dim: int,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        default_k: int | None = None,
        default_params: SearchParams | None = None,
        k_bins: tuple[int, ...] | None = None,
        latency_window: int = 8192,
        dtype=np.float32,
        clock: Callable[[], float] = time.perf_counter,
        insert_fn: Callable[[np.ndarray, Any], np.ndarray] | None = None,
        delete_fn: Callable[[Any], int] | None = None,
        compact_fn: Callable[[], bool] | None = None,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if k_bins is not None and (not k_bins or min(k_bins) < 1):
            raise ValueError("k_bins must be a non-empty tuple of positive ints")
        self._search_fn = search_fn
        self._dim = dim
        self._batch_size = batch_size
        self._timeout_ms = timeout_ms
        # same precedence as resolve_search_params: an explicit default_k
        # wins, otherwise the configured params speak, otherwise k=10
        if default_k is None:
            default_k = (
                default_params.k if default_params is not None else 10
            )
        self._default_k = default_k
        self._default_params = default_params
        self._k_bins = tuple(sorted(k_bins)) if k_bins else None
        self._dtype = dtype
        self._clock = clock
        self._lock = threading.RLock()
        # (k_bin, params) -> pending requests of that shape/knob group
        self._pending: dict[tuple, list[_Pending]] = {}
        self._timer: threading.Timer | None = None
        self._timer_gen = 0     # invalidates stale timers (see _flush_due)
        self._closed = False
        # aggregate counters (window-bounded where they would otherwise grow)
        self._latencies_ms: collections.deque = collections.deque(
            maxlen=latency_window
        )
        self._insert_fn = insert_fn
        self._delete_fn = delete_fn
        self._compact_fn = compact_fn
        self._inserts = 0
        self._deletes = 0
        self._compactions = 0
        self._completed = 0
        self._total_ios = 0.0
        self._batches = 0
        self._dispatched_rows = 0
        self._padded_rows = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    # ------------------------------------------------------------- requests
    def _bin_k(self, k: int) -> int:
        """Round k up to the engine's k grid (bounded compiled shapes)."""
        if self._k_bins is None:
            return k
        for b in self._k_bins:
            if b >= k:
                return b
        return k  # above the grid: its own exact shape

    def submit(
        self,
        query: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
    ) -> Future:
        """Enqueue one (d,) query; returns a Future[RequestResult].

        ``k``/``params`` default to the engine's; requests sharing a
        (k-bin, params) group share one fixed-shape dispatch.
        """
        q = np.asarray(query, self._dtype).reshape(-1)
        if q.shape[0] != self._dim:
            raise ValueError(f"query dim {q.shape[0]} != engine dim {self._dim}")
        if k is None:
            # an explicit SearchParams speaks for the request: its k wins
            # over the engine default unless the kwarg overrides it
            k = params.k if params is not None else self._default_k
        k = int(k)
        if k < 1:
            raise ValueError("k must be >= 1")
        params = params if params is not None else self._default_params
        key = (self._bin_k(k), params)
        fut: Future = Future()
        batch = None
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            if self._t_first is None:
                self._t_first = self._clock()
            group = self._pending.setdefault(key, [])
            group.append(_Pending(fut, q, k, self._clock()))
            if len(group) >= self._batch_size:
                batch = self._take_locked(key)
            else:
                self._arm_timer_locked()
        if batch is not None:
            self._run_batch(key, batch)
        return fut

    def flush(self) -> None:
        """Dispatch whatever is pending in every group, padding ragged
        batches."""
        while True:
            with self._lock:
                key = next(
                    (key for key, grp in self._pending.items() if grp), None
                )
                batch = self._take_locked(key) if key is not None else None
            if batch is None:
                return
            self._run_batch(key, batch)

    def search(
        self,
        queries: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
    ) -> list[RequestResult]:
        """Synchronous convenience: submit a (Q, d) batch, flush, gather."""
        futs = [
            self.submit(q, k=k, params=params) for q in np.asarray(queries)
        ]
        self.flush()
        return [f.result() for f in futs]

    # --------------------------------------------------------------- writes
    # Write requests run inline against the mutable backend; the backend
    # (``core.delta.MutableIndex``) publishes each mutation as ONE atomic
    # state swap, so in-flight search dispatches — which snapshot that
    # state lock-free at backend-call time — interleave safely: a search
    # sees either the pre- or post-write index, never a half-applied one.

    def insert(self, vectors: np.ndarray, ids=None) -> np.ndarray:
        """Insert vectors into the mutable backend; returns their external
        ids. Raises if the engine wraps an immutable index."""
        if self._insert_fn is None:
            raise RuntimeError("engine backend does not support insert")
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
        vectors = np.asarray(vectors, self._dtype).reshape(-1, self._dim)
        out = self._insert_fn(vectors, ids)
        with self._lock:
            self._inserts += vectors.shape[0]
        return out

    def delete(self, ids) -> int:
        """Delete ids from the mutable backend; returns how many were live."""
        if self._delete_fn is None:
            raise RuntimeError("engine backend does not support delete")
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
        removed = self._delete_fn(ids)
        with self._lock:
            self._deletes += removed
        return removed

    def compact(self) -> bool:
        """Fold the backend's delta tier into a fresh base artifact.
        Pending searches keep completing against the pre-compaction
        snapshot while the rebuild runs."""
        if self._compact_fn is None:
            raise RuntimeError("engine backend does not support compact")
        did = self._compact_fn()
        if did:
            with self._lock:
                self._compactions += 1
        return did

    def close(self) -> None:
        self.flush()
        with self._lock:
            self._closed = True
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None

    # ------------------------------------------------------------- dispatch
    def _flush_due(self, gen: int) -> None:
        """Timer callback: dispatch only the groups whose OLDEST request has
        aged past the timeout, then re-arm for whatever remains — a timer
        fired by one stale group must not flush a just-arrived group into a
        near-empty padded batch. A timer that raced a size-triggered
        dispatch (its generation was retired by _take_locked before it got
        the lock) must no-op, or it would prematurely flush the NEXT
        batch."""
        with self._lock:
            if gen != self._timer_gen or self._closed:
                return
            self._timer = None
        deadline_s = self._timeout_ms / 1e3
        while True:
            with self._lock:
                now = self._clock()
                key = next(
                    (
                        key
                        for key, grp in self._pending.items()
                        if grp and now - grp[0].t_submit >= deadline_s
                    ),
                    None,
                )
                batch = self._take_locked(key) if key is not None else None
                if batch is None:
                    self._arm_timer_locked()
                    return
            self._run_batch(key, batch)

    def _arm_timer_locked(self) -> None:
        """Start the timeout timer if requests are pending and none is live.
        The delay is measured from the OLDEST pending submit, not reset to
        the full duration — otherwise steady full-batch traffic in one
        group would push a sparse group's deadline out forever. Caller must
        hold the lock."""
        if (
            self._timeout_ms is not None
            and self._timer is None
            and not self._closed
            and any(self._pending.values())
        ):
            oldest = min(
                p.t_submit for grp in self._pending.values() for p in grp
            )
            delay = max(
                0.0, self._timeout_ms / 1e3 - (self._clock() - oldest)
            )
            gen = self._timer_gen
            self._timer = threading.Timer(
                delay, self._flush_due, args=(gen,)
            )
            self._timer.daemon = True
            self._timer.start()

    def _take_locked(self, key: tuple) -> tuple[int, list[_Pending]]:
        """Pop up to batch_size pending requests of one group and retire the
        live timer — re-arming it when OTHER groups still hold pending
        requests, so a size-triggered dispatch of one (k-bin, params) group
        never strands another group's waiters. Caller must hold the lock;
        the batch index is assigned here so dispatch order matches take
        order even with concurrent submitters."""
        group = self._pending.get(key, [])
        take = group[: self._batch_size]
        rest = group[self._batch_size:]
        if rest:
            self._pending[key] = rest
        else:
            # drop drained keys: distinct (k, params) combinations must not
            # accumulate empty entries in a long-lived server
            self._pending.pop(key, None)
        self._timer_gen += 1
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        self._arm_timer_locked()
        batch_index = self._batches
        self._batches += 1
        return batch_index, take

    def _run_batch(self, key: tuple, batch: tuple[int, list[_Pending]]) -> None:
        """Pad, search (outside the lock), record counters, demux."""
        k_bin, params = key
        batch_index, take = batch
        n = len(take)
        padded = np.zeros((self._batch_size, self._dim), self._dtype)
        for i, p in enumerate(take):
            padded[i] = p.query
        try:
            out = self._search_fn(padded, k_bin, params)
            out = jax.tree.map(np.asarray, out)
        except Exception as e:
            # a backend failure must reach every waiter through its future —
            # not hang them, and not vanish into the timer thread's
            # excepthook (submit/flush never raise backend errors)
            with self._lock:
                self._dispatched_rows += self._batch_size
                self._padded_rows += self._batch_size - n
            for p in take:
                p.future.set_exception(e)
            return

        t_done = self._clock()
        ios = getattr(out, "ios", None)
        latencies = [(t_done - p.t_submit) * 1e3 for p in take]
        with self._lock:
            self._dispatched_rows += self._batch_size
            self._padded_rows += self._batch_size - n
            self._t_last = t_done
            self._completed += n
            self._latencies_ms.extend(latencies)
            if ios is not None:
                self._total_ios += float(np.sum(ios[:n]))
        for i, p in enumerate(take):
            row = jax.tree.map(lambda a: a[i], out)
            if p.k < k_bin:
                # k was rounded up to the bin: trim the result axes back
                row = jax.tree.map(
                    lambda a: a[: p.k]
                    if getattr(a, "ndim", 0) >= 1 and a.shape[0] == k_bin
                    else a,
                    row,
                )
            p.future.set_result(
                RequestResult(
                    result=row,
                    latency_ms=latencies[i],
                    batch_size=n,
                    batch_index=batch_index,
                )
            )

    # -------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            done = self._completed
            wall = (
                (self._t_last - self._t_first)
                if done and self._t_last is not None
                else 0.0
            )
            return EngineMetrics(
                requests=done,
                batches=self._batches,
                qps=done / wall if wall > 0 else float(done and np.inf),
                latency_ms_mean=float(lat.mean()) if len(lat) else 0.0,
                latency_ms_p50=float(np.percentile(lat, 50)) if len(lat) else 0.0,
                latency_ms_p99=float(np.percentile(lat, 99)) if len(lat) else 0.0,
                mean_ios=self._total_ios / done if done else 0.0,
                mean_batch_occupancy=(
                    (self._dispatched_rows - self._padded_rows) / self._batches
                    if self._batches
                    else 0.0
                ),
                padded_fraction=(
                    self._padded_rows / self._dispatched_rows
                    if self._dispatched_rows
                    else 0.0
                ),
                inserts=self._inserts,
                deletes=self._deletes,
                compactions=self._compactions,
            )

    # ------------------------------------------------------------- builders
    @classmethod
    def from_index(
        cls,
        index,
        *,
        k: int | None = None,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        params: SearchParams | None = None,
        k_bins: tuple[int, ...] | None = None,
        mesh=None,
        **kwargs,
    ) -> "BatchingEngine":
        """Engine over any built/loaded ``VectorIndex``; results carry
        ORIGINAL vector ids.

        The backend is the protocol's ``index.search(queries, k, params)``
        — PageANN, DiskANN, Starling, or a ``MutableIndex`` alike. When the
        index speaks the ``MutableVectorIndex`` writes
        (insert/delete/compact), the engine exposes them as request types
        that interleave safely with in-flight searches. For a
        ``PageANNIndex``, passing a mesh (see ``launch.mesh``) dispatches
        ``shard_search`` with the query batch split across it.
        """
        def fn(queries: np.ndarray, k_bin: int, p: SearchParams | None):
            if mesh is not None:
                return index.search(queries, k=k_bin, params=p, mesh=mesh)
            return index.search(queries, k=k_bin, params=p)

        return cls(
            fn,
            dim=index.dim,
            batch_size=batch_size,
            timeout_ms=timeout_ms,
            default_k=k,
            default_params=params,
            k_bins=k_bins,
            insert_fn=getattr(index, "insert", None),
            delete_fn=getattr(index, "delete", None),
            compact_fn=getattr(index, "compact", None),
            **kwargs,
        )
