"""Semantic query cache: (query embedding, result) pairs keyed by cosine
similarity.

RAG front-ends send near-duplicate queries — the same question rephrased,
re-embedded with jitter, retried. An exact-match cache misses all of
them; a *semantic* cache returns the stored result whenever a new query
embedding is within a cosine-similarity threshold of a cached one. It
sits in FRONT of :class:`repro.serve.service.VectorService.submit`: a hit
skips the batching engine entirely (no queueing, no device dispatch), a
miss falls through and the completed result is inserted on the way out.

Entries are scoped per (collection, k, params, filter) — a hit must be an
answer to the *same question*, not just a nearby embedding — and the
whole collection scope is invalidated on any write (insert / delete /
compact / drop): a cached result may reference deleted ids or miss fresh
inserts, so correctness beats hit rate.

Lookup is a brute-force dot product over the scope's stored (normalized)
embeddings — numpy on host, O(entries x dim). At cache-sized entry counts
(thousands) this is microseconds, far below one engine batch; the point
of the cache is to skip the *index* scan, not to be an index itself.

Eviction: global LRU capacity bound plus optional per-entry TTL. All
methods are thread-safe (one lock; the engine submits from many
threads). Zero-norm query embeddings bypass the cache (cosine similarity
is undefined for them).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Hashable, NamedTuple

import numpy as np


class CacheStats(NamedTuple):
    """Counters since construction (monotonic; reads are lock-consistent)."""

    hits: int
    misses: int
    evictions: int
    invalidations: int
    entries: int


class _Entry(NamedTuple):
    vec: np.ndarray       # (d,) f32, unit-normalized
    result: Any
    expires: float        # monotonic deadline, +inf when no TTL


class SemanticCache:
    """Similarity-keyed result cache.

    ``threshold``: minimum cosine similarity for a hit (1.0 = exact
    match only). ``capacity``: global LRU bound on entries across all
    scopes. ``ttl``: seconds an entry stays valid (None = forever).
    """

    def __init__(
        self,
        threshold: float = 0.98,
        capacity: int = 4096,
        ttl: float | None = None,
        *,
        clock=time.monotonic,
    ):
        if not -1.0 <= threshold <= 1.0:
            raise ValueError(
                f"threshold must be a cosine in [-1, 1], got {threshold}"
            )
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if ttl is not None and ttl <= 0:
            raise ValueError(f"ttl must be positive, got {ttl}")
        self.threshold = float(threshold)
        self.capacity = int(capacity)
        self.ttl = ttl
        self._clock = clock
        self._lock = threading.Lock()
        # insertion/recency order across ALL scopes: key -> (scope, entry)
        self._lru: OrderedDict[tuple, tuple[Hashable, _Entry]] = OrderedDict()
        # scope -> {key: entry} for O(scope) lookup and O(1) invalidation
        self._scopes: dict[Hashable, dict[tuple, _Entry]] = {}
        self._seq = 0
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0
        # optional span tracer (duck-typed, see repro.obs.trace.Tracer),
        # attached by VectorService; lookups emit "semantic_lookup" spans
        # stamped with the tracer's own clock
        self.tracer = None

    @staticmethod
    def _normalize(query: np.ndarray) -> np.ndarray | None:
        v = np.asarray(query, np.float32).reshape(-1)
        n = float(np.linalg.norm(v))
        if n == 0.0 or not np.isfinite(n):
            return None
        return v / n

    def get(self, scope: Hashable, query: np.ndarray):
        """Best cached result within ``threshold`` of ``query`` under
        ``scope``, or None. A hit refreshes the entry's LRU recency."""
        tr = self.tracer
        if tr is not None and tr.enabled:
            t0 = tr.now()
            out = self._get(scope, query)
            tr.add("semantic_lookup", t0, tr.now(), cat="cache",
                   track="semantic-cache", args={"hit": out is not None})
            return out
        return self._get(scope, query)

    def _get(self, scope: Hashable, query: np.ndarray):
        v = self._normalize(query)
        with self._lock:
            if v is None or not self._scopes.get(scope):
                self._misses += 1
                return None
            now = self._clock()
            entries = self._scopes[scope]
            expired = [k for k, e in entries.items() if e.expires < now]
            for k in expired:
                del entries[k]
                del self._lru[k]
                self._evictions += 1
            if not entries:
                self._misses += 1
                return None
            keys = list(entries)
            mat = np.stack([entries[k].vec for k in keys])
            sims = mat @ v
            best = int(np.argmax(sims))
            if float(sims[best]) < self.threshold:
                self._misses += 1
                return None
            self._hits += 1
            self._lru.move_to_end(keys[best])
            return entries[keys[best]].result

    def put(self, scope: Hashable, query: np.ndarray, result: Any) -> None:
        """Insert a completed result; evicts the global LRU tail when the
        capacity bound is hit."""
        v = self._normalize(query)
        if v is None:
            return
        with self._lock:
            self._seq += 1
            key = (scope, self._seq)
            deadline = (
                self._clock() + self.ttl if self.ttl is not None
                else float("inf")
            )
            entry = _Entry(vec=v, result=result, expires=deadline)
            self._lru[key] = (scope, entry)
            self._scopes.setdefault(scope, {})[key] = entry
            while len(self._lru) > self.capacity:
                old_key, (old_scope, _) = self._lru.popitem(last=False)
                bucket = self._scopes.get(old_scope)
                if bucket is not None:
                    bucket.pop(old_key, None)
                    if not bucket:
                        del self._scopes[old_scope]
                self._evictions += 1

    def invalidate(self, match=None) -> int:
        """Drop entries whose scope satisfies ``match`` (a predicate over
        scopes; None drops everything). Returns how many entries went.
        Writers call this with a per-collection predicate: any insert /
        delete / compact makes that collection's cached results stale."""
        with self._lock:
            if match is None:
                n = len(self._lru)
                self._lru.clear()
                self._scopes.clear()
            else:
                doomed = [s for s in self._scopes if match(s)]
                n = 0
                for s in doomed:
                    for key in self._scopes[s]:
                        del self._lru[key]
                        n += 1
                    del self._scopes[s]
            self._invalidations += n
            return n

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(
                hits=self._hits,
                misses=self._misses,
                evictions=self._evictions,
                invalidations=self._invalidations,
                entries=len(self._lru),
            )

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)
