from repro.serve.compile_cache import CompileCache, CompileCacheStats
from repro.serve.engine import (
    DEFAULT_COLLECTION,
    BatchingEngine,
    EngineMetrics,
    RequestResult,
)
from repro.serve.http import HttpFrontend, TokenBucket
from repro.serve.semantic_cache import CacheStats, SemanticCache
from repro.serve.service import CollectionHandle, VectorService

__all__ = [
    "BatchingEngine",
    "CacheStats",
    "CollectionHandle",
    "CompileCache",
    "CompileCacheStats",
    "DEFAULT_COLLECTION",
    "EngineMetrics",
    "HttpFrontend",
    "RequestResult",
    "SemanticCache",
    "TokenBucket",
    "VectorService",
]
