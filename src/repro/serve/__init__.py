from repro.serve.engine import BatchingEngine, EngineMetrics, RequestResult

__all__ = ["BatchingEngine", "EngineMetrics", "RequestResult"]
