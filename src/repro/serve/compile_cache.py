"""Shared compile-cache registry: one warm executable per search *geometry*.

The jitted search hot path (``core.search.batch_search``) compiles one
executable per (static knobs, array shapes) signature.  When one process
serves many collections, what determines that signature is not the
collection — it is the collection's **geometry**: vector dim, page
capacity, memory mode, and the shapes of the device arrays the search
touches.  Two collections built with the same config over same-sized
corpora share every one of those, so their dispatch groups hit the *same*
compiled executable in jax's jit cache; a third collection with a
different page count or dim compiles its own.

This module makes that sharing observable and accountable at the serving
layer.  A :class:`CompileCache` maps

    geometry ⊕ (batch_size, resolved SearchParams)   →   seen-before?

where ``geometry`` is derived from the index artifact by
:func:`geometry_of`.  The batching engine consults the cache on every
group dispatch: the first dispatch of a key is a **miss** (jax traces and
compiles underneath), every later dispatch — from *any* collection with
the same geometry — is a **hit**.  Hit/miss/unique-executable counters
ride :class:`repro.serve.engine.EngineMetrics`, so "attaching collection
B compiled 0 new executables" is a measurable claim, not folklore.

Geometry extraction is conservative: an index whose compiled shapes this
module cannot prove stable (e.g. a mutable index, whose delta-scan shapes
grow with the fill level) gets a per-object key, so the cache never
reports sharing that the jit cache does not actually deliver.
"""
from __future__ import annotations

import itertools
import threading
import weakref
from typing import Any, NamedTuple

import jax


class CompileCacheStats(NamedTuple):
    hits: int      # dispatches whose executable was already warm
    misses: int    # dispatches that compiled a new executable
    unique: int    # distinct executables this cache has seen compiled


# --- per-object identity tokens for unshareable geometries -----------------
# id() alone is not a safe cache-key component: a process-scoped cache
# outlives services, and CPython recycles addresses after GC — a brand-new
# backend allocated where a dead one lived would register as already warm.
# Tokens are monotonic and retired (never reused) when the object dies.
_token_lock = threading.Lock()
_tokens: dict[int, int] = {}             # id(obj) -> token, while obj lives
_token_refs: dict[int, weakref.ref] = {}
_token_counter = itertools.count()


def unshared_token(obj: Any) -> int:
    """A stable token for ``obj``, distinct from every other object's —
    including past objects that happened to share its address."""
    with _token_lock:
        oid = id(obj)
        tok = _tokens.get(oid)
        if tok is None:
            tok = next(_token_counter)

            def _cleanup(_ref, oid=oid):
                with _token_lock:
                    _tokens.pop(oid, None)
                    _token_refs.pop(oid, None)

            try:
                _token_refs[oid] = weakref.ref(obj, _cleanup)
            except TypeError:
                # not weakref-able: the entry is pinned for the process
                # lifetime, which keeps the token stable (never recycled)
                pass
            _tokens[oid] = tok
        return tok


def geometry_of(index: Any) -> tuple:
    """Everything about ``index`` that shapes its compiled search
    executable, as a hashable key.

    For a :class:`repro.core.index.PageANNIndex` this is the artifact
    geometry — (dim, capacity, memory mode) plus the shape/dtype signature
    of every array in its :class:`SearchData` pytree — exactly the traced
    part of ``batch_search``'s jit signature, so equal keys really do mean
    a shared executable.  Anything else (baselines, mutable indexes whose
    delta shapes drift between calls) is keyed by object identity:
    correct, never falsely shared.
    """
    data = getattr(index, "data", None)
    cfg = getattr(index, "cfg", None)
    store = getattr(index, "store", None)
    if data is not None and cfg is not None and store is not None:
        sig = tuple(
            (tuple(leaf.shape), str(leaf.dtype))
            for leaf in jax.tree.leaves(data)
        )
        key = (
            "pageann",
            cfg.dim,
            store.capacity,
            cfg.memory_mode.value,
            sig,
        )
        fetcher = getattr(index, "fetcher", None)
        if fetcher is not None:
            # a streamed index's executable closes over its host fetcher
            # (core.search._stream_search_fn is lru-cached per fetcher), so
            # two streamed indexes never share one — the residency identity
            # joins the key
            key = key + (("stream", unshared_token(fetcher)),)
        return key
    return ("unshared", unshared_token(index))


class CompileCache:
    """Thread-safe registry of compiled-search signatures with counters.

    ``note(key)`` records one dispatch under ``key`` and returns whether
    the executable was already warm.  One cache is typically shared by
    every collection behind one engine/service, which is what lets a
    second same-geometry collection register as all-hits.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._seen: dict[tuple, int] = {}
        self._hits = 0
        self._misses = 0

    def note(self, key: tuple) -> bool:
        """Record a dispatch of ``key``; True if it was already compiled."""
        with self._lock:
            warm = key in self._seen
            self._seen[key] = self._seen.get(key, 0) + 1
            if warm:
                self._hits += 1
            else:
                self._misses += 1
            return warm

    def contains(self, key: tuple) -> bool:
        with self._lock:
            return key in self._seen

    def stats(self) -> CompileCacheStats:
        with self._lock:
            return CompileCacheStats(
                hits=self._hits, misses=self._misses, unique=len(self._seen)
            )

    def clear(self) -> None:
        with self._lock:
            self._seen.clear()
            self._hits = 0
            self._misses = 0
