"""Network frontend: the service fleet's stdlib HTTP surface.

:class:`HttpFrontend` puts a ``ThreadingHTTPServer`` on a daemon thread
in front of a :class:`~repro.serve.service.VectorService`:

  * ``POST /search``       — one query or a batch against a collection;
  * ``POST /insert``       — write vectors into a mutable collection;
  * ``POST /delete``       — remove ids from a mutable collection;
  * ``GET  /collections``  — the registry: names, dims, default k;
  * ``GET  /metrics`` / ``/healthz`` / ``/stats`` — the PR-9 obs surface,
    mounted on the SAME port so one scrape target covers API and engine.

Admission control happens before any engine work:

  * **bounded in-flight queue** — at most ``max_inflight`` requests may
    hold engine work concurrently; excess requests are shed immediately
    with **503** (no queueing behind a stampede);
  * **per-collection token buckets** — sustained rate + burst per
    collection; an empty bucket sheds with **429** and ``Retry-After``;
  * **per-request deadlines** — ``deadline_ms`` (or the server default)
    rides through ``BatchingEngine.submit``; a request whose deadline
    passes while queued completes with **504** and counts as an engine
    ``shed``.

Rejections are cheap by design: a 429/503 touches no lock shared with
dispatch. Every decision is visible in the exposition —
``pageann_http_requests_total{route=,code=}`` and
``pageann_http_rejected_total{reason=}`` ride the same registry as the
engine series. No third-party dependencies.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.obs.server import PROMETHEUS_CONTENT_TYPE, _jsonable

MAX_BODY_BYTES = 64 * 1024 * 1024


class TokenBucket:
    """Sustained ``rate``/s with ``burst`` capacity; thread-safe."""

    def __init__(self, rate: float, burst: float,
                 clock=time.monotonic):
        if not rate > 0 or not burst > 0:
            raise ValueError("rate and burst must be > 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t = clock()
        self._lock = threading.Lock()

    def try_acquire(self, n: float = 1.0) -> bool:
        with self._lock:
            now = self._clock()
            self._tokens = min(
                self.burst, self._tokens + (now - self._t) * self.rate
            )
            self._t = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False

    def retry_after_s(self, n: float = 1.0) -> float:
        """Seconds until ``n`` tokens will have accrued (>= 0)."""
        with self._lock:
            return max(0.0, (n - self._tokens) / self.rate)


class _RequestError(Exception):
    def __init__(self, code: int, message: str, *, reason: str | None = None,
                 retry_after_s: float | None = None):
        super().__init__(message)
        self.code = code
        self.message = message
        self.reason = reason          # rejected-counter label, None = no shed
        self.retry_after_s = retry_after_s


class HttpFrontend:
    """Serve ``service`` over HTTP with admission control + QoS.

    ``rate_limits`` maps collection name -> ``(rate_per_s, burst)``; a
    collection without an entry is not rate limited.  ``registry`` is an
    ``obs.MetricsRegistry`` already carrying the engine series (e.g. from
    ``serve_registry(service)``); the frontend adds its own http series
    to it, so ``/metrics`` exposes both.  Bind ``port=0`` for an
    ephemeral port (``.port``/``.url`` report it).
    """

    def __init__(
        self,
        service,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        max_inflight: int = 64,
        default_deadline_ms: float | None = None,
        rate_limits: dict | None = None,
        registry=None,
        clock=time.monotonic,
    ):
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self._service = service
        self._default_deadline_ms = default_deadline_ms
        self._inflight = threading.Semaphore(max_inflight)
        self._buckets = {
            name: TokenBucket(rate, burst, clock)
            for name, (rate, burst) in (rate_limits or {}).items()
        }
        if registry is None:
            from repro.obs import serve_registry

            registry = serve_registry(service)
        self._registry = registry
        self._requests_total = registry.counter(
            "pageann_http_requests_total",
            "HTTP requests by route and status code",
        )
        self._rejected_total = registry.counter(
            "pageann_http_rejected_total",
            "HTTP requests shed by admission control, by reason",
        )

        frontend = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # quiet by default
                pass

            def _reply(self, code: int, body: bytes, ctype: str,
                       headers: dict | None = None) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                for k, v in (headers or {}).items():
                    self.send_header(k, v)
                self.end_headers()
                self.wfile.write(body)

            def _reply_json(self, code: int, doc: dict,
                            headers: dict | None = None) -> None:
                self._reply(code, json.dumps(doc).encode(),
                            "application/json", headers)

            def _route(self) -> str:
                return self.path.split("?", 1)[0]

            def _body(self) -> dict:
                length = int(self.headers.get("Content-Length") or 0)
                if length > MAX_BODY_BYTES:
                    raise _RequestError(413, "request body too large")
                raw = self.rfile.read(length) if length else b"{}"
                try:
                    doc = json.loads(raw or b"{}")
                except json.JSONDecodeError as e:
                    raise _RequestError(400, f"invalid JSON body: {e}")
                if not isinstance(doc, dict):
                    raise _RequestError(400, "body must be a JSON object")
                return doc

            def _dispatch(self, fn) -> None:
                route = self._route()
                try:
                    code, doc, headers = fn(route)
                except _RequestError as e:
                    if e.reason is not None:
                        frontend._rejected_total.inc(
                            labels={"reason": e.reason}
                        )
                    headers = {}
                    if e.retry_after_s is not None:
                        headers["Retry-After"] = (
                            f"{max(1, int(np.ceil(e.retry_after_s)))}"
                        )
                    code, doc = e.code, {"error": e.message}
                except Exception as e:  # noqa: BLE001 — surface, don't die
                    code, doc, headers = 500, {"error": repr(e)}, {}
                frontend._requests_total.inc(
                    labels={"route": route, "code": str(code)}
                )
                self._reply_json(code, doc, headers)

            def do_GET(self):
                route = self._route()
                try:
                    if route == "/metrics":
                        body = frontend._registry.render().encode()
                        self._reply(200, body, PROMETHEUS_CONTENT_TYPE)
                        return
                    if route == "/healthz":
                        frontend._service.metrics()
                        self._reply(200, b"ok\n", "text/plain")
                        return
                    if route == "/stats":
                        payload = {
                            "metrics": _jsonable(frontend._service.metrics()),
                            "collections": _jsonable(
                                frontend._service.stats()
                            ),
                        }
                        self._reply_json(200, payload)
                        return
                except Exception as exc:  # noqa: BLE001
                    self._reply(503, f"unhealthy: {exc}\n".encode(),
                                "text/plain")
                    return
                if route == "/collections":
                    self._dispatch(frontend._handle_collections)
                else:
                    self._reply_json(404, {"error": f"no route {route}"})

            def do_POST(self):
                route = self._route()
                handlers = {
                    "/search": frontend._handle_search,
                    "/insert": frontend._handle_insert,
                    "/delete": frontend._handle_delete,
                }
                fn = handlers.get(route)
                if fn is None:
                    self._reply_json(404, {"error": f"no route {route}"})
                    return
                self._dispatch(lambda _route: fn(self._body()))

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="pageann-http-frontend",
            daemon=True,
        )
        self._thread.start()

    # -------------------------------------------------------- admission
    def _admit(self, collection: str):
        """503 when the in-flight cap is hit, 429 when the collection's
        token bucket is dry. Returns a release callable on success."""
        if not self._inflight.acquire(blocking=False):
            raise _RequestError(
                503, "overloaded: in-flight request cap reached",
                reason="inflight", retry_after_s=0.05,
            )
        bucket = self._buckets.get(collection)
        if bucket is not None and not bucket.try_acquire():
            self._inflight.release()
            raise _RequestError(
                429, f"rate limit exceeded for collection {collection!r}",
                reason="ratelimit",
                retry_after_s=bucket.retry_after_s(),
            )
        return self._inflight.release

    @staticmethod
    def _collection_of(doc: dict) -> str:
        name = doc.get("collection")
        if not isinstance(name, str) or not name:
            raise _RequestError(400, "missing 'collection'")
        return name

    # --------------------------------------------------------- handlers
    def _handle_collections(self, _route: str):
        svc = self._service
        out = []
        for name in sorted(svc.list_collections()):
            try:
                idx = svc.index_of(name)
                out.append({"name": name, "dim": int(idx.dim)})
            except KeyError:
                continue  # dropped between list and lookup
        return 200, {"collections": out}, {}

    def _handle_search(self, doc: dict):
        name = self._collection_of(doc)
        if "queries" in doc:
            queries = doc["queries"]
            single = False
        elif "query" in doc:
            queries = [doc["query"]]
            single = True
        else:
            raise _RequestError(400, "missing 'query' or 'queries'")
        try:
            q = np.asarray(queries, np.float32)
        except (TypeError, ValueError) as e:
            raise _RequestError(400, f"bad query payload: {e}")
        if q.ndim != 2 or q.shape[0] == 0:
            raise _RequestError(
                400, f"queries must be a non-empty (Q, d) matrix, "
                     f"got shape {q.shape}"
            )
        k = doc.get("k")
        deadline_ms = doc.get("deadline_ms", self._default_deadline_ms)
        release = self._admit(name)
        try:
            t0 = time.perf_counter()
            try:
                futs = [
                    self._service.submit(
                        name, row, k=k, deadline_ms=deadline_ms
                    )
                    for row in q
                ]
                self._service.flush(name)
            except KeyError:
                raise _RequestError(404, f"no collection {name!r}")
            except ValueError as e:
                raise _RequestError(400, str(e))
            results = []
            shed = 0
            for fut in futs:
                try:
                    rr = fut.result()
                except TimeoutError:
                    shed += 1
                    results.append(None)
                    continue
                res = rr.result
                ids = np.asarray(res.ids)
                dists = np.asarray(res.dists)
                results.append({
                    "ids": ids.reshape(-1).tolist(),
                    "dists": dists.reshape(-1).tolist(),
                    "cached": bool(rr.cached),
                })
            if shed == len(futs):
                # the whole request expired in queue: one 504, engine
                # sheds already counted per request
                raise _RequestError(
                    504, "deadline passed while queued", reason="deadline",
                )
            wall_ms = (time.perf_counter() - t0) * 1e3
            doc_out = {
                "results": results if not single else results[0],
                "shed": shed,
                "wall_ms": wall_ms,
            }
            return 200, doc_out, {}
        finally:
            release()

    def _handle_insert(self, doc: dict):
        name = self._collection_of(doc)
        vectors = doc.get("vectors")
        if vectors is None:
            raise _RequestError(400, "missing 'vectors'")
        try:
            v = np.asarray(vectors, np.float32)
        except (TypeError, ValueError) as e:
            raise _RequestError(400, f"bad vectors payload: {e}")
        release = self._admit(name)
        try:
            try:
                ids = self._service.insert(
                    name, v, doc.get("ids"), metadata=doc.get("metadata")
                )
            except KeyError:
                raise _RequestError(404, f"no collection {name!r}")
            except (RuntimeError, ValueError) as e:
                raise _RequestError(400, str(e))
            return 200, {"ids": np.asarray(ids).tolist()}, {}
        finally:
            release()

    def _handle_delete(self, doc: dict):
        name = self._collection_of(doc)
        ids = doc.get("ids")
        if ids is None:
            raise _RequestError(400, "missing 'ids'")
        release = self._admit(name)
        try:
            try:
                removed = self._service.delete(name, np.asarray(ids))
            except KeyError:
                raise _RequestError(404, f"no collection {name!r}")
            except (RuntimeError, ValueError) as e:
                raise _RequestError(400, str(e))
            return 200, {"removed": int(removed)}, {}
        finally:
            release()

    # ---------------------------------------------------------- plumbing
    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False
