"""Database-level serving API: many named collections, one process.

The paper frames PageANN as the engine of a vector database; this module
is the database surface. A :class:`VectorService` owns

  * a **collection registry** — named :class:`repro.core.protocol.
    VectorIndex` artifacts (built in-process, or attached from disk), each
    registered on
  * one shared :class:`repro.serve.engine.BatchingEngine` core — a single
    batching/timer/demux loop whose pending groups are keyed by
    ``(collection, k-bin, params)``, so every collection gets fixed-shape
    dispatches without its own process, its own metrics machinery, or its
    own timer thread, and
  * one shared :class:`repro.serve.compile_cache.CompileCache` — compiled
    search executables are keyed by *geometry* (dim, page capacity, memory
    mode, array shapes, batch, resolved params), not by collection, so
    attaching a second collection with the geometry of an already-warm one
    compiles **zero** new executables (observable in ``metrics()``), and
  * optionally a :class:`repro.serve.semantic_cache.SemanticCache` in
    front of ``submit``: a query embedding within a cosine threshold of a
    recently answered one (same collection/k/params/filter scope) returns
    the cached result as an already-completed future — no queueing, no
    dispatch. Writes to a collection invalidate its cached entries, so a
    hit is never stale; hit/miss/eviction/invalidation counters ride
    ``metrics()``.

Lifecycle::

    with VectorService(batch_size=64, timeout_ms=2.0) as svc:
        svc.create_collection("wiki", index)          # built VectorIndex
        svc.create_collection("notes", cfg, vectors)  # build from a config
        svc.attach("prod", "artifacts/prod_idx")      # load from disk
        fut = svc.submit("wiki", query, k=10)         # routed dispatch
        svc.insert("notes", fresh_vectors)            # writes, if mutable
        svc.save("db_dir")                            # whole database

    svc = VectorService.load("db_dir")                # round-trips

On disk a database is ``db.json`` (collection name -> subdirectory,
versioned like index manifests) over ordinary per-collection artifacts —
see ``repro.core.persist.save_database``.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future
from typing import Any, Iterator

import numpy as np

from repro.core import persist
from repro.core.config import PageANNConfig, SearchParams
from repro.serve.compile_cache import CompileCache
from repro.serve.engine import BatchingEngine, EngineMetrics, RequestResult
from repro.serve.semantic_cache import SemanticCache


class CollectionHandle:
    """A bound view of one named collection: the service's routing surface
    with the name pre-applied. Handles stay cheap and stateless — dropping
    the collection invalidates the handle (later calls raise KeyError)."""

    __slots__ = ("_service", "name")

    def __init__(self, service: "VectorService", name: str):
        self._service = service
        self.name = name

    @property
    def index(self):
        """The underlying ``VectorIndex`` (e.g. for ``stats`` / ``save``)."""
        return self._service.index_of(self.name)

    def submit(self, query, *, k=None, params=None, filter=None,
               deadline_ms=None):
        return self._service.submit(
            self.name, query, k=k, params=params, filter=filter,
            deadline_ms=deadline_ms,
        )

    def search(self, queries, *, k=None, params=None, filter=None):
        return self._service.search(
            self.name, queries, k=k, params=params, filter=filter
        )

    def insert(self, vectors, ids=None, *, metadata=None):
        return self._service.insert(
            self.name, vectors, ids, metadata=metadata
        )

    def delete(self, ids):
        return self._service.delete(self.name, ids)

    def compact(self):
        return self._service.compact(self.name)

    def __repr__(self) -> str:
        return f"CollectionHandle({self.name!r})"


class VectorService:
    """One serving process, many named vector collections (see module
    docstring). All engine knobs (``batch_size``, ``timeout_ms``,
    ``k_bins``, …) are shared across collections — they shape the batching
    core, not any one index."""

    def __init__(
        self,
        *,
        batch_size: int = 64,
        timeout_ms: float | None = None,
        k_bins: tuple[int, ...] | None = None,
        compile_cache: CompileCache | None = None,
        semantic_cache: SemanticCache | None = None,
        tracer=None,
        **engine_kwargs: Any,
    ):
        self._compile_cache = compile_cache or CompileCache()
        # the tracer (duck-typed, see repro.obs.trace.Tracer) is threaded
        # down into the engine (request/dispatch spans), the semantic
        # cache (lookup spans), and — via add_collection — any streamed
        # collection's PageFetcher (host-fetch spans)
        self._tracer = tracer
        self._engine = BatchingEngine(
            batch_size=batch_size,
            timeout_ms=timeout_ms,
            k_bins=k_bins,
            compile_cache=self._compile_cache,
            tracer=tracer,
            **engine_kwargs,
        )
        self._semantic_cache = semantic_cache
        if semantic_cache is not None and tracer is not None:
            semantic_cache.tracer = tracer
        self._lock = threading.Lock()
        self._indexes: dict[str, Any] = {}
        # per-collection write generation: bumped by insert/delete/compact/
        # drop so in-flight cache misses never store a stale result
        self._write_gen: dict[str, int] = {}
        self._closed = False

    # ------------------------------------------------------- context manager
    def __enter__(self) -> "VectorService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Flush and shut down the shared engine. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._engine.close()

    # ------------------------------------------------- collection lifecycle
    def create_collection(
        self,
        name: str,
        index_or_cfg,
        vectors: np.ndarray | None = None,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        mesh=None,
        priority: float = 1.0,
        **build_kwargs: Any,
    ) -> CollectionHandle:
        """Register a new collection under ``name``.

        ``index_or_cfg`` is either an already built/loaded ``VectorIndex``,
        or a :class:`PageANNConfig` — then ``vectors`` supplies the corpus
        and the index is built here (``build_kwargs`` forwarded to
        ``PageANNIndex.build``). ``k``/``params`` set the collection's
        serving defaults; ``mesh`` routes its dispatches through
        ``shard_search``; ``priority`` weights this collection's dispatch
        order on the shared core (see ``BatchingEngine.add_collection``).
        """
        persist.check_collection_name(name)
        if isinstance(index_or_cfg, PageANNConfig):
            if vectors is None:
                raise ValueError(
                    "create_collection from a PageANNConfig needs vectors"
                )
            from repro.core.index import PageANNIndex

            index = PageANNIndex.build(
                np.asarray(vectors, np.float32), index_or_cfg, **build_kwargs
            )
        else:
            if vectors is not None:
                raise ValueError(
                    "vectors only apply when building from a PageANNConfig"
                )
            index = index_or_cfg
            if not (hasattr(index, "search") and hasattr(index, "dim")):
                raise TypeError(
                    f"{type(index).__name__} does not implement the "
                    "VectorIndex protocol (need search + dim)"
                )
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            if name in self._indexes:
                raise ValueError(f"collection {name!r} already exists")
            self._indexes[name] = index
        try:
            self._engine.add_collection(
                name, index=index, default_k=k, default_params=params,
                mesh=mesh, priority=priority,
            )
        except Exception:
            with self._lock:
                self._indexes.pop(name, None)
            raise
        return CollectionHandle(self, name)

    def attach(
        self,
        name: str,
        directory: str,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        mesh=None,
        memory_budget=None,
        recall_target: float | None = None,
        priority: float = 1.0,
    ) -> CollectionHandle:
        """Load a persisted index artifact (any manifest kind) from
        ``directory`` and register it as collection ``name``.

        ``memory_budget`` (``MemoryBudget`` | bytes | fraction | spec
        string | None) caps the collection's device-resident page region —
        pages beyond it stream from the artifact's memmap per hop with
        bit-identical results (see ``PageANNIndex.load``).

        ``recall_target`` resolves the collection's serving defaults from
        the artifact's autotuned operating points (the manifest ``tuned``
        section written by ``PageANNIndex.autotune``): the highest-QPS
        stored point whose measured recall meets the target. Strict — an
        artifact with no qualifying point (or no tuned section at all)
        raises ``LookupError`` rather than silently serving hand-picked
        params. Mutually exclusive with an explicit ``params``."""
        persist.check_collection_name(name)
        index = persist.load_index(directory, memory_budget=memory_budget)
        if recall_target is not None:
            if params is not None:
                raise ValueError(
                    "pass either params= or recall_target=, not both"
                )
            params = index.params_for_target(recall_target=recall_target)
        return self.create_collection(
            name, index, k=k, params=params, mesh=mesh, priority=priority,
        )

    def drop(self, name: str) -> None:
        """Unregister ``name``: its pending requests are dispatched first,
        then later routing to it raises ``KeyError``. The index object (and
        anything it has persisted on disk) is left untouched."""
        with self._lock:
            if name not in self._indexes:
                raise KeyError(f"no collection {name!r}")
        self._engine.remove_collection(name)
        with self._lock:
            self._indexes.pop(name, None)
        # a later collection reusing the name must not inherit cached
        # results computed against the dropped index
        self._invalidate(name)

    def list_collections(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._indexes))

    def collection(self, name: str) -> CollectionHandle:
        """A bound handle for ``name`` (KeyError if it does not exist)."""
        self.index_of(name)  # existence check
        return CollectionHandle(self, name)

    def index_of(self, name: str):
        with self._lock:
            try:
                return self._indexes[name]
            except KeyError:
                raise KeyError(
                    f"no collection {name!r}; have {sorted(self._indexes)}"
                ) from None

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._indexes

    def __len__(self) -> int:
        with self._lock:
            return len(self._indexes)

    def __iter__(self) -> Iterator[str]:
        return iter(self.list_collections())

    # -------------------------------------------------------------- routing
    def submit(
        self,
        collection: str,
        query: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        filter=None,
        deadline_ms: float | None = None,
    ):
        """Enqueue one query for ``collection``; returns a
        Future[RequestResult]. Requests sharing a (collection, k-bin,
        params, filter) group share one fixed-shape dispatch on the common
        core. ``deadline_ms`` bounds queue time (see
        ``BatchingEngine.submit``); a semantic-cache hit resolves
        immediately and never expires.

        With a :class:`SemanticCache` installed, a query embedding within
        the cache's cosine threshold of an already-answered one (under the
        SAME (collection, k, params, filter) scope) resolves immediately
        from the cache — the returned future is already completed and its
        ``RequestResult.cached`` is True. Misses fall through to the
        engine and populate the cache on completion, unless the collection
        was written to while the request was in flight (the result would
        already be stale)."""
        cache = self._semantic_cache
        if cache is None:
            return self._engine.submit(query, k=k, params=params,
                                       collection=collection, filter=filter,
                                       deadline_ms=deadline_ms)
        scope = (collection, k, params, filter)
        q = np.asarray(query, np.float32).reshape(-1)
        hit = cache.get(scope, q)
        if hit is not None:
            fut: Future = Future()
            fut.set_result(
                RequestResult(
                    result=hit, latency_ms=0.0, batch_size=0,
                    batch_index=-1, cached=True,
                )
            )
            return fut
        with self._lock:
            gen = self._write_gen.get(collection, 0)
        fut = self._engine.submit(query, k=k, params=params,
                                  collection=collection, filter=filter,
                                  deadline_ms=deadline_ms)

        def _store(done, _q=q, _scope=scope, _gen=gen):
            if done.cancelled() or done.exception() is not None:
                return
            with self._lock:
                stale = self._write_gen.get(collection, 0) != _gen
            if not stale:
                cache.put(_scope, _q, done.result().result)

        fut.add_done_callback(_store)
        return fut

    def search(
        self,
        collection: str,
        queries: np.ndarray,
        *,
        k: int | None = None,
        params: SearchParams | None = None,
        filter=None,
    ) -> list[RequestResult]:
        """Synchronous convenience: submit a (Q, d) batch, flush, gather.
        Routed through :meth:`submit` so the semantic cache applies."""
        futs = [
            self.submit(collection, q, k=k, params=params, filter=filter)
            for q in np.asarray(queries)
        ]
        self._engine.flush(collection=collection)
        return [f.result() for f in futs]

    def flush(self, collection: str | None = None) -> None:
        self._engine.flush(collection=collection)

    # --------------------------------------------------------------- writes
    def _invalidate(self, collection: str) -> None:
        """A write landed on ``collection``: bump its generation (in-flight
        misses stop populating the cache) and drop its cached entries."""
        with self._lock:
            self._write_gen[collection] = (
                self._write_gen.get(collection, 0) + 1
            )
        if self._semantic_cache is not None:
            self._semantic_cache.invalidate(
                lambda scope: scope[0] == collection
            )

    def insert(
        self, collection: str, vectors, ids=None, *, metadata=None
    ) -> np.ndarray:
        out = self._engine.insert(
            vectors, ids, collection=collection, metadata=metadata
        )
        self._invalidate(collection)
        return out

    def delete(self, collection: str, ids) -> int:
        removed = self._engine.delete(ids, collection=collection)
        self._invalidate(collection)
        return removed

    def compact(self, collection: str) -> bool:
        # compaction does not change the live set, but it swaps the base
        # artifact the cached results were computed against — invalidate
        # rather than reason about bit-identity across a rebuild
        did = self._engine.compact(collection=collection)
        if did:
            self._invalidate(collection)
        return did

    # -------------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        """Aggregate serving metrics of the shared core, including the
        compile-cache hit/miss/unique-executable counters and — when a
        semantic cache is installed — its hit/miss/eviction/invalidation
        counters."""
        m = self._engine.metrics()
        if self._semantic_cache is not None:
            cs = self._semantic_cache.stats()
            m = m._replace(
                semantic_hits=cs.hits,
                semantic_misses=cs.misses,
                semantic_evictions=cs.evictions,
                semantic_invalidations=cs.invalidations,
            )
        return m

    def metrics_windows(self) -> dict:
        """The engine's trailing metric windows (latency/hops/ios/fetch
        wall) in one atomic snapshot — the exposition layer's histogram
        feed (see ``BatchingEngine.metrics_windows``)."""
        return self._engine.metrics_windows()

    def stats(self) -> dict:
        """Per-collection index stats keyed by collection name, as plain
        dicts (dataclass stats flattened recursively — a mutable index
        nests its base's ``BuildStats`` under ``"base"``). Includes the
        residency split (``resident_pages``/``resident_bytes`` vs
        ``pages``/``disk_bytes``) for streamed collections — the
        ``/stats`` endpoint's payload."""
        with self._lock:
            snapshot = dict(self._indexes)
        out: dict[str, dict] = {}
        for name, idx in snapshot.items():
            st = getattr(idx, "stats", None)
            if dataclasses.is_dataclass(st) and not isinstance(st, type):
                st = dataclasses.asdict(st)
            elif hasattr(st, "_asdict"):
                st = st._asdict()
            out[name] = st if isinstance(st, dict) else {}
        return out

    # ------------------------------------------------------------ lifecycle
    def save(self, directory: str) -> None:
        """Persist every collection under ``directory`` as one database
        (``db.json`` + per-collection artifacts); round-trips through
        :meth:`load`."""
        with self._lock:
            snapshot = dict(self._indexes)
        persist.save_database(snapshot, directory)

    @classmethod
    def load(
        cls,
        directory: str,
        *,
        memory_budget=None,
        recall_target: float | None = None,
        **service_kwargs: Any,
    ) -> "VectorService":
        """Reopen a saved database as a ready-to-serve service: every
        collection in ``db.json`` is loaded (whatever index kind it
        persisted as) and registered on a fresh shared core.
        ``memory_budget`` caps each collection's device-resident page
        region independently (see :meth:`attach`).

        ``recall_target`` resolves each collection's serving defaults from
        its autotuned operating points where possible. Lenient per
        collection — a database mixes index kinds and tuning states, so a
        collection with no qualifying tuned point keeps its own defaults
        instead of failing the whole load (use :meth:`attach` for the
        strict single-artifact behavior)."""
        svc = cls(**service_kwargs)
        try:
            loaded = persist.load_database(
                directory, memory_budget=memory_budget
            )
            for name, index in loaded.items():
                params = None
                if recall_target is not None:
                    try:
                        params = index.params_for_target(
                            recall_target=recall_target
                        )
                    except (LookupError, AttributeError):
                        params = None
                svc.create_collection(name, index, params=params)
        except Exception:
            svc.close()
            raise
        return svc
