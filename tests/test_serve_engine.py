"""Serving engine + refactored search-loop contracts: batching/demux order,
ragged-batch padding, shard_search parity, ops-dispatch routing, and the
_mask_dups_keep_first dedup invariant."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MemoryMode, PageANNConfig, PageANNIndex, SearchParams
from repro.core import search as search_mod
from repro.core.search import SearchResult, _mask_dups_keep_first
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.launch.mesh import make_host_mesh
from repro.serve import BatchingEngine

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N, D = 800, 32


@pytest.fixture(scope="module")
def index():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    cfg = PageANNConfig(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    return PageANNIndex.build(x, cfg)


def _toy_search_fn(seen_shapes, seen_knobs=None):
    """Deterministic per-row backend: row i's ids encode round(q[i, 0])."""

    def fn(q, k, params):
        seen_shapes.append(np.asarray(q).shape)
        if seen_knobs is not None:
            seen_knobs.append((k, params))
        b = q.shape[0]
        tag = jnp.round(q[:, :1]).astype(jnp.int32)
        return SearchResult(
            ids=tag + jnp.arange(k)[None],
            dists=q.sum(1)[:, None] + jnp.arange(k)[None].astype(jnp.float32),
            ios=jnp.full((b,), 2, jnp.int32),
            hops=jnp.ones((b,), jnp.int32),
            cache_hits=jnp.zeros((b,), jnp.int32),
        )

    return fn


# ------------------------------------------------------------------ engine
def test_batching_and_demux_order():
    shapes = []
    eng = BatchingEngine(_toy_search_fn(shapes), dim=4, batch_size=4)
    futs = [eng.submit(np.full(4, i, np.float32)) for i in range(11)]
    eng.flush()
    rows = [f.result(timeout=30) for f in futs]
    # demux preserves submission order: request i gets the row tagged i
    for i, r in enumerate(rows):
        assert r.result.ids[0] == i
        np.testing.assert_allclose(r.result.dists[0], 4.0 * i)
        assert r.latency_ms >= 0.0
    # full batches dispatch eagerly at batch_size, the ragged tail on flush
    assert [r.batch_index for r in rows] == [0] * 4 + [1] * 4 + [2] * 3
    assert [r.batch_size for r in rows] == [4] * 8 + [3] * 3
    m = eng.metrics()
    assert m.requests == 11 and m.batches == 3
    assert m.mean_ios == 2.0


def test_ragged_batch_is_padded_to_fixed_shape():
    shapes = []
    eng = BatchingEngine(_toy_search_fn(shapes), dim=6, batch_size=8)
    futs = [eng.submit(np.full(6, 1.0 + i, np.float32)) for i in range(3)]
    eng.flush()
    rows = [f.result(timeout=30) for f in futs]
    # the backend always sees the fixed (batch_size, dim) shape ...
    assert shapes == [(8, 6)]
    # ... and pad rows never leak into real requests' results
    for i, r in enumerate(rows):
        assert r.result.ids[0] == 1 + i
        assert r.batch_size == 3
    assert eng.metrics().padded_fraction == pytest.approx(5 / 8)


def test_timeout_flush_without_explicit_flush():
    eng = BatchingEngine(
        _toy_search_fn([]), dim=4, batch_size=64, timeout_ms=30.0
    )
    fut = eng.submit(np.zeros(4, np.float32))
    r = fut.result(timeout=30)
    assert r.batch_size == 1
    eng.close()


def test_backend_failure_reaches_every_future():
    def boom(q, k, params):
        raise RuntimeError("backend down")

    eng = BatchingEngine(boom, dim=4, batch_size=2)
    futs = [eng.submit(np.zeros(4, np.float32)) for _ in range(3)]
    eng.flush()  # ragged tail; submit/flush themselves never raise
    # every future must carry the error rather than hang its waiter
    for f in futs:
        with pytest.raises(RuntimeError, match="backend down"):
            f.result(timeout=5)


def test_engine_from_index_matches_direct_search(index):
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, 9, seed=3)
    want = index.search(q, k=5)
    eng = BatchingEngine.from_index(index, k=5, batch_size=4)
    futs = [eng.submit(row) for row in q]
    eng.flush()
    rows = [f.result(timeout=120) for f in futs]
    got_ids = np.stack([r.result.ids for r in rows])
    got_d = np.stack([r.result.dists for r in rows])
    np.testing.assert_array_equal(got_ids, want.ids)
    np.testing.assert_allclose(got_d, want.dists, rtol=1e-6)
    assert eng.metrics().requests == 9


# ------------------------------------------------- per-request k / params
def test_per_request_k_binning_and_param_groups():
    """Distinct (k-bin, params) requests form their own fixed-shape
    dispatches; k below a bin is rounded up and the result trimmed."""
    shapes, knobs = [], []
    eng = BatchingEngine(
        _toy_search_fn(shapes, knobs), dim=4, batch_size=4,
        default_k=5, k_bins=(5, 8),
    )
    wide = SearchParams(k=5, beam_width=128)
    futs = [eng.submit(np.full(4, i, np.float32)) for i in range(4)]
    f_small = eng.submit(np.full(4, 9.0, np.float32), k=3)   # binned up to 5
    f_eight = eng.submit(np.full(4, 7.0, np.float32), k=7)   # binned up to 8
    f_wide = eng.submit(np.full(4, 5.0, np.float32), params=wide)
    f_tall = eng.submit(np.full(4, 6.0, np.float32), k=12)   # above the grid
    eng.flush()

    for i, f in enumerate(futs):
        assert f.result(timeout=30).result.ids.shape == (5,)
        assert f.result(timeout=30).result.ids[0] == i
    assert f_small.result(timeout=30).result.ids.shape == (3,)   # trimmed
    np.testing.assert_array_equal(
        f_small.result(timeout=30).result.ids, 9 + np.arange(3)
    )
    assert f_eight.result(timeout=30).result.ids.shape == (7,)
    assert f_wide.result(timeout=30).result.ids.shape == (5,)
    assert f_tall.result(timeout=30).result.ids.shape == (12,)
    # the four default requests shared one dispatch; the other four knob
    # combinations each formed their own fixed-shape group
    ks = sorted(k for k, _ in knobs)
    assert ks == [5, 5, 5, 8, 12]
    assert sum(1 for _, p in knobs if p is wide) == 1
    assert eng.metrics().requests == 8


def test_timer_survives_other_groups_size_dispatch():
    """A size-triggered dispatch of one (k-bin, params) group must not
    strand another group's pending request: the timeout timer is re-armed
    while any group still holds waiters."""
    eng = BatchingEngine(
        _toy_search_fn([]), dim=4, batch_size=2, timeout_ms=30.0, default_k=3
    )
    slow = eng.submit(np.zeros(4, np.float32))          # default group, waits
    # fill a DIFFERENT group to its size trigger (cancels the live timer)
    for _ in range(2):
        eng.submit(np.ones(4, np.float32), k=8)
    r = slow.result(timeout=5)                          # timeout must fire
    assert r.batch_size == 1
    eng.close()


def test_sparse_group_not_starved_by_steady_traffic():
    """The timeout deadline tracks the OLDEST pending submit: steady
    size-triggered dispatches of another group must not keep pushing a
    sparse group's flush out to a fresh full timeout each time."""
    import threading
    import time as time_mod

    eng = BatchingEngine(
        _toy_search_fn([]), dim=4, batch_size=2, timeout_ms=100.0, default_k=3
    )
    resolved_at = []
    t0 = time_mod.perf_counter()
    slow = eng.submit(np.zeros(4, np.float32), k=5)      # sparse group
    slow.add_done_callback(
        lambda _: resolved_at.append(time_mod.perf_counter() - t0)
    )
    for _ in range(10):                                  # ~500ms of churn
        for _ in range(2):                               # size-dispatch
            eng.submit(np.ones(4, np.float32))
        time_mod.sleep(0.05)
    slow.result(timeout=5)
    eng.close()
    # with deadline-resetting timers this resolves only after the churn
    # stops (~0.6s); with the oldest-submit deadline it fires at ~0.1s
    assert resolved_at and resolved_at[0] < 0.35, resolved_at


def test_drained_groups_do_not_accumulate():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=1)
    for k in range(1, 30):
        eng.submit(np.zeros(4, np.float32), k=k).result(timeout=30)
    assert len(eng._pending) == 0
    eng.close()


def test_params_k_respected_without_k_kwarg():
    """submit(query, params=SearchParams(k=...)) without the k kwarg must
    honor the params' k, not the engine default."""
    knobs = []
    eng = BatchingEngine(
        _toy_search_fn([], knobs), dim=4, batch_size=1, default_k=3
    )
    fut = eng.submit(np.zeros(4, np.float32), params=SearchParams(k=7))
    assert fut.result(timeout=30).result.ids.shape == (7,)
    assert knobs[0][0] == 7
    eng.close()


def test_per_request_params_match_direct_search(index):
    """An engine request carrying its own SearchParams returns exactly what
    a direct protocol search with those params returns."""
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, 3, seed=5)
    params = SearchParams(k=4, beam_width=16, lsh_entries=4, max_hops=48)
    want = index.search(q, params=params)
    eng = BatchingEngine.from_index(index, k=4, batch_size=8)
    rows = eng.search(q, params=params)
    np.testing.assert_array_equal(
        np.stack([r.result.ids for r in rows]), want.ids
    )
    np.testing.assert_array_equal(
        np.stack([r.result.ios for r in rows]), want.ios
    )


# ------------------------------------------------- multi-collection core
def test_submit_routes_to_named_collection():
    """The batching core is collection-agnostic: distinct collections form
    distinct (collection, k-bin, params) groups with their own dims and
    backends, on one shared engine."""
    shapes_a, shapes_b = [], []
    eng = BatchingEngine(batch_size=2)
    eng.add_collection("a", _toy_search_fn(shapes_a), dim=4, default_k=3)
    eng.add_collection("b", _toy_search_fn(shapes_b), dim=6, default_k=2)
    assert eng.collections() == ("a", "b")
    fa = [eng.submit(np.full(4, i, np.float32), collection="a")
          for i in range(2)]
    fb = eng.submit(np.full(6, 7.0, np.float32), collection="b")
    eng.flush()
    for i, f in enumerate(fa):
        r = f.result(timeout=30)
        assert r.result.ids.shape == (3,) and r.result.ids[0] == i
    assert fb.result(timeout=30).result.ids.shape == (2,)
    assert fb.result(timeout=30).result.ids[0] == 7
    assert shapes_a == [(2, 4)] and shapes_b == [(2, 6)]
    m = eng.metrics()
    assert m.requests == 3 and m.collections == 2
    eng.close()


def test_collection_routing_errors():
    eng = BatchingEngine(batch_size=2)
    with pytest.raises(RuntimeError, match="no collections"):
        eng.submit(np.zeros(4, np.float32))
    eng.add_collection("a", _toy_search_fn([]), dim=4)
    eng.add_collection("b", _toy_search_fn([]), dim=4)
    with pytest.raises(KeyError, match="'c'"):
        eng.submit(np.zeros(4, np.float32), collection="c")
    with pytest.raises(ValueError, match="multiple collections"):
        eng.submit(np.zeros(4, np.float32))       # ambiguous: no default
    with pytest.raises(ValueError, match="dim"):
        eng.submit(np.zeros(5, np.float32), collection="a")
    with pytest.raises(ValueError, match="already exists"):
        eng.add_collection("a", _toy_search_fn([]), dim=4)
    eng.remove_collection("b")
    assert eng.collections() == ("a",)
    # one collection left: routing without a name falls back to it
    fut = eng.submit(np.zeros(4, np.float32))
    eng.flush()
    assert fut.result(timeout=30)
    with pytest.raises(KeyError):
        eng.remove_collection("b")
    eng.close()


def test_backend_failure_isolated_to_its_group():
    """A backend exception in one (collection, k-bin, params) group must
    fail only that group's futures; other groups — same engine, same
    flush — keep dispatching and resolving, and the engine stays usable."""

    def boom(q, k, params):
        raise RuntimeError("backend down")

    eng = BatchingEngine(batch_size=2)
    eng.add_collection("bad", boom, dim=4)
    eng.add_collection("good", _toy_search_fn([]), dim=4, default_k=3)
    wide = SearchParams(k=3, beam_width=128)
    f_bad = [eng.submit(np.zeros(4, np.float32), collection="bad")
             for _ in range(3)]
    f_good = [eng.submit(np.full(4, float(i), np.float32), collection="good")
              for i in range(3)]
    f_wide = eng.submit(np.full(4, 5.0, np.float32), collection="good",
                        params=wide)
    eng.flush()  # dispatches every group, the failing one included
    for f in f_bad:
        with pytest.raises(RuntimeError, match="backend down"):
            f.result(timeout=5)
    # the good collection's groups resolved despite the sibling failure
    for i, f in enumerate(f_good):
        assert f.result(timeout=5).result.ids[0] == i
    assert f_wide.result(timeout=5).result.ids.shape == (3,)
    # and the engine keeps dispatching new work afterwards
    again = eng.submit(np.full(4, 9.0, np.float32), collection="good")
    eng.flush()
    assert again.result(timeout=5).result.ids[0] == 9
    m = eng.metrics()
    assert m.requests == 5  # failed futures never count as completed
    eng.close()


def test_engine_context_manager_and_idempotent_close():
    with BatchingEngine(_toy_search_fn([]), dim=4, batch_size=8) as eng:
        fut = eng.submit(np.zeros(4, np.float32))
    # __exit__ flushed the ragged batch and closed the engine
    assert fut.result(timeout=5).batch_size == 1
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(np.zeros(4, np.float32))
    eng.close()  # second close is a no-op, not an error
    eng.close()


def test_qps_zero_wall_is_zero_not_inf():
    """EngineMetrics.qps: zero elapsed wall (instantaneous batch, frozen
    clock) must report 0.0 — `float(done and np.inf)` used to return inf
    for any nonzero completed count."""
    eng = BatchingEngine(
        _toy_search_fn([]), dim=4, batch_size=1, clock=lambda: 42.0
    )
    eng.submit(np.zeros(4, np.float32)).result(timeout=30)
    m = eng.metrics()
    assert m.requests == 1
    assert m.qps == 0.0 and np.isfinite(m.qps)
    eng.close()


def test_compile_cache_shared_across_same_geometry_collections():
    """Two collections whose backends share a compiled identity register
    one executable: the second collection's dispatches are all hits."""
    fn = _toy_search_fn([])
    eng = BatchingEngine(batch_size=2)
    eng.add_collection("a", fn, dim=4, default_k=3)
    eng.add_collection("b", fn, dim=4, default_k=3)  # same geometry (same fn)
    eng.search(np.zeros((2, 4), np.float32), collection="a")
    m0 = eng.metrics()
    assert (m0.compile_misses, m0.compile_hits) == (1, 0)
    eng.search(np.zeros((2, 4), np.float32), collection="b")
    m1 = eng.metrics()
    assert m1.compile_misses == 1          # b compiled nothing new
    assert m1.compile_hits == 1
    assert m1.compiled_executables == 1
    # a different params group is its own executable
    eng.search(np.zeros((2, 4), np.float32), collection="b",
               params=SearchParams(k=3, beam_width=128))
    assert eng.metrics().compiled_executables == 2
    eng.close()


# ----------------------------------------------------------- shard_search
def test_shard_search_parity_on_1device_mesh(index):
    q = jnp.asarray(
        query_vectors(clustered_vectors(N, D, num_clusters=16, seed=0), 7, seed=2),
        jnp.float32,
    )
    params = index.resolve_params(10, None)
    kw = dict(capacity=index.store.capacity, mode=index.cfg.memory_mode.value)
    ref = search_mod.batch_search(q, index.data, params, **kw)
    got = search_mod.shard_search(
        q, index.data, params, mesh=make_host_mesh(), **kw
    )
    for field in SearchResult._fields:
        a = np.asarray(getattr(ref, field))
        b = np.asarray(getattr(got, field))
        assert np.array_equal(a, b), field  # bitwise, not approx


# ------------------------------------------------------------ ops routing
def test_search_loop_routes_through_kernel_ops(index, monkeypatch):
    """The fused page scan (member L2 + neighbor ADC from one record DMA)
    and the memory-tier ADC must go through the kernels.ops dispatch layer
    (pallas on TPU, oracle on CPU) — not inline jnp."""
    from repro.kernels import ops

    calls = {"page_scan": 0, "pq_adc": 0}
    real_ps, real_adc = ops.page_scan, ops.pq_adc

    def spy_ps(*a, **k):
        calls["page_scan"] += 1
        return real_ps(*a, **k)

    def spy_adc(*a, **k):
        calls["pq_adc"] += 1
        return real_adc(*a, **k)

    monkeypatch.setattr(ops, "page_scan", spy_ps)
    monkeypatch.setattr(ops, "pq_adc", spy_adc)
    q = jnp.asarray(np.zeros((2, D), np.float32))
    # k=9 is used nowhere else with this index, so jit must re-trace here
    search_mod.batch_search(
        q, index.data, index.resolve_params(9, None),
        capacity=index.store.capacity,
        mode=index.cfg.memory_mode.value,
    )
    assert calls["page_scan"] >= 1
    assert calls["pq_adc"] >= 1


# ------------------------------------------------------- dedup invariant
def _check_keep_first(ids: np.ndarray, d: np.ndarray):
    out = np.asarray(_mask_dups_keep_first(jnp.asarray(ids), jnp.asarray(d)))
    for uid in np.unique(ids):
        where = ids == uid
        if uid == search_mod.PAD:
            np.testing.assert_array_equal(out[where], d[where])
        else:
            finite = np.isfinite(out[where])
            assert finite.sum() == 1, (uid, out[where])
            kept = d[where][finite]
            assert kept[0] in d[where]


def test_mask_dups_keep_first_seeded_sweep():
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 40))
        ids = rng.integers(-1, 12, n).astype(np.int32)
        d = rng.uniform(0, 10, n).astype(np.float32)
        _check_keep_first(ids, d)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        ids=st.lists(st.integers(-1, 15), min_size=1, max_size=48),
        seed=st.integers(0, 2**16),
    )
    def test_mask_dups_keep_first_property(ids, seed):
        ids = np.asarray(ids, np.int32)
        d = np.random.default_rng(seed).uniform(0, 10, len(ids)).astype(np.float32)
        _check_keep_first(ids, d)


# ------------------------------------------------- deadlines + QoS (PR 10)
def test_deadline_expiry_sheds_with_timeout_error():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=8,
                         timeout_ms=None)
    futs = [
        eng.submit(np.zeros(4, np.float32), deadline_ms=0.01)
        for _ in range(2)
    ]
    time.sleep(0.05)
    eng.flush()
    for f in futs:
        with pytest.raises(TimeoutError, match="deadline"):
            f.result(timeout=5)
    m = eng.metrics()
    assert m.sheds == 2
    assert m.requests == 0  # shed rows never count as served
    eng.close()


def test_generous_deadline_completes_normally():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=2)
    futs = [
        eng.submit(np.zeros(4, np.float32), deadline_ms=60_000.0)
        for _ in range(2)
    ]
    for f in futs:
        assert f.result(timeout=10).batch_size == 2
    assert eng.metrics().sheds == 0
    eng.close()


def test_deadline_fires_via_timer_without_flush():
    # no engine timeout and no explicit flush: the deadline itself must
    # arm the timer, or the future would hang forever
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=64,
                         timeout_ms=None)
    fut = eng.submit(np.zeros(4, np.float32), deadline_ms=20.0)
    with pytest.raises(TimeoutError):
        fut.result(timeout=10)
    assert eng.metrics().sheds == 1
    eng.close()


def test_expired_and_live_coexist_in_one_group():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=4,
                         timeout_ms=None)
    doomed = eng.submit(np.zeros(4, np.float32), deadline_ms=5.0)
    time.sleep(0.03)
    live = eng.submit(np.ones(4, np.float32) * 3)
    eng.flush()
    with pytest.raises(TimeoutError):
        doomed.result(timeout=5)
    r = live.result(timeout=5)
    assert r.batch_size == 1  # the expired row was pruned before dispatch
    assert int(np.asarray(r.result.ids)[0]) == 3
    m = eng.metrics()
    assert m.sheds == 1 and m.requests == 1
    eng.close()


def test_deadline_validation():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=2)
    for bad in (0.0, -1.0):
        with pytest.raises(ValueError, match="deadline_ms"):
            eng.submit(np.zeros(4, np.float32), deadline_ms=bad)
    eng.close()


def test_priority_weighted_dispatch_order():
    order = []

    def tagged(tag):
        base = _toy_search_fn([])

        def fn(q, k, params):
            order.append(tag)
            return base(q, k, params)

        return fn

    eng = BatchingEngine(tagged("default"), dim=4, batch_size=64,
                         timeout_ms=None)
    eng.add_collection("hi", tagged("hi"), dim=4, priority=50.0)
    eng.add_collection("lo", tagged("lo"), dim=4, priority=0.5)
    # lo is OLDER, but hi's weight dominates the weighted-aging rank
    lo = eng.submit(np.zeros(4, np.float32), collection="lo")
    time.sleep(0.01)
    hi = eng.submit(np.zeros(4, np.float32), collection="hi")
    eng.flush()  # one group per flush: picks the highest rank first
    eng.flush()
    hi.result(timeout=5), lo.result(timeout=5)
    assert order == ["hi", "lo"]
    eng.close()


def test_priority_validation():
    eng = BatchingEngine(_toy_search_fn([]), dim=4, batch_size=2)
    with pytest.raises(ValueError, match="priority"):
        eng.add_collection("bad", _toy_search_fn([]), dim=4, priority=0.0)
    eng.close()
