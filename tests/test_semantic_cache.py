"""SemanticCache unit behavior (threshold, scoping, LRU, TTL,
invalidation) and its integration with VectorService.submit (cached
futures, write invalidation, stale in-flight misses, metrics merge)."""
import numpy as np
import pytest

from repro.core.search import SearchResult
from repro.serve import SemanticCache, VectorService


def _vec(*xs):
    return np.asarray(xs, np.float32)


def _rot(deg):
    """Unit 2-vector at ``deg`` degrees from [1, 0]."""
    r = np.deg2rad(deg)
    return _vec(np.cos(r), np.sin(r))


# ------------------------------------------------------------------- unit
def test_constructor_validation():
    with pytest.raises(ValueError, match="cosine"):
        SemanticCache(threshold=1.5)
    with pytest.raises(ValueError, match="capacity"):
        SemanticCache(capacity=0)
    with pytest.raises(ValueError, match="ttl"):
        SemanticCache(ttl=0)


def test_threshold_hit_and_miss():
    c = SemanticCache(threshold=np.cos(np.deg2rad(10)))
    c.put("s", _rot(0), "answer")
    assert c.get("s", _rot(5)) == "answer"       # within 10 degrees
    assert c.get("s", _rot(45)) is None          # outside
    # scale-invariant: cosine ignores magnitude
    assert c.get("s", 100.0 * _rot(5)) == "answer"
    s = c.stats()
    assert (s.hits, s.misses, s.entries) == (2, 1, 1)


def test_best_match_wins_not_first():
    c = SemanticCache(threshold=0.9)
    c.put("s", _rot(0), "a")
    c.put("s", _rot(20), "b")
    assert c.get("s", _rot(19)) == "b"


def test_scope_isolation():
    c = SemanticCache(threshold=0.9)
    c.put(("docs", 10, None, None), _rot(0), "ten")
    assert c.get(("docs", 5, None, None), _rot(0)) is None
    assert c.get(("docs", 10, None, None), _rot(0)) == "ten"


def test_lru_eviction_and_hit_refresh():
    c = SemanticCache(threshold=0.99, capacity=2)
    c.put("a", _rot(0), "A")
    c.put("b", _rot(90), "B")
    assert c.get("a", _rot(0)) == "A"   # refresh: 'a' is now most recent
    c.put("c", _rot(180), "C")          # evicts 'b', the LRU tail
    assert c.get("b", _rot(90)) is None
    assert c.get("a", _rot(0)) == "A"
    assert c.get("c", _rot(180)) == "C"
    assert c.stats().evictions == 1
    assert len(c) == 2


def test_ttl_expiry_with_fake_clock():
    now = [0.0]
    c = SemanticCache(threshold=0.9, ttl=10.0, clock=lambda: now[0])
    c.put("s", _rot(0), "fresh")
    now[0] = 9.0
    assert c.get("s", _rot(0)) == "fresh"
    now[0] = 11.0
    assert c.get("s", _rot(0)) is None
    s = c.stats()
    assert s.evictions == 1 and s.entries == 0


def test_invalidate_predicate_and_all():
    c = SemanticCache(threshold=0.9)
    c.put(("docs", 1), _rot(0), "d")
    c.put(("docs", 2), _rot(0), "d2")
    c.put(("wiki", 1), _rot(0), "w")
    assert c.invalidate(lambda s: s[0] == "docs") == 2
    assert c.get(("wiki", 1), _rot(0)) == "w"
    assert c.invalidate() == 1
    assert len(c) == 0
    assert c.stats().invalidations == 3


def test_zero_norm_embeddings_bypass():
    c = SemanticCache(threshold=0.9)
    c.put("s", _vec(0.0, 0.0), "never")
    assert len(c) == 0
    assert c.get("s", _vec(0.0, 0.0)) is None
    c.put("s", _vec(np.inf, 1.0), "never")
    assert len(c) == 0


# ------------------------------------------------------------ integration
class FakeIndex:
    """Deterministic VectorIndex stand-in: row i's ids encode
    round(q[i, 0]); counts dispatched searches."""

    dim = 4

    def __init__(self):
        self.searches = 0
        self.next_id = 100

    def search(self, queries, k=None, params=None, *, mesh=None,
               filter=None, filter_params=None):
        self.searches += 1
        q = np.asarray(queries)
        b, kk = q.shape[0], k or 3
        tag = np.round(q[:, :1]).astype(np.int64)
        z = np.zeros((b,), np.int32)
        return SearchResult(
            ids=tag + np.arange(kk)[None],
            dists=np.zeros((b, kk), np.float32),
            ios=z, hops=z, cache_hits=z,
        )

    def insert(self, vectors, ids=None, *, metadata=None):
        n = len(np.asarray(vectors))
        out = np.arange(self.next_id, self.next_id + n)
        self.next_id += n
        return out

    def delete(self, ids):
        return len(np.asarray(ids).reshape(-1))

    def compact(self):
        return True


def _query(tag):
    v = np.zeros(4, np.float32)
    v[0] = tag
    v[1] = 1.0
    return v


def test_service_serves_repeats_from_cache():
    idx = FakeIndex()
    with VectorService(
        batch_size=4, semantic_cache=SemanticCache(threshold=0.999)
    ) as svc:
        svc.create_collection("docs", idx, k=3)
        first = svc.submit("docs", _query(7))
        svc.flush()
        r1 = first.result()
        assert not r1.cached
        dispatched = idx.searches

        again = svc.submit("docs", _query(7))
        r2 = again.result()  # already completed: no flush needed
        assert r2.cached and r2.batch_index == -1
        assert idx.searches == dispatched
        np.testing.assert_array_equal(
            np.asarray(r1.result.ids), np.asarray(r2.result.ids)
        )
        m = svc.metrics()
        assert m.semantic_hits == 1 and m.semantic_misses == 1


def test_cache_scopes_split_by_k_and_filter():
    with VectorService(
        batch_size=4, semantic_cache=SemanticCache(threshold=0.999)
    ) as svc:
        svc.create_collection("docs", FakeIndex(), k=3)
        svc.submit("docs", _query(1), k=3)
        svc.flush()
        # same embedding, different k: a different question -> miss
        fut = svc.submit("docs", _query(1), k=2)
        svc.flush()
        assert not fut.result().cached


def test_writes_invalidate_cached_answers():
    idx = FakeIndex()
    with VectorService(
        batch_size=4, semantic_cache=SemanticCache(threshold=0.999)
    ) as svc:
        svc.create_collection("docs", idx, k=3)
        svc.submit("docs", _query(5))
        svc.flush()
        assert svc.submit("docs", _query(5)).result().cached

        svc.insert("docs", np.ones((1, 4), np.float32))
        fut = svc.submit("docs", _query(5))
        svc.flush()
        assert not fut.result().cached
        assert svc.metrics().semantic_invalidations >= 1

        # delete and compact invalidate too
        assert svc.submit("docs", _query(5)).result().cached
        svc.delete("docs", [100])
        fut = svc.submit("docs", _query(5))
        svc.flush()
        assert not fut.result().cached

        assert svc.submit("docs", _query(5)).result().cached
        assert svc.compact("docs")
        fut = svc.submit("docs", _query(5))
        svc.flush()
        assert not fut.result().cached


def test_in_flight_miss_does_not_cache_across_a_write():
    """A miss submitted BEFORE a write must not populate the cache when it
    completes after: its result was computed against the old live set."""
    idx = FakeIndex()
    cache = SemanticCache(threshold=0.999)
    with VectorService(batch_size=64, semantic_cache=cache) as svc:
        svc.create_collection("docs", idx, k=3)
        fut = svc.submit("docs", _query(9))  # pending: batch not full
        svc.insert("docs", np.ones((1, 4), np.float32))  # write lands first
        svc.flush()
        fut.result()
        assert len(cache) == 0
        replay = svc.submit("docs", _query(9))
        svc.flush()
        assert not replay.result().cached


def test_no_cache_service_unchanged():
    idx = FakeIndex()
    with VectorService(batch_size=4) as svc:
        svc.create_collection("docs", idx, k=3)
        svc.submit("docs", _query(2))
        svc.flush()
        fut = svc.submit("docs", _query(2))
        svc.flush()
        assert not fut.result().cached
        m = svc.metrics()
        assert m.semantic_hits == 0 and m.semantic_misses == 0
