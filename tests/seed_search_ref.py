"""Frozen copy of the PR-1 (seed) search loop, kept as a test reference.

This is the argsort-based hop body the fused/top-k hot path replaced:
separate ``page_gather_l2`` member scoring and neighbor-code gathers, two
full argsort merges per hop, argsort dedup, and the serial per-pick
``fori_loop`` in ``select_batch``. ``test_search.py`` asserts the optimized
loop in ``repro.core.search`` returns identical results (ids, dists, ios,
hops, cache_hits) on every memory mode — the optimization must be a pure
speedup, not a semantic change. Reads the unpacked ``store.vecs`` /
``store.nbr_codes`` views (the packed record is the optimized path's
concern) through the same jnp oracles the seed dispatched to on CPU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq as pq_mod
from repro.core.config import MemoryMode
from repro.core.lsh import hash_codes
from repro.core.search import BeamState, SearchResult
from repro.kernels import ref

PAD = -1
INF = jnp.inf


class SeedData(NamedTuple):
    vecs: jnp.ndarray
    member_count: jnp.ndarray
    nbr_ids: jnp.ndarray
    nbr_codes: jnp.ndarray
    nbr_count: jnp.ndarray
    mem_codes: jnp.ndarray
    mem_mask: jnp.ndarray
    mem_codebooks: jnp.ndarray
    disk_codebooks: jnp.ndarray
    cached_pages: jnp.ndarray
    lsh_planes: jnp.ndarray
    lsh_ids: jnp.ndarray
    lsh_codes: jnp.ndarray
    lsh_pq: jnp.ndarray


def _mask_dups_keep_first(ids, d):
    order = jnp.argsort(ids)
    s = ids[order]
    dup_sorted = jnp.concatenate([jnp.array([False]), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[order].set(dup_sorted)
    return jnp.where(dup & (ids != PAD), INF, d)


def _init_state(q, data, disk_lut, *, beam, k, entries):
    num_pages = data.vecs.shape[0]
    qcode = hash_codes(q[None], data.lsh_planes)[0]
    ham = ref.hamming_ref(data.lsh_codes, qcode)
    top = jnp.argsort(ham)[:entries]
    entry_ids = data.lsh_ids[top].astype(jnp.int32)
    entry_d = ref.pq_adc_ref(data.lsh_pq[top], disk_lut)
    entry_d = _mask_dups_keep_first(entry_ids, entry_d)
    cand_ids = jnp.full((beam,), PAD, jnp.int32).at[:entries].set(entry_ids)
    cand_d = jnp.full((beam,), INF, jnp.float32).at[:entries].set(entry_d)
    return BeamState(
        cand_ids=cand_ids,
        cand_d=cand_d,
        cand_vis=jnp.zeros((beam,), bool),
        page_vis=jnp.zeros((num_pages,), bool),
        res_ids=jnp.full((k,), PAD, jnp.int32),
        res_d=jnp.full((k,), INF, jnp.float32),
        io=jnp.int32(0),
        cache_hits=jnp.int32(0),
        hops=jnp.int32(0),
    )


def _select_batch(state, *, capacity, io_batch):
    cand_ids = state.cand_ids
    batch = jnp.full((io_batch,), PAD, jnp.int32)

    def pick(j, carry):
        cand_vis, page_vis, batch = carry
        cpages = jnp.where(cand_ids >= 0, cand_ids // capacity, 0)
        stale = (cand_ids != PAD) & page_vis[cpages]
        cand_vis2 = cand_vis | stale
        masked = jnp.where(cand_vis2 | (cand_ids == PAD), INF, state.cand_d)
        slot = jnp.argmin(masked)
        ok = jnp.isfinite(masked[slot])
        cand_vis2 = cand_vis2.at[slot].set(True)
        pid = jnp.where(ok, cand_ids[slot] // capacity, PAD)
        page_vis = jnp.where(
            ok, page_vis.at[jnp.maximum(pid, 0)].set(True), page_vis
        )
        batch = batch.at[j].set(pid)
        return cand_vis2, page_vis, batch

    cand_vis, page_vis, batch = jax.lax.fori_loop(
        0, io_batch, pick, (state.cand_vis, state.page_vis, batch)
    )
    return state._replace(cand_vis=cand_vis, page_vis=page_vis), batch


def _score_members(q, data, batch, *, capacity):
    cap = data.vecs.shape[1]
    safe = jnp.maximum(batch, 0)
    fetched = batch >= 0
    ex = ref.page_gather_l2_ref(data.vecs, safe, q)
    slots = jnp.arange(cap)[None, :]
    ex = jnp.where(slots < data.member_count[safe][:, None], ex, INF)
    ex = jnp.where(fetched[:, None], ex, INF)
    member_ids = (batch[:, None] * capacity + slots).astype(jnp.int32)
    if data.cached_pages.shape[0] > 0:
        pos = jnp.searchsorted(data.cached_pages, safe)
        pos = jnp.minimum(pos, data.cached_pages.shape[0] - 1)
        in_cache = data.cached_pages[pos] == safe
    else:
        in_cache = jnp.zeros_like(fetched)
    io_delta = (fetched & ~in_cache).sum().astype(jnp.int32)
    hit_delta = (fetched & in_cache).sum().astype(jnp.int32)
    return member_ids.ravel(), ex.ravel(), io_delta, hit_delta


def _score_neighbors(data, batch, state, disk_lut, mem_lut, *, capacity, mode):
    rp = data.nbr_ids.shape[1]
    safe = jnp.maximum(batch, 0)
    fetched = batch >= 0
    page_nids = data.nbr_ids[safe]
    page_ncodes = data.nbr_codes[safe]
    page_nc = data.nbr_count[safe]
    flat_nids = page_nids.reshape(-1)
    valid_n = (
        (jnp.arange(rp)[None, :] < page_nc[:, None]).reshape(-1)
        & (flat_nids != PAD)
        & fetched.repeat(rp)
    )
    safe_nids = jnp.maximum(flat_nids, 0)
    est_disk = ref.pq_adc_ref(
        page_ncodes.reshape(-1, page_ncodes.shape[-1]), disk_lut
    )
    if mode == MemoryMode.DISK_ONLY.value:
        est = est_disk
    elif mode == MemoryMode.MEM_ALL.value:
        est = ref.pq_adc_ref(data.mem_codes[safe_nids], mem_lut)
    else:
        est_mem = ref.pq_adc_ref(data.mem_codes[safe_nids], mem_lut)
        est = jnp.where(data.mem_mask[safe_nids], est_mem, est_disk)
    est = jnp.where(valid_n, est, INF)
    est = jnp.where(state.page_vis[safe_nids // capacity], INF, est)
    dup_in_cand = (flat_nids[:, None] == state.cand_ids[None, :]).any(1)
    est = jnp.where(dup_in_cand, INF, est)
    est = _mask_dups_keep_first(flat_nids, est)
    return flat_nids, est


def _merge(state, member_ids, member_d, nbr_ids, nbr_d, io_delta, hit_delta):
    k = state.res_ids.shape[0]
    beam = state.cand_ids.shape[0]
    all_rd = jnp.concatenate([state.res_d, member_d])
    all_ri = jnp.concatenate([state.res_ids, member_ids])
    order = jnp.argsort(all_rd)[:k]
    res_d, res_ids = all_rd[order], all_ri[order]
    all_ci = jnp.concatenate([state.cand_ids, nbr_ids])
    all_cd = jnp.concatenate([state.cand_d, nbr_d])
    all_cv = jnp.concatenate([state.cand_vis, jnp.zeros(nbr_ids.shape, bool)])
    order = jnp.argsort(all_cd)[:beam]
    return state._replace(
        cand_ids=all_ci[order],
        cand_d=all_cd[order],
        cand_vis=all_cv[order],
        res_ids=res_ids,
        res_d=res_d,
        io=state.io + io_delta,
        cache_hits=state.cache_hits + hit_delta,
        hops=state.hops + 1,
    )


def _search_one(q, data, *, capacity, beam, io_batch, k, max_hops, entries, mode):
    disk_lut = pq_mod.pq_lut(q, data.disk_codebooks)
    mem_lut = pq_mod.pq_lut(q, data.mem_codebooks)
    state = _init_state(q, data, disk_lut, beam=beam, k=k, entries=entries)

    def cond(state):
        live = (
            (~state.cand_vis)
            & (state.cand_ids != PAD)
            & jnp.isfinite(state.cand_d)
        )
        return live.any() & (state.hops < max_hops)

    def body(state):
        state, batch = _select_batch(state, capacity=capacity, io_batch=io_batch)
        mids, md, io_delta, hit_delta = _score_members(
            q, data, batch, capacity=capacity
        )
        nids, nd = _score_neighbors(
            data, batch, state, disk_lut, mem_lut, capacity=capacity, mode=mode
        )
        return _merge(state, mids, md, nids, nd, io_delta, hit_delta)

    state = jax.lax.while_loop(cond, body, state)
    return state.res_ids, state.res_d, state.io, state.hops, state.cache_hits


@functools.partial(
    jax.jit,
    static_argnames=(
        "capacity", "beam", "io_batch", "k", "max_hops", "entries", "mode"
    ),
)
def _seed_batch_search(queries, data, *, capacity, beam, io_batch, k,
                       max_hops, entries, mode):
    fn = functools.partial(
        _search_one, data=data, capacity=capacity, beam=beam,
        io_batch=io_batch, k=k, max_hops=max_hops, entries=entries, mode=mode,
    )
    ids, dists, ios, hops, hits = jax.vmap(fn)(queries)
    return SearchResult(ids=ids, dists=dists, ios=ios, hops=hops, cache_hits=hits)


def seed_batch_search(queries, index, k: int = 10) -> SearchResult:
    """Run the frozen seed loop against a built ``PageANNIndex``.

    Returns REASSIGNED ids (same space as ``index._raw_search``).
    """
    store, tier, lsh = index.store, index.tier, index.lsh
    data = SeedData(
        vecs=store.vecs,
        member_count=store.member_count,
        nbr_ids=store.nbr_ids,
        nbr_codes=store.nbr_codes,
        nbr_count=store.nbr_count,
        mem_codes=tier.mem_codes,
        mem_mask=tier.mem_mask,
        mem_codebooks=tier.mem_codebooks,
        disk_codebooks=tier.disk_codebooks,
        cached_pages=tier.cached_pages,
        lsh_planes=lsh.planes,
        lsh_ids=lsh.sample_ids,
        lsh_codes=lsh.sample_codes,
        lsh_pq=lsh.sample_pq,
    )
    cfg = index.cfg
    return _seed_batch_search(
        queries, data,
        capacity=store.capacity, beam=cfg.beam_width, io_batch=cfg.io_batch,
        k=k, max_hops=cfg.max_hops, entries=cfg.lsh_entries,
        mode=cfg.memory_mode.value,
    )
