"""Filtered search: schema/expression validation, in-scan masking recall
parity vs a post-filter brute force across MemoryModes + the streamed
tier, persistence round-trips, mutable-tier filtering, and engine group
keying by filter."""
import json
import math
import os

import numpy as np
import pytest

from repro.core import (
    IndexFormatError,
    MemoryBudget,
    MemoryMode,
    MetadataSchema,
    MutableIndex,
    Num,
    PageANNConfig,
    PageANNIndex,
    Tag,
    load_index,
    recall_at_k,
)
from repro.core import filter as filter_mod
from repro.core import persist
from repro.core.filter import FilterExpr, compile_filter, filter_mask_np
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.serve import BatchingEngine

N, D, Q, K = 1200, 32, 8, 10
PAD = -1
MODES = (MemoryMode.DISK_ONLY, MemoryMode.HYBRID, MemoryMode.MEM_ALL)
SELECTIVITIES = (0.5, 0.1, 0.01)


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, Q, seed=1)
    rng = np.random.default_rng(7)
    meta = {
        "lang": rng.choice(["en", "de", "fr"], N).tolist(),
        "score": rng.uniform(0.0, 1.0, N).tolist(),
    }
    return x, q, meta


SCHEMA = MetadataSchema(tags=("lang",), numerics=("score",))


@pytest.fixture(scope="module")
def indexes(dataset):
    """One with-metadata build per MemoryMode (the expensive part,
    shared by every parity case)."""
    x, _, meta = dataset
    return {
        mode: PageANNIndex.build(
            x, _cfg(memory_mode=mode), schema=SCHEMA, metadata=meta
        )
        for mode in MODES
    }


def _oracle(x, q, mask, k):
    """Post-filter brute force: exact top-k over passing rows only."""
    idx = np.flatnonzero(mask)
    take = min(k, len(idx))
    d = ((q[:, None, :] - x[idx][None]) ** 2).sum(-1)
    out = np.full((len(q), k), PAD, np.int64)
    out[:, :take] = idx[np.argsort(d, axis=1)[:, :take]]
    return out


def _host_mask(idx, expr):
    cf, _ = idx.compiled_filter(expr)
    return filter_mask_np(cf, idx.meta_host.tags, idx.meta_host.nums)


# ------------------------------------------------------------- validation
def test_schema_reports_every_violation_in_one_error():
    with pytest.raises(ValueError) as e:
        MetadataSchema(tags=("ok", "ok", "not an id"),
                       numerics=("ok", "x", "x"))
    msg = str(e.value)
    assert "duplicate tags" in msg
    assert "duplicate numerics" in msg
    assert "identifiers" in msg
    assert "both tag and numeric" in msg
    with pytest.raises(ValueError, match="at least one field"):
        MetadataSchema()


def test_expr_validation_and_canonical_hashing():
    with pytest.raises(ValueError) as e:
        FilterExpr(tag_clauses=(("f", ()),),
                   num_clauses=(("g", 2.0, 1.0), ("h", math.nan, 0.0)))
    msg = str(e.value)
    assert "empty value set" in msg and "lo > hi" in msg and "NaN" in msg
    with pytest.raises(ValueError, match="at least one clause"):
        FilterExpr()
    # clause order must not matter: engine group keys and the compile
    # cache both hash the expression
    a = Tag("lang").isin("en", "de") & Num("score").le(0.5)
    b = Num("score").le(0.5) & Tag("lang").isin("de", "en")
    assert a == b and hash(a) == hash(b)


def test_compile_reports_unknown_fields_with_kind_hints():
    expr = (Tag("nope").isin("x") & Tag("score").isin("x")
            & Num("lang").ge(0))
    with pytest.raises(ValueError) as e:
        compile_filter(expr, SCHEMA, {})
    msg = str(e.value)
    assert "unknown tag field 'nope'" in msg
    assert "unknown tag field 'score' (declared numeric)" in msg
    assert "unknown numeric field 'lang' (declared tag)" in msg


def test_filter_on_schemaless_index_is_an_error(dataset):
    x, q, _ = dataset
    idx = PageANNIndex.build(x[:300], _cfg())
    with pytest.raises(ValueError, match="no MetadataSchema"):
        idx.search(q, K, filter=Tag("lang") == "en")


def test_unknown_tag_value_matches_nothing(indexes, dataset):
    _, q, _ = dataset
    idx = indexes[MemoryMode.HYBRID]
    res = idx.search(q, K, filter=Tag("lang") == "klingon")
    assert np.all(np.asarray(res.ids) == PAD)


def test_metadata_normalization_reports_all_problems(dataset):
    x, _, _ = dataset
    with pytest.raises(ValueError) as e:
        filter_mod.normalize_metadata(
            SCHEMA, {"bogus": [1] * 5, "score": [1.0] * 3}, 5
        )
    msg = str(e.value)
    assert "unknown metadata field 'bogus'" in msg
    assert "3 entries for 5 vectors" in msg


# ----------------------------------------------------- recall parity gates
@pytest.mark.parametrize("mode", MODES, ids=lambda m: m.value)
def test_filtered_recall_matches_postfilter_oracle(indexes, dataset, mode):
    x, q, meta = dataset
    idx = indexes[mode]
    scores = np.asarray(meta["score"])
    for sel in SELECTIVITIES:
        expr = Num("score").le(float(np.quantile(scores, sel)))
        truth = _oracle(x, q, _host_mask(idx, expr), K)
        res = idx.search(q, K, filter=expr)
        rec = recall_at_k(res.ids, truth)
        assert rec >= 0.9, f"{mode.value} sel={sel}: recall {rec}"
        # every returned id actually passes the predicate
        passing = set(np.flatnonzero(_host_mask(idx, expr)).tolist())
        got = np.asarray(res.ids)
        assert set(got[got != PAD].tolist()) <= passing


def test_conjunction_tag_and_numeric(indexes, dataset):
    x, q, meta = dataset
    idx = indexes[MemoryMode.HYBRID]
    expr = Tag("lang").isin("en", "de") & Num("score").between(0.2, 0.8)
    truth = _oracle(x, q, _host_mask(idx, expr), K)
    res = idx.search(q, K, filter=expr)
    assert recall_at_k(res.ids, truth) >= 0.9


@pytest.mark.parametrize("mode", MODES, ids=lambda m: m.value)
def test_no_filter_is_bit_identical_to_metadata_free_build(
    indexes, dataset, mode
):
    x, q, _ = dataset
    plain = PageANNIndex.build(x, _cfg(memory_mode=mode))
    want, got = plain.search(q, K), indexes[mode].search(q, K)
    for f in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
            err_msg=f"{mode.value}: {f}",
        )


def test_streamed_filtered_search_is_bit_identical(indexes, dataset,
                                                   tmp_path):
    x, q, meta = dataset
    idx = indexes[MemoryMode.HYBRID]
    d = str(tmp_path / "streamed.pageann")
    idx.save(d)
    streamed = load_index(d, memory_budget=MemoryBudget(fraction=0.25))
    scores = np.asarray(meta["score"])
    for sel in SELECTIVITIES:
        expr = Num("score").le(float(np.quantile(scores, sel)))
        want = idx.search(q, K, filter=expr)
        got = streamed.search(q, K, filter=expr)
        for f in want._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
                err_msg=f"sel={sel}: {f}",
            )


# ------------------------------------------------------------- persistence
def test_persist_round_trip_keeps_filtering(indexes, dataset, tmp_path):
    _, q, _ = dataset
    idx = indexes[MemoryMode.HYBRID]
    d = str(tmp_path / "rt.pageann")
    idx.save(d)
    assert os.path.isfile(os.path.join(d, persist.META_NPZ))
    loaded = load_index(d)
    assert loaded.schema == SCHEMA and loaded.vocab == idx.vocab
    expr = Tag("lang") == "en"
    want, got = idx.search(q, K, filter=expr), loaded.search(q, K, filter=expr)
    np.testing.assert_array_equal(want.ids, got.ids)
    np.testing.assert_array_equal(want.dists, got.dists)


def _manifest(d):
    with open(os.path.join(d, persist.MANIFEST)) as f:
        return json.load(f)


def _write_manifest(d, doc):
    with open(os.path.join(d, persist.MANIFEST), "w") as f:
        json.dump(doc, f)


def test_load_errors_are_index_format_errors(indexes, tmp_path):
    idx = indexes[MemoryMode.HYBRID]

    # sidecar deleted but manifest still declares a schema
    d1 = str(tmp_path / "no_sidecar.pageann")
    idx.save(d1)
    os.remove(os.path.join(d1, persist.META_NPZ))
    with pytest.raises(IndexFormatError, match="meta.npz"):
        load_index(d1)

    # manifest schema section dropped but the sidecar is present
    d2 = str(tmp_path / "no_schema.pageann")
    idx.save(d2)
    doc = _manifest(d2)
    del doc["schema"]
    _write_manifest(d2, doc)
    with pytest.raises(IndexFormatError, match="schema"):
        load_index(d2)

    # sidecar shape disagrees with the manifest schema
    d3 = str(tmp_path / "bad_shape.pageann")
    idx.save(d3)
    with np.load(os.path.join(d3, persist.META_NPZ)) as z:
        tags, nums = z["tags"], z["nums"]
    np.savez(os.path.join(d3, persist.META_NPZ),
             tags=tags[:, :0], nums=nums)
    with pytest.raises(IndexFormatError, match="shape"):
        load_index(d3)

    # garbled schema section is a format error, not a KeyError
    d4 = str(tmp_path / "garbled.pageann")
    idx.save(d4)
    doc = _manifest(d4)
    doc["schema"] = {"tags": 13}
    _write_manifest(d4, doc)
    with pytest.raises(IndexFormatError):
        load_index(d4)


# ------------------------------------------------------------ mutable tier
def test_mutable_insert_metadata_filterable_immediately(dataset):
    x, q, meta = dataset
    base = PageANNIndex.build(
        x[:800], _cfg(),
        schema=SCHEMA,
        metadata={k: v[:800] for k, v in meta.items()},
    )
    mut = MutableIndex(base, auto_compact=False)
    fresh = x[800:810]
    new_ids = mut.insert(
        fresh,
        metadata={"lang": ["xx"] * 10, "score": [0.5] * 10},
    )
    # "xx" is a NEW tag value: the unified vocab grows append-only, base
    # codes stay stable, and the fresh rows are filterable with no rebuild
    assert "xx" in mut.vocab["lang"]
    res = mut.search(fresh, k=1, filter=Tag("lang") == "xx")
    assert set(np.asarray(res.ids)[:, 0].tolist()) == set(new_ids.tolist())
    # base-tier rows still match their original tags through the delta path
    res_en = mut.search(q, K, filter=Tag("lang") == "en")
    assert np.all(np.asarray(res_en.ids) < 800)

    # compaction re-encodes both tiers under a fresh vocab; the filtered
    # answer set is unchanged
    before = mut.search(fresh, k=1, filter=Tag("lang") == "xx")
    assert mut.compact()
    after = mut.search(fresh, k=1, filter=Tag("lang") == "xx")
    np.testing.assert_array_equal(
        np.asarray(before.ids), np.asarray(after.ids)
    )


def test_mutable_save_load_round_trips_metadata(dataset, tmp_path):
    x, q, meta = dataset
    base = PageANNIndex.build(
        x[:600], _cfg(),
        schema=SCHEMA,
        metadata={k: v[:600] for k, v in meta.items()},
    )
    mut = MutableIndex(base, auto_compact=False)
    mut.insert(x[600:605],
               metadata={"lang": ["zz"] * 5, "score": [0.9] * 5})
    d = str(tmp_path / "mut.pageann")
    mut.save(d)
    loaded = load_index(d)
    assert isinstance(loaded, MutableIndex)
    assert loaded.vocab == mut.vocab
    expr = Tag("lang") == "zz"
    want = mut.search(q, K, filter=expr)
    got = loaded.search(q, K, filter=expr)
    np.testing.assert_array_equal(
        np.asarray(want.ids), np.asarray(got.ids)
    )


# ------------------------------------------------------- engine (satellite)
def test_engine_groups_by_filter_and_matches_direct_search(indexes, dataset):
    _, q, _ = dataset
    idx = indexes[MemoryMode.HYBRID]
    en, de = Tag("lang") == "en", Tag("lang") == "de"
    with BatchingEngine.from_index(idx, k=K, batch_size=64) as eng:
        futs = (
            [eng.submit(v, filter=en) for v in q]
            + [eng.submit(v, filter=de) for v in q]
            + [eng.submit(v) for v in q]
        )
        eng.flush()
        rows = [f.result() for f in futs]
        # three distinct pending groups -> three dispatches, even though
        # one 64-wide batch could hold all 24 requests
        assert eng.metrics().batches == 3
    for flt, chunk in zip((en, de, None), range(3)):
        got = np.stack(
            [r.result.ids for r in rows[chunk * Q:(chunk + 1) * Q]]
        )
        want = idx.search(q, K, filter=flt)
        np.testing.assert_array_equal(got, np.asarray(want.ids))


def test_raw_search_fn_backend_rejects_filter():
    from repro.core.search import SearchResult

    def toy(q, k, params):
        b = len(q)
        z = np.zeros((b,), np.int32)
        return SearchResult(
            ids=np.zeros((b, k), np.int64),
            dists=np.zeros((b, k), np.float32),
            ios=z, hops=z, cache_hits=z,
        )

    with BatchingEngine(toy, dim=4, batch_size=2, default_k=3) as eng:
        with pytest.raises(ValueError, match="does not support filtered"):
            eng.submit(np.zeros(4, np.float32), filter=Tag("x") == "y")


# ----------------------------------------------------- property (hypothesis)
def test_random_predicates_match_oracle_property(indexes, dataset):
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    x, q, meta = dataset
    scores = np.asarray(meta["score"])

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(
        langs=st.sets(st.sampled_from(["en", "de", "fr"]), min_size=1),
        lo=st.floats(0.0, 1.0),
        width=st.floats(0.05, 1.0),
        mode=st.sampled_from(MODES),
    )
    def check(langs, lo, width, mode):
        idx = indexes[mode]
        expr = (Tag("lang").isin(*sorted(langs))
                & Num("score").between(lo, lo + width))
        mask = _host_mask(idx, expr)
        res = idx.search(q, K, filter=expr)
        got = np.asarray(res.ids)
        passing = set(np.flatnonzero(mask).tolist())
        assert set(got[got != PAD].tolist()) <= passing
        if mask.sum() >= K:
            truth = _oracle(x, q, mask, K)
            assert recall_at_k(got, truth) >= 0.9

    check()
