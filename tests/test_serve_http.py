"""HTTP frontend contracts: endpoint surface over a live socket,
admission control (in-flight 503, token-bucket 429, deadline 504),
validation errors, and the shared metrics exposition."""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import MemoryMode, PageANNConfig, PageANNIndex
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.obs import parse_prometheus_text, sample_value
from repro.serve import HttpFrontend, TokenBucket, VectorService

N, D, K = 600, 32, 10


@pytest.fixture(scope="module")
def corpus():
    return clustered_vectors(N, D, num_clusters=16, seed=0)


@pytest.fixture(scope="module")
def index(corpus):
    cfg = PageANNConfig(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    return PageANNIndex.build(corpus, cfg)


@pytest.fixture()
def served(index):
    with VectorService(batch_size=16, timeout_ms=5.0) as svc:
        svc.create_collection("wiki", index, k=K)
        with HttpFrontend(svc, port=0, max_inflight=4) as fe:
            yield svc, fe


def _post(url, doc, timeout=60.0):
    req = urllib.request.Request(
        url, json.dumps(doc).encode(), {"Content-Type": "application/json"}
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def _get(url, timeout=30.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read()


# -------------------------------------------------------------- endpoints
def test_search_batch_matches_direct(served, corpus):
    svc, fe = served
    q = query_vectors(corpus, 6, seed=3)
    truth = brute_force_knn(corpus, q, K)
    code, doc, _ = _post(fe.url + "/search", {
        "collection": "wiki", "queries": q.tolist(), "k": K,
    })
    assert code == 200 and doc["shed"] == 0
    ids = np.array([r["ids"] for r in doc["results"]])
    assert ids.shape == (6, K)
    hits = sum(
        len(set(map(int, r)) & set(map(int, t)))
        for r, t in zip(ids, truth)
    )
    assert hits / truth.size >= 0.8
    # the HTTP answer is the engine's answer, not an approximation of it
    direct = np.array([
        np.asarray(rr.result.ids).reshape(-1)
        for rr in svc.search("wiki", q, k=K)
    ])
    assert np.array_equal(ids, direct)


def test_single_query_form(served, corpus):
    _, fe = served
    code, doc, _ = _post(fe.url + "/search", {
        "collection": "wiki", "query": corpus[7].tolist(),
    })
    assert code == 200
    assert isinstance(doc["results"], dict)  # unwrapped, not a 1-list
    assert doc["results"]["ids"][0] == 7


def test_collections_healthz_stats(served):
    _, fe = served
    code, body = _get(fe.url + "/collections")
    doc = json.loads(body)
    assert code == 200
    assert {"name": "wiki", "dim": D} in doc["collections"]
    code, body = _get(fe.url + "/healthz")
    assert code == 200 and body == b"ok\n"
    code, body = _get(fe.url + "/stats")
    stats = json.loads(body)
    assert code == 200
    assert "metrics" in stats and "wiki" in stats["collections"]


def test_metrics_exposition_covers_http_and_engine(served, corpus):
    _, fe = served
    _post(fe.url + "/search", {
        "collection": "wiki", "query": corpus[0].tolist(),
    })
    code, body = _get(fe.url + "/metrics")
    assert code == 200
    parsed = parse_prometheus_text(body.decode())
    assert sample_value(
        parsed, "pageann_http_requests_total", route="/search", code="200"
    ) >= 1
    # engine series ride the same registry: one scrape target
    assert sample_value(parsed, "pageann_requests_total") >= 1
    assert sample_value(parsed, "pageann_sheds_total") == 0


# -------------------------------------------------------------- validation
def test_validation_errors(served, corpus):
    _, fe = served
    url = fe.url
    assert _post(url + "/search", {"queries": [[0.0] * D]})[0] == 400
    assert _post(url + "/search", {"collection": "nope",
                                   "queries": [[0.0] * D]})[0] == 404
    assert _post(url + "/search", {"collection": "wiki"})[0] == 400
    assert _post(url + "/search", {"collection": "wiki",
                                   "queries": []})[0] == 400
    assert _post(url + "/search", {"collection": "wiki",
                                   "queries": [[1.0, 2.0]]})[0] == 400
    assert _post(url + "/nope", {})[0] == 404
    # immutable collection: writes are 400, not 500
    assert _post(url + "/insert", {
        "collection": "wiki", "vectors": [corpus[0].tolist()],
    })[0] == 400
    assert _post(url + "/delete", {"collection": "wiki", "ids": [1]})[0] == 400
    req = urllib.request.Request(
        url + "/search", b"{not json", {"Content-Type": "application/json"}
    )
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=30)
    assert ei.value.code == 400


# -------------------------------------------------------- admission + QoS
def test_rate_limit_429_with_retry_after(index):
    with VectorService(batch_size=16, timeout_ms=5.0) as svc:
        svc.create_collection("wiki", index, k=K)
        with HttpFrontend(
            svc, port=0, rate_limits={"wiki": (0.001, 2.0)}
        ) as fe:
            q = {"collection": "wiki", "query": [0.0] * D}
            codes, headers = [], []
            for _ in range(4):
                c, _, h = _post(fe.url + "/search", q)
                codes.append(c)
                headers.append(h)
            assert codes == [200, 200, 429, 429]
            assert int(headers[2]["Retry-After"]) >= 1
            _, body = _get(fe.url + "/metrics")
            parsed = parse_prometheus_text(body.decode())
            assert sample_value(
                parsed, "pageann_http_rejected_total", reason="ratelimit"
            ) == 2


def test_inflight_cap_503(served, corpus):
    _, fe = served
    # deterministically exhaust the in-flight budget (4), then observe
    # the shed path without relying on races between server threads
    for _ in range(4):
        assert fe._inflight.acquire(blocking=False)
    try:
        code, doc, _ = _post(fe.url + "/search", {
            "collection": "wiki", "query": corpus[0].tolist(),
        })
        assert code == 503 and "overloaded" in doc["error"]
    finally:
        for _ in range(4):
            fe._inflight.release()
    code, _, _ = _post(fe.url + "/search", {
        "collection": "wiki", "query": corpus[0].tolist(),
    })
    assert code == 200  # released capacity admits again
    _, body = _get(fe.url + "/metrics")
    parsed = parse_prometheus_text(body.decode())
    assert sample_value(
        parsed, "pageann_http_rejected_total", reason="inflight"
    ) == 1


def test_deadline_504_counts_engine_sheds(served, corpus):
    _, fe = served
    code, doc, _ = _post(fe.url + "/search", {
        "collection": "wiki", "queries": corpus[:4].tolist(),
        "deadline_ms": 0.001,
    })
    assert code == 504
    _, body = _get(fe.url + "/metrics")
    parsed = parse_prometheus_text(body.decode())
    assert sample_value(parsed, "pageann_sheds_total") == 4
    assert sample_value(
        parsed, "pageann_http_rejected_total", reason="deadline"
    ) == 1


def test_service_healthy_after_sheds(served, corpus):
    _, fe = served
    code, _, _ = _post(fe.url + "/search", {
        "collection": "wiki", "queries": corpus[:2].tolist(),
        "deadline_ms": 0.001,
    })
    assert code == 504
    # a shed batch leaves no poisoned state behind: the very next
    # request on the same group completes normally
    code, doc, _ = _post(fe.url + "/search", {
        "collection": "wiki", "queries": corpus[:2].tolist(),
    })
    assert code == 200 and doc["shed"] == 0
    assert all(r is not None for r in doc["results"])


# ------------------------------------------------------------ token bucket
def test_token_bucket_refill_and_burst():
    t = [0.0]
    b = TokenBucket(rate=2.0, burst=4.0, clock=lambda: t[0])
    assert [b.try_acquire() for _ in range(5)] == [True] * 4 + [False]
    assert b.retry_after_s() == pytest.approx(0.5)
    t[0] += 1.0  # 2 tokens accrue
    assert b.try_acquire() and b.try_acquire() and not b.try_acquire()
    t[0] += 100.0  # refill clamps at burst
    assert [b.try_acquire() for _ in range(5)] == [True] * 4 + [False]


def test_token_bucket_validation():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.0)
