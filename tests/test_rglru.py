"""RG-LRU associative scan vs per-step recurrence; full block consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import rglru as rg


def test_scan_matches_stepwise():
    cfg = get_arch("recurrentgemma-9b", smoke=True)
    params = rg.init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    B, T, w = 2, 9, cfg.rnn_width
    x = jnp.asarray(rng.standard_normal((B, T, w)), jnp.float32)

    y_scan, hT = rg.rglru_scan(params, x)

    h = jnp.zeros((B, w), jnp.float32)
    ys = []
    for t in range(T):
        y1, h = rg.rglru_step(params, x[:, t], h)
        ys.append(y1)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h), atol=1e-5, rtol=1e-4)


def test_scan_with_initial_state():
    cfg = get_arch("recurrentgemma-9b", smoke=True)
    params = rg.init_rglru(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    B, T, w = 1, 8, cfg.rnn_width
    x = jnp.asarray(rng.standard_normal((B, T, w)), jnp.float32)
    # run whole sequence vs split halves carrying state
    y_full, h_full = rg.rglru_scan(params, x)
    y1, h1 = rg.rglru_scan(params, x[:, : T // 2])
    y2, h2 = rg.rglru_scan(params, x[:, T // 2 :], h0=h1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(jnp.concatenate([y1, y2], 1)),
        atol=1e-5, rtol=1e-4,
    )
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), atol=1e-5, rtol=1e-4)


def test_recurrent_block_step_matches_scan():
    cfg = get_arch("recurrentgemma-9b", smoke=True)
    params = rg.init_rglru(jax.random.PRNGKey(2), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    B, T = 2, 7
    u = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)), jnp.float32)
    full, _ = rg.recurrent_block(params, u, cfg)
    w = cfg.rnn_width or cfg.d_model
    state = {
        "conv": jnp.zeros((B, cfg.conv_width - 1, w)),
        "h": jnp.zeros((B, w)),
    }
    outs = []
    for t in range(T):
        o, state = rg.recurrent_block_step(params, u[:, t], cfg, state)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(jnp.stack(outs, 1)), atol=1e-4, rtol=1e-3
    )
