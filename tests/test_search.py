"""End-to-end PageANN search behaviour (Algorithm 2) + memory-mode matrix
+ exact equivalence of the fused/top-k hot path against the frozen seed loop."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import seed_search_ref
from repro.core import MemoryMode, PageANNConfig, PageANNIndex, recall_at_k
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

N, D, Q = 2500, 32, 25


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=32, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=16, build_beam=32, pq_subspaces=8,
        lsh_sample=512, lsh_entries=8, beam_width=64, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def hybrid_index(dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg())


def test_recall_at_10(dataset, hybrid_index):
    x, q, truth = dataset
    res = hybrid_index.search(q, k=10)
    r = recall_at_k(res.ids, truth)
    assert r >= 0.85, r


def test_io_accounting_invariants(dataset, hybrid_index):
    _, q, _ = dataset
    res = hybrid_index.search(q, k=10)
    cfg = hybrid_index.cfg
    assert (res.ios <= res.hops * cfg.io_batch).all()
    assert (res.ios + res.cache_hits >= res.hops).all()   # >=1 fresh page/hop
    assert (res.ios <= hybrid_index.store.num_pages).all()  # visited-set works


@pytest.fixture(scope="module", params=list(MemoryMode), ids=lambda m: m.value)
def mode_index(request, dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg(memory_mode=request.param))


def test_memory_modes_all_reach_recall(dataset, mode_index):
    _, q, truth = dataset
    res = mode_index.search(q, k=10)
    r = recall_at_k(res.ids, truth)
    assert r >= 0.8, (mode_index.cfg.memory_mode, r)


def test_optimized_loop_matches_seed_search(dataset, mode_index):
    """The fused page-scan + top-k hot path is a pure speedup: identical
    results, I/O counts, and hop counts to the frozen seed loop (argsort
    merges, serial select, split member/neighbor gathers) on every
    memory-disk coordination mode."""
    _, q, _ = dataset
    qj = jnp.asarray(q, jnp.float32)
    got = mode_index._raw_search(qj, k=10)
    want = seed_search_ref.seed_batch_search(qj, mode_index, k=10)
    np.testing.assert_array_equal(np.asarray(got.ios), np.asarray(want.ios))
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(
        np.asarray(got.cache_hits), np.asarray(want.cache_hits)
    )
    np.testing.assert_allclose(
        np.asarray(got.dists), np.asarray(want.dists), rtol=1e-6, atol=1e-6
    )
    # id sets match row-wise (ordering may differ only across exact ties)
    for i in range(len(q)):
        assert set(np.asarray(got.ids)[i].tolist()) == set(
            np.asarray(want.ids)[i].tolist()
        ), i


def test_mem_all_packs_more_vectors_per_page(dataset):
    x, _, _ = dataset
    disk = PageANNIndex.build(x, _cfg(memory_mode=MemoryMode.DISK_ONLY))
    mem = PageANNIndex.build(x, _cfg(memory_mode=MemoryMode.MEM_ALL))
    # Sec 4.3(3): freed page bytes -> more vectors per page -> fewer pages
    assert mem.store.capacity > disk.store.capacity
    assert mem.store.num_pages < disk.store.num_pages


def test_page_cache_reduces_counted_ios(dataset):
    x, q, truth = dataset
    idx = PageANNIndex.build(x, _cfg(cache_pages=32))
    before = idx.search(q, k=10)
    idx.warm_cache(q)
    after = idx.search(q, k=10)
    assert after.cache_hits.sum() > 0
    assert after.ios.mean() < before.ios.mean()
    # caching must not change results
    assert recall_at_k(after.ids, truth) >= recall_at_k(before.ids, truth) - 1e-9


def test_results_sorted_and_unique(dataset, hybrid_index):
    _, q, _ = dataset
    res = hybrid_index.search(q, k=10)
    for i in range(len(q)):
        d = res.dists[i]
        assert (np.diff(d[np.isfinite(d)]) >= -1e-6).all()
        ids = res.ids[i][res.ids[i] >= 0]
        assert len(np.unique(ids)) == len(ids)


def test_beam_width_trades_io_for_recall(dataset):
    x, q, truth = dataset
    lo = PageANNIndex.build(x, _cfg(beam_width=16, lsh_entries=4))
    hi = PageANNIndex.build(x, _cfg(beam_width=96, lsh_entries=16))
    r_lo = recall_at_k(lo.search(q, k=10).ids, truth)
    r_hi = recall_at_k(hi.search(q, k=10).ids, truth)
    io_lo = lo.search(q, k=10).ios.mean()
    io_hi = hi.search(q, k=10).ios.mean()
    assert r_hi >= r_lo
    assert io_hi >= io_lo


def test_high_dim_vectors_span_multiple_record_rows():
    """dim > 128 packs each member vector over ceil(d/128) record rows —
    the fused hot path must handle standard embedding sizes end to end."""
    d = 160  # rpv = 2, and 160/8 PQ subspaces divides evenly
    x = clustered_vectors(600, d, num_clusters=8, seed=4)
    q = query_vectors(x, 8, seed=5)
    truth = brute_force_knn(x, q, 10)
    idx = PageANNIndex.build(
        x,
        PageANNConfig(
            dim=d, graph_degree=12, build_beam=24, pq_subspaces=8,
            lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
            memory_mode=MemoryMode.HYBRID,
        ),
    )
    res = idx.search(q, k=10)
    assert recall_at_k(res.ids, truth) >= 0.7


def test_layout_equation_capacity():
    cfg = _cfg(page_bytes=4096, pq_subspaces=8, page_degree=48)
    cap = cfg.resolve_capacity()
    # Sec 4.2 equation: (4096 - 8 - 48*4 - 24*8) / (32*4) for HYBRID
    assert cap == (4096 - 8 - 48 * 4 - 24 * 8) // (32 * 4)
