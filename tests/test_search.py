"""End-to-end PageANN search behaviour (Algorithm 2) + memory-mode matrix
+ exact equivalence of the fused/top-k hot path against the frozen seed loop."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

import seed_search_ref
from repro.core import (
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    recall_at_k,
)
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

N, D, Q = 2500, 32, 25


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=32, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=16, build_beam=32, pq_subspaces=8,
        lsh_sample=512, lsh_entries=8, beam_width=64, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def hybrid_index(dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg())


def test_recall_at_10(dataset, hybrid_index):
    x, q, truth = dataset
    res = hybrid_index.search(q, k=10)
    r = recall_at_k(res.ids, truth)
    assert r >= 0.85, r


def test_io_accounting_invariants(dataset, hybrid_index):
    _, q, _ = dataset
    res = hybrid_index.search(q, k=10)
    cfg = hybrid_index.cfg
    assert (res.ios <= res.hops * cfg.io_batch).all()
    assert (res.ios + res.cache_hits >= res.hops).all()   # >=1 fresh page/hop
    assert (res.ios <= hybrid_index.store.num_pages).all()  # visited-set works


@pytest.fixture(scope="module", params=list(MemoryMode), ids=lambda m: m.value)
def mode_index(request, dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg(memory_mode=request.param))


def test_memory_modes_all_reach_recall(dataset, mode_index):
    _, q, truth = dataset
    res = mode_index.search(q, k=10)
    r = recall_at_k(res.ids, truth)
    assert r >= 0.8, (mode_index.cfg.memory_mode, r)


def test_optimized_loop_matches_seed_search(dataset, mode_index):
    """The fused page-scan + top-k hot path is a pure speedup: identical
    results, I/O counts, and hop counts to the frozen seed loop (argsort
    merges, serial select, split member/neighbor gathers) on every
    memory-disk coordination mode."""
    _, q, _ = dataset
    qj = jnp.asarray(q, jnp.float32)
    got = mode_index._raw_search(qj, mode_index.resolve_params(10, None))
    want = seed_search_ref.seed_batch_search(qj, mode_index, k=10)
    np.testing.assert_array_equal(np.asarray(got.ios), np.asarray(want.ios))
    np.testing.assert_array_equal(np.asarray(got.hops), np.asarray(want.hops))
    np.testing.assert_array_equal(
        np.asarray(got.cache_hits), np.asarray(want.cache_hits)
    )
    np.testing.assert_allclose(
        np.asarray(got.dists), np.asarray(want.dists), rtol=1e-6, atol=1e-6
    )
    # id sets match row-wise (ordering may differ only across exact ties)
    for i in range(len(q)):
        assert set(np.asarray(got.ids)[i].tolist()) == set(
            np.asarray(want.ids)[i].tolist()
        ), i


def test_mem_all_packs_more_vectors_per_page(dataset):
    x, _, _ = dataset
    disk = PageANNIndex.build(x, _cfg(memory_mode=MemoryMode.DISK_ONLY))
    mem = PageANNIndex.build(x, _cfg(memory_mode=MemoryMode.MEM_ALL))
    # Sec 4.3(3): freed page bytes -> more vectors per page -> fewer pages
    assert mem.store.capacity > disk.store.capacity
    assert mem.store.num_pages < disk.store.num_pages


def test_page_cache_reduces_counted_ios(dataset):
    x, q, truth = dataset
    idx = PageANNIndex.build(x, _cfg(cache_pages=32))
    before = idx.search(q, k=10)
    idx.warm_cache(q)
    after = idx.search(q, k=10)
    assert after.cache_hits.sum() > 0
    assert after.ios.mean() < before.ios.mean()
    # caching must not change results
    assert recall_at_k(after.ids, truth) >= recall_at_k(before.ids, truth) - 1e-9


def test_results_sorted_and_unique(dataset, hybrid_index):
    _, q, _ = dataset
    res = hybrid_index.search(q, k=10)
    for i in range(len(q)):
        d = res.dists[i]
        assert (np.diff(d[np.isfinite(d)]) >= -1e-6).all()
        ids = res.ids[i][res.ids[i] >= 0]
        assert len(np.unique(ids)) == len(ids)


def test_beam_width_trades_io_for_recall(dataset, hybrid_index):
    """Runtime knobs are per-call SearchParams: the whole beam sweep runs
    over ONE built index, and a point of that sweep is bit-identical to an
    index whose build config froze the same knobs."""
    x, q, truth = dataset
    lo = SearchParams(k=10, beam_width=16, lsh_entries=4, max_hops=48)
    hi = SearchParams(k=10, beam_width=96, lsh_entries=16, max_hops=48)
    res_lo = hybrid_index.search(q, params=lo)
    res_hi = hybrid_index.search(q, params=hi)
    assert recall_at_k(res_hi.ids, truth) >= recall_at_k(res_lo.ids, truth)
    assert res_hi.ios.mean() >= res_lo.ios.mean()

    # the config's knobs are only defaults for the same runtime path:
    # a config-frozen build must reproduce the per-call sweep point exactly
    frozen = PageANNIndex.build(x, _cfg(beam_width=16, lsh_entries=4))
    res_frozen = frozen.search(q, k=10)
    for field in res_lo._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res_lo, field)),
            np.asarray(getattr(res_frozen, field)),
            err_msg=field,
        )


def test_build_warmup_queries_populate_cache(dataset):
    """Sec 4.3 warm path: build(..., warmup_queries=...) with cache_pages>0
    must leave a populated page cache, and repeat queries must convert
    disk reads into cache hits without changing the read schedule."""
    import dataclasses as dc

    from repro.core import search as search_mod

    x, q, _ = dataset
    idx = PageANNIndex.build(x, _cfg(cache_pages=32), warmup_queries=q)
    cached = np.asarray(idx.tier.cached_pages)
    assert 0 < cached.size <= 32
    assert (np.diff(cached) > 0).all()          # sorted, unique page ids

    warm = idx.search(q, k=10)                  # repeat of the warmup batch
    assert warm.cache_hits.sum() > 0

    # against the same index with the cache emptied: hits come out of ios
    # one for one (the cache reclassifies reads, never reorders them)
    cold_tier = dc.replace(
        idx.tier, cached_pages=jnp.zeros((0,), jnp.int32)
    )
    cold_data = search_mod.make_search_data(idx.store, cold_tier, idx.lsh)
    cold = search_mod.batch_search(
        jnp.asarray(q, jnp.float32),
        cold_data,
        idx.resolve_params(10, None),
        capacity=idx.store.capacity,
        mode=idx.cfg.memory_mode.value,
    )
    assert np.asarray(cold.cache_hits).sum() == 0
    np.testing.assert_array_equal(
        np.asarray(warm.ios) + np.asarray(warm.cache_hits),
        np.asarray(cold.ios),
    )
    assert warm.ios.sum() < np.asarray(cold.ios).sum()


def _recall_reference_loop(found_ids, truth_ids):
    """The pre-vectorization recall_at_k: per-query python set intersection."""
    hits = 0
    q, k = truth_ids.shape
    for i in range(q):
        hits += len(set(found_ids[i].tolist()) & set(truth_ids[i].tolist()))
    return hits / (q * k)


def test_recall_at_k_matches_reference_loop():
    rng = np.random.default_rng(7)
    for _ in range(40):
        qn = int(rng.integers(1, 8))
        kt = int(rng.integers(1, 12))
        kf = int(rng.integers(1, 12))           # found width may differ
        found = rng.integers(-1, 15, (qn, kf))  # duplicates and PAD included
        truth = rng.integers(-1, 15, (qn, kt))
        assert recall_at_k(found, truth) == pytest.approx(
            _recall_reference_loop(found, truth), abs=1e-12
        )


def test_high_dim_vectors_span_multiple_record_rows():
    """dim > 128 packs each member vector over ceil(d/128) record rows —
    the fused hot path must handle standard embedding sizes end to end."""
    d = 160  # rpv = 2, and 160/8 PQ subspaces divides evenly
    x = clustered_vectors(600, d, num_clusters=8, seed=4)
    q = query_vectors(x, 8, seed=5)
    truth = brute_force_knn(x, q, 10)
    idx = PageANNIndex.build(
        x,
        PageANNConfig(
            dim=d, graph_degree=12, build_beam=24, pq_subspaces=8,
            lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
            memory_mode=MemoryMode.HYBRID,
        ),
    )
    res = idx.search(q, k=10)
    assert recall_at_k(res.ids, truth) >= 0.7


def test_layout_equation_capacity():
    cfg = _cfg(page_bytes=4096, pq_subspaces=8, page_degree=48)
    cap = cfg.resolve_capacity()
    # Sec 4.2 equation: (4096 - 8 - 48*4 - 24*8) / (32*4) for HYBRID
    assert cap == (4096 - 8 - 48 * 4 - 24 * 8) // (32 * 4)
