"""Index lifecycle: runtime SearchParams, on-disk persistence, and the
unified VectorIndex protocol (build → save → load → search)."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    IndexFormatError,
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    VectorIndex,
    load_index,
    recall_at_k,
)
from repro.core import baselines as bl
from repro.core import persist
from repro.core import pq as pq_mod
from repro.core.layout import pack_page_records, unpack_member_vectors
from repro.core.vamana import brute_force_knn, build_vamana
from repro.data.pipeline import clustered_vectors, query_vectors

N, D, Q = 1200, 32, 12


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module", params=list(MemoryMode), ids=lambda m: m.value)
def mode_index(request, dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg(memory_mode=request.param))


@pytest.fixture(scope="module")
def pageann_hybrid(dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg())


# ------------------------------------------------------------- persistence
def test_save_load_bit_identical_every_mode(tmp_path, dataset, mode_index):
    """The acceptance bar: save(dir) -> load(dir) -> search returns
    bit-identical ids/dists/ios/hops/cache_hits on every MemoryMode."""
    _, q, _ = dataset
    art = str(tmp_path / "idx.pageann")
    mode_index.save(art)
    loaded = PageANNIndex.load(art)
    assert loaded.cfg == mode_index.cfg
    # host-side views recovered from (or, for MEM_ALL codes, alongside)
    # the page file match the originals exactly
    np.testing.assert_array_equal(loaded.store.vecs, mode_index.store.vecs)
    np.testing.assert_array_equal(
        np.asarray(loaded.store.nbr_codes),
        np.asarray(mode_index.store.nbr_codes),
    )
    want = mode_index.search(q, k=10)
    got = loaded.search(q, k=10)
    for field in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, field)),
            np.asarray(getattr(got, field)),
            err_msg=field,
        )


def test_page_file_is_page_aligned_and_memmap_readable(tmp_path, pageann_hybrid):
    """pages.bin is the literal paper disk layout: raw page records, each a
    whole number of 4 KB pages, readable via np.memmap without the
    sidecars."""
    idx = pageann_hybrid
    art = str(tmp_path / "idx.pageann")
    idx.save(art)

    with open(os.path.join(art, "manifest.json")) as f:
        doc = json.load(f)
    rec_bytes = doc["page_record_bytes"]
    assert rec_bytes % 4096 == 0                       # page-aligned records
    path = os.path.join(art, "pages.bin")
    assert os.path.getsize(path) == doc["pages"] * rec_bytes

    mm = np.memmap(
        path, dtype=np.float32, mode="r",
        shape=(doc["pages"], doc["record_rows"], doc["record_lanes"]),
    )
    np.testing.assert_array_equal(np.asarray(mm), np.asarray(idx.store.recs))
    # host-side member vectors are recovered from the page file itself
    np.testing.assert_array_equal(
        unpack_member_vectors(mm, doc["capacity"], doc["dim"]),
        idx.store.vecs,
    )


def test_unpack_member_vectors_inverts_pack_high_dim():
    rng = np.random.default_rng(3)
    for d in (32, 100, 160, 300):
        cap = 5
        vecs = rng.standard_normal((4, cap, d)).astype(np.float32)
        codes = rng.integers(0, 256, (4, 7, 8)).astype(np.uint8)
        recs = pack_page_records(vecs, codes)
        np.testing.assert_array_equal(
            unpack_member_vectors(recs, cap, d), vecs
        )


def test_manifest_version_guard(tmp_path, pageann_hybrid):
    idx = pageann_hybrid
    art = str(tmp_path / "idx.pageann")
    idx.save(art)
    path = os.path.join(art, "manifest.json")
    with open(path) as f:
        doc = json.load(f)
    doc["version"] = 999
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="version"):
        PageANNIndex.load(art)


def test_version_ahead_names_found_vs_supported(tmp_path, pageann_hybrid):
    """A manifest written by a NEWER library raises IndexFormatError that
    states both versions and says to upgrade — not a cryptic KeyError."""
    art = str(tmp_path / "idx.pageann")
    pageann_hybrid.save(art)
    path = os.path.join(art, "manifest.json")
    with open(path) as f:
        doc = json.load(f)
    doc["version"] = persist.VERSION + 7
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(
        IndexFormatError,
        match=rf"found format version {persist.VERSION + 7}.*"
              rf"supports version {persist.VERSION}.*upgrade",
    ):
        load_index(art)


def test_truncated_pages_bin_raises_index_format_error(
    tmp_path, pageann_hybrid
):
    """A corrupted/truncated page file fails with a clear IndexFormatError
    naming the byte mismatch — not a numpy memmap/reshape error."""
    art = str(tmp_path / "idx.pageann")
    pageann_hybrid.save(art)
    path = os.path.join(art, "pages.bin")
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 4096)
    with pytest.raises(IndexFormatError, match="truncated"):
        PageANNIndex.load(art)
    os.remove(path)
    with pytest.raises(IndexFormatError, match="missing page file"):
        load_index(art)


def test_garbled_manifest_raises_index_format_error(tmp_path, pageann_hybrid):
    art = str(tmp_path / "idx.pageann")
    pageann_hybrid.save(art)
    with open(os.path.join(art, "manifest.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(IndexFormatError, match="JSON"):
        load_index(art)


def test_stats_disk_bytes_reports_persisted_artifact(tmp_path, pageann_hybrid):
    """stats on a loaded (memmap) index reports the artifact's actual
    on-disk size; a built index projects the same number from its page
    geometry — the two agree because save writes the records verbatim."""
    idx = pageann_hybrid
    art = str(tmp_path / "idx.pageann")
    idx.save(art)
    loaded = PageANNIndex.load(art)
    on_disk = os.path.getsize(os.path.join(art, "pages.bin"))
    assert loaded.stats.disk_bytes == on_disk
    assert idx.stats.disk_bytes == on_disk
    assert (
        loaded.stats.disk_bytes
        == loaded.store.num_pages * loaded.store.padded_tile_bytes()
    )


def test_warm_cache_persists_across_save_load(tmp_path, dataset):
    """Warm-cache persistence (ROADMAP): hot page ids ride the manifest on
    save and pre-populate cached_pages on load — a restarted server's
    ios/cache_hits match the warmed builder exactly."""
    x, q, _ = dataset
    idx = PageANNIndex.build(x, _cfg(cache_pages=16), warmup_queries=q)
    assert np.asarray(idx.tier.cached_pages).size > 0

    art = str(tmp_path / "idx.warm")
    idx.save(art)
    with open(os.path.join(art, "manifest.json")) as f:
        doc = json.load(f)
    np.testing.assert_array_equal(
        np.asarray(doc["hot_pages"], np.int32),
        np.asarray(idx.tier.cached_pages),
    )

    loaded = PageANNIndex.load(art)
    np.testing.assert_array_equal(
        np.asarray(loaded.tier.cached_pages), np.asarray(idx.tier.cached_pages)
    )
    warm = idx.search(q, k=10)
    reloaded = loaded.search(q, k=10)
    np.testing.assert_array_equal(
        np.asarray(reloaded.ios), np.asarray(warm.ios)
    )
    np.testing.assert_array_equal(
        np.asarray(reloaded.cache_hits), np.asarray(warm.cache_hits)
    )
    assert np.asarray(reloaded.cache_hits).sum() > 0   # actually warm


# ---------------------------------------------------------- SearchParams
def test_params_sweep_reuses_one_index(dataset, pageann_hybrid):
    """A recall-vs-beam sweep is per-call SearchParams over ONE build; the
    curve is monotone in I/O and matches per-point k overrides."""
    _, q, _ = dataset
    idx = pageann_hybrid
    ios = []
    for beam, entries in ((16, 4), (48, 8), (96, 12)):
        p = SearchParams(
            k=10, beam_width=beam, lsh_entries=entries, max_hops=48
        )
        ios.append(float(idx.search(q, params=p).ios.mean()))
    assert ios == sorted(ios)
    # k override rides on top of params without another dataclass
    p = SearchParams(k=10, beam_width=48, lsh_entries=8, max_hops=48)
    r5 = idx.search(q, k=5, params=p)
    assert r5.ids.shape == (Q, 5)


def test_search_params_validation():
    with pytest.raises(ValueError):
        SearchParams(k=0)
    # beam < lsh_entries is constructible (baselines never consult the LSH
    # router) — the PageANN search path enforces it at call time
    SearchParams(beam_width=8, lsh_entries=16)
    # hashable == usable as a static jit arg / dict key
    assert hash(SearchParams()) == hash(SearchParams())


def test_pageann_rejects_beam_below_lsh_entries(dataset, pageann_hybrid):
    _, q, _ = dataset
    idx = pageann_hybrid
    with pytest.raises(ValueError, match="lsh_entries"):
        idx.search(q, params=SearchParams(beam_width=8, lsh_entries=16))


def test_baselines_accept_low_beam(dataset, baseline_parts):
    x, q, _ = dataset
    nbrs, books = baseline_parts
    idx = bl.DiskANNIndex.from_data(x, nbrs, books)
    res = idx.search(q, params=SearchParams(k=5, beam_width=8, max_hops=48))
    assert res.ids.shape == (Q, 5)


# -------------------------------------------------------------- protocol
@pytest.fixture(scope="module")
def baseline_parts(dataset):
    x, _, _ = dataset
    nbrs = build_vamana(x, degree=12, beam=24, seed=0)
    books = np.asarray(pq_mod.train_pq(x, 8, 256, 6))
    return nbrs, books


def test_all_systems_implement_vector_index(dataset, baseline_parts, pageann_hybrid):
    x, _, _ = dataset
    nbrs, books = baseline_parts
    systems = [
        pageann_hybrid,
        bl.DiskANNIndex.from_data(x, nbrs, books),
        bl.StarlingIndex.build(x, _cfg()),
    ]
    for idx in systems:
        assert isinstance(idx, VectorIndex), type(idx)
        assert idx.dim == D


def test_baselines_search_through_protocol(dataset, baseline_parts):
    """Both baselines speak search(queries, k, params) and agree with the
    raw functional entry points they wrap."""
    x, q, truth = dataset
    nbrs, books = baseline_parts
    idx = bl.DiskANNIndex.from_data(x, nbrs, books)
    params = SearchParams(k=10, beam_width=64, max_hops=48)
    res = idx.search(q, params=params)
    assert recall_at_k(res.ids, truth) >= 0.8
    assert (res.cache_hits == 0).all()
    raw = bl.diskann_search(
        np.asarray(q, np.float32), idx.data, beam=64, k=10, max_hops=48
    )
    np.testing.assert_array_equal(res.ids, np.asarray(raw.ids))
    np.testing.assert_array_equal(res.ios, np.asarray(raw.ios))


def test_baseline_save_load_round_trip(tmp_path, dataset, baseline_parts):
    x, q, _ = dataset
    nbrs, books = baseline_parts
    idx = bl.StarlingIndex.build(x, _cfg())
    art = str(tmp_path / "idx.starling")
    idx.save(art)
    loaded = load_index(art)                    # kind-dispatched reload
    assert type(loaded) is bl.StarlingIndex
    params = SearchParams(k=10, beam_width=48, max_hops=48)
    want = idx.search(q, params=params)
    got = loaded.search(q, params=params)
    for field in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, field)),
            np.asarray(getattr(got, field)),
            err_msg=field,
        )


def test_load_index_dispatches_pageann(tmp_path, dataset, pageann_hybrid):
    _, q, _ = dataset
    idx = pageann_hybrid
    art = str(tmp_path / "idx.pageann")
    idx.save(art)
    loaded = load_index(art)
    assert type(loaded) is PageANNIndex
    np.testing.assert_array_equal(
        loaded.search(q, k=5).ids, idx.search(q, k=5).ids
    )


def test_load_rejects_non_index_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_index(str(tmp_path))
    assert not persist.is_index_dir(str(tmp_path))
