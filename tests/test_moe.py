"""MoE dispatch correctness vs a dense per-token mixture reference."""
import jax
import jax.numpy as jnp
import numpy as np
import dataclasses

from repro.configs.registry import get_arch
from repro.models import moe as moe_mod


def dense_moe_reference(params, x, cfg):
    """No-capacity reference: every token reaches its top-k experts."""
    b, t, d = x.shape
    xt = np.asarray(x.reshape(b * t, d), np.float64)
    logits = xt @ np.asarray(params["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    out = np.zeros_like(xt)
    for i in range(len(xt)):
        top = np.argsort(-probs[i])[:k]
        w = probs[i, top]
        w = w / w.sum()
        for e, we in zip(top, w):
            wg = np.asarray(params["we_gate"][e], np.float64)
            wu = np.asarray(params["we_up"][e], np.float64)
            wd = np.asarray(params["we_down"][e], np.float64)
            hpre = xt[i] @ wg
            h = hpre / (1 + np.exp(-hpre)) * (xt[i] @ wu)
            out[i] += we * (h @ wd)
    return out.reshape(b, t, d)


def _ample_cfg():
    cfg = get_arch("arctic-480b", smoke=True)
    # capacity factor large enough that nothing is dropped
    return dataclasses.replace(cfg, moe_capacity_factor=8.0)


def test_moe_matches_dense_reference_when_no_drops():
    cfg = _ample_cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out = moe_mod.moe_layer(params, x, cfg)
    want = dense_moe_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-3, rtol=1e-2)


def test_moe_capacity_drops_are_bounded():
    cfg = get_arch("arctic-480b", smoke=True)  # capacity factor 1.25
    params = moe_mod.init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    out = moe_mod.moe_layer(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens produce zero output rows, never NaNs
    want = dense_moe_reference(params, x, cfg)
    # most tokens should still match the reference
    close = np.isclose(np.asarray(out), want, atol=1e-3, rtol=1e-2).all(-1)
    assert close.mean() > 0.5


def test_moe_grad_finite():
    cfg = _ample_cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), jnp.float32)

    def f(p):
        return moe_mod.moe_layer(p, x, cfg).sum()

    g = jax.grad(f)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_aux_loss_uniform_router_is_one():
    cfg = _ample_cfg()
    params = moe_mod.init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), jnp.float32)
    aux = moe_mod.moe_aux_loss(params, x, cfg)
    # uniform probs: E * sum(f_i * 1/E) = 1 regardless of argmax distribution
    assert 0.9 < float(aux) < 1.6
