"""Scale-out contracts: ShardedPageStore recall parity with the
unsharded index on every MemoryMode, per-shard bit-identical persist
round-trips under one sharded manifest, global-id translation, and the
per-shard search-parameter scaling rule."""
import os

import numpy as np
import pytest

from repro.core import (
    IndexFormatError,
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
)
from repro.core import persist
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.dist import ShardedPageStore, shard_params_for
from repro.dist.sharded import SHARDS_NPZ

N, D, K = 600, 32, 10


def _cfg(**kw) -> PageANNConfig:
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def corpus():
    return clustered_vectors(N, D, num_clusters=16, seed=0)


@pytest.fixture(scope="module")
def queries(corpus):
    return query_vectors(corpus, 12, seed=3)


@pytest.fixture(scope="module")
def truth(corpus, queries):
    return brute_force_knn(corpus, queries, K)


def _recall(ids, truth):
    hits = sum(
        len(set(map(int, r)) & set(map(int, t)))
        for r, t in zip(ids, truth)
    )
    return hits / truth.size


@pytest.fixture(scope="module")
def hybrid_store(corpus):
    return ShardedPageStore.build(corpus, _cfg(), num_shards=2)


# -------------------------------------------------------- recall parity
@pytest.mark.parametrize("mode", list(MemoryMode))
def test_recall_parity_with_unsharded_all_modes(corpus, queries, truth, mode):
    cfg = _cfg(memory_mode=mode)
    base = PageANNIndex.build(corpus, cfg)
    store = ShardedPageStore.build(corpus, cfg, num_shards=2)
    r_base = _recall(np.asarray(base.search(queries, k=K).ids), truth)
    r_shard = _recall(np.asarray(store.search(queries, k=K).ids), truth)
    assert r_shard >= r_base - 0.02, (mode, r_shard, r_base)


def test_recall_parity_four_shards(corpus, queries, truth):
    cfg = _cfg()
    base = PageANNIndex.build(corpus, cfg)
    store = ShardedPageStore.build(corpus, cfg, num_shards=4)
    r_base = _recall(np.asarray(base.search(queries, k=K).ids), truth)
    r_shard = _recall(np.asarray(store.search(queries, k=K).ids), truth)
    assert r_shard >= r_base - 0.02, (r_shard, r_base)


# ------------------------------------------------------ global-id space
def test_search_returns_global_ids(corpus, hybrid_store):
    # corpus rows as queries: the nearest neighbor of x[i] is i itself,
    # which only holds if per-shard local ids were translated correctly
    res = hybrid_store.search(corpus[:16], k=K)
    ids = np.asarray(res.ids)
    valid = ids[ids >= 0]
    assert valid.size and valid.max() < N
    assert (ids[:, 0] == np.arange(16)).mean() >= 0.9
    # no duplicate global ids within a row
    for row in ids:
        row = row[row >= 0]
        assert len(set(row.tolist())) == len(row)


def test_partitions_cover_corpus_disjointly(hybrid_store):
    parts = [np.asarray(p) for p in hybrid_store.parts]
    cat = np.concatenate(parts)
    assert len(cat) == N
    assert np.array_equal(np.sort(cat), np.arange(N))


# ------------------------------------------------------------ persistence
def test_persist_round_trip_bit_identical(tmp_path, hybrid_store, queries):
    d = str(tmp_path / "db")
    hybrid_store.save(d)
    # layout: one sharded manifest over per-shard sub-artifacts
    assert os.path.isfile(os.path.join(d, SHARDS_NPZ))
    for i in range(2):
        sub = os.path.join(d, f"shard-{i}")
        assert os.path.isdir(sub), sub
    man = persist.read_manifest(d)
    assert man["kind"] == "sharded" and man["num_shards"] == 2

    loaded = persist.load_index(d)
    assert isinstance(loaded, ShardedPageStore)
    assert loaded.num_shards == 2
    for p_a, p_b in zip(hybrid_store.parts, loaded.parts):
        assert np.array_equal(np.asarray(p_a), np.asarray(p_b))
    # per-shard searches are bit-identical, not merely recall-equal
    for sub_a, sub_b in zip(hybrid_store.shards, loaded.shards):
        ra = sub_a.search(queries, k=K)
        rb = sub_b.search(queries, k=K)
        assert np.array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
        assert np.array_equal(np.asarray(ra.dists), np.asarray(rb.dists))
    # and so is the merged result
    ra = hybrid_store.search(queries, k=K)
    rb = loaded.search(queries, k=K)
    assert np.array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    assert np.array_equal(np.asarray(ra.dists), np.asarray(rb.dists))


def test_load_rejects_non_sharded_artifact(tmp_path, corpus):
    idx = PageANNIndex.build(corpus[:200], _cfg())
    d = str(tmp_path / "plain")
    idx.save(d)
    with pytest.raises(IndexFormatError):
        ShardedPageStore.load(d)


# ------------------------------------------------- per-shard search rule
def test_shard_params_scaling_rule():
    base = SearchParams(k=K, beam_width=64, max_hops=64, io_batch=8,
                        lsh_entries=12)
    for s in (2, 4, 8):
        p = shard_params_for(base, s)
        assert p.k == base.k
        # beam shrinks with shard count but never below what top-k
        # merging and entry seeding need
        assert p.beam_width >= max(base.k, base.lsh_entries)
        assert p.beam_width <= base.beam_width
        assert p.io_batch <= 3
        assert p.max_hops >= 16
    # more shards never means more per-shard work
    assert (shard_params_for(base, 4).beam_width
            <= shard_params_for(base, 2).beam_width)
