"""DiskANN/Starling baselines + sharded-index search (subprocess for the
multi-device mesh)."""
import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MemoryMode, PageANNConfig, recall_at_k
from repro.core import baselines as bl
from repro.core import pq as pq_mod
from repro.core.vamana import brute_force_knn, build_vamana
from repro.data.pipeline import clustered_vectors, query_vectors


@pytest.fixture(scope="module")
def setup():
    x = clustered_vectors(2000, 32, num_clusters=32, seed=0)
    q = query_vectors(x, 20, seed=1)
    truth = brute_force_knn(x, q, 10)
    nbrs = build_vamana(x, degree=16, beam=32, seed=0)
    books = pq_mod.train_pq(x, 8, 256, 8)
    return x, q, truth, nbrs, np.asarray(books)


def test_diskann_baseline_recall(setup):
    x, q, truth, nbrs, books = setup
    data = bl.make_baseline_data(x, nbrs, books)
    res = bl.diskann_search(jnp.asarray(q), data, beam=64, k=10, max_hops=64)
    assert recall_at_k(np.asarray(res.ids), truth) >= 0.85


def test_starling_layout_reduces_ios(setup):
    """Starling-style co-located layout must read fewer unique pages than
    DiskANN's per-node reads at the same traversal (paper Table 1)."""
    x, q, truth, nbrs, books = setup
    from repro.core.page_graph import group_pages

    g = group_pages(x, nbrs, capacity=8, h=2)
    data_id = bl.make_baseline_data(x, nbrs, books, vectors_per_page=8)
    data_star = bl.make_baseline_data(x, nbrs, books, page_of=g.page_of)
    r1 = bl.diskann_search(jnp.asarray(q), data_id, beam=64, k=10, max_hops=64)
    r2 = bl.starling_search(jnp.asarray(q), data_star, beam=64, k=10, max_hops=64)
    assert recall_at_k(np.asarray(r2.ids), truth) >= 0.8
    assert np.asarray(r2.ios).mean() < np.asarray(r1.ios).mean()


_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import MemoryMode, PageANNConfig, recall_at_k
from repro.core import compat
from repro.core import distributed as dist
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

x = clustered_vectors(1200, 32, num_clusters=16, seed=0)
q = query_vectors(x, 8, seed=1)
truth = brute_force_knn(x, q, 10)
cfg = PageANNConfig(dim=32, graph_degree=12, build_beam=24, pq_subspaces=8,
                    lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48)
sh = dist.build_sharded_index(x, cfg, num_shards=2)
mesh = compat.make_mesh((2, 2), ("data", "model"))
fn, _ = dist.make_sharded_search(mesh, cfg, sh.capacity, k=10)
with mesh:
    ids, tag, d, ios = fn(sh.data, jnp.asarray(q))
old = dist.translate_ids(sh, np.asarray(ids), np.asarray(tag))
print(json.dumps({"recall": recall_at_k(old, truth),
                  "ios": float(np.asarray(ios).mean())}))
"""


def test_sharded_search_on_multidevice_mesh():
    out = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["recall"] >= 0.8, rec
    assert rec["ios"] > 0


_RAGGED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import MemoryMode, PageANNConfig, recall_at_k
from repro.core import compat
from repro.core import distributed as dist
from repro.core.config import SearchParams
from repro.core.search import PAD
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

# 130 vectors over 4 shards -> ragged 33/33/32/32 partition, every shard
# padded to the largest shard's page count; k=64 exceeds the smallest
# shard's pool so each shard MUST emit PAD tails into the merge.
x = clustered_vectors(130, 16, num_clusters=8, seed=0)
q = query_vectors(x, 8, seed=1)
k = 64
truth = brute_force_knn(x, q, 10)
cfg = PageANNConfig(dim=16, graph_degree=8, build_beam=16, pq_subspaces=4,
                    lsh_sample=64, lsh_entries=4, beam_width=64, max_hops=32,
                    memory_mode=MemoryMode.HYBRID)
sh = dist.build_sharded_index(x, cfg, num_shards=4)
mesh = compat.make_mesh((4, 1), ("data", "model"))
params = SearchParams(k=k, beam_width=64, io_batch=4, max_hops=32,
                      lsh_entries=4)
fn, _ = dist.make_sharded_search(mesh, cfg, sh.capacity, k=k, params=params)
with mesh:
    ids, tag, d, ios = fn(sh.data, jnp.asarray(q))
ids = np.asarray(ids)
d = np.asarray(d)
old = dist.translate_ids(sh, ids, np.asarray(tag))

pad = old == PAD
# invariant 1: a merged PAD never carries a finite distance
finite_pad = int((pad & np.isfinite(d)).sum())
# invariant 2: no shard-local id survives the merge pointing at a pad slot
surfaced = int(((ids >= 0) & pad).sum())
# invariant 3: PAD only ever trails real candidates (never displaces one)
interleaved = 0
for row in old:
    seen_pad = False
    for v in row:
        if v == PAD:
            seen_pad = True
        elif seen_pad:
            interleaved += 1
# invariant 4: every real id is a valid global id
in_range = bool(((old >= 0) | pad).all() and (old < len(x)).all())
print(json.dumps({
    "recall": recall_at_k(old[:, :10], truth),
    "finite_pad": finite_pad,
    "surfaced_padslots": surfaced,
    "interleaved": interleaved,
    "in_range": in_range,
}))
"""


def test_sharded_search_ragged_partitions_never_surface_pad():
    """Non-divisible shard sizes pad every shard to the largest; pad slots
    and pad pages must never rank in the merged top-k (satellite: pad-shard
    handling in ``pad_pages``/``translate_ids``)."""
    out = subprocess.run(
        [sys.executable, "-c", _RAGGED_SCRIPT],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["finite_pad"] == 0, rec
    assert rec["surfaced_padslots"] == 0, rec
    assert rec["interleaved"] == 0, rec
    assert rec["in_range"], rec
    assert rec["recall"] >= 0.9, rec
