"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.hamming import hamming
from repro.kernels.l2dist import l2_distance
from repro.kernels.page_gather import page_gather_l2
from repro.kernels.pq_adc import pq_adc

SET = dict(max_examples=12, deadline=None)


@settings(**SET)
@given(
    bq=st.integers(1, 70),
    nx=st.integers(1, 300),
    d=st.sampled_from([8, 32, 96, 128]),
    dtype=st.sampled_from([np.float32, np.float16]),
)
def test_l2_distance_matches_ref(bq, nx, d, dtype):
    rng = np.random.default_rng(bq * 1000 + nx)
    q = jnp.asarray(rng.standard_normal((bq, d)).astype(dtype))
    x = jnp.asarray(rng.standard_normal((nx, d)).astype(dtype))
    out = l2_distance(q, x, interpret=True)
    want = ref.l2_distance_ref(q, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-2, rtol=2e-2)


@settings(**SET)
@given(
    n=st.integers(1, 600),
    m=st.sampled_from([4, 8, 16]),
    k=st.sampled_from([16, 256]),
)
def test_pq_adc_matches_ref(n, m, k):
    rng = np.random.default_rng(n)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    lut = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    out = pq_adc(codes, lut, interpret=True)
    want = ref.pq_adc_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(s=st.integers(1, 700), w=st.sampled_from([1, 2, 4]))
def test_hamming_matches_ref(s, w):
    rng = np.random.default_rng(s)
    codes = jnp.asarray(
        rng.integers(0, 2**32, (s, w), dtype=np.uint64).astype(np.uint32)
    )
    qc = jnp.asarray(rng.integers(0, 2**32, (w,), dtype=np.uint64).astype(np.uint32))
    out = hamming(codes, qc, interpret=True)
    want = ref.hamming_ref(codes, qc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_hamming_zero_distance_to_self():
    codes = jnp.asarray(np.arange(8, dtype=np.uint32).reshape(4, 2))
    out = hamming(codes, codes[2], interpret=True)
    assert int(np.asarray(out)[2]) == 0


@settings(**SET)
@given(
    p=st.integers(2, 40),
    cap=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([16, 64]),
    b=st.integers(1, 12),
)
def test_page_gather_l2_matches_ref(p, cap, d, b):
    rng = np.random.default_rng(p * 7 + b)
    pages = jnp.asarray(rng.standard_normal((p, cap, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, p, (b,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    out = page_gather_l2(pages, ids, q, interpret=True)
    want = ref.page_gather_l2_ref(pages, ids, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_dispatch_to_ref_on_cpu():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((9, 16)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.l2_distance(q, x)),
        np.asarray(ref.l2_distance_ref(q, x)),
        rtol=1e-5,
    )
