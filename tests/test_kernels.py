"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle.

Seeded sweeps always run; the hypothesis shape/dtype property sweeps ride
along when hypothesis is installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layout import pack_page_records
from repro.kernels import ref
from repro.kernels.hamming import hamming
from repro.kernels.l2dist import l2_distance
from repro.kernels.page_gather import page_gather_l2
from repro.kernels.page_scan import page_scan
from repro.kernels.pq_adc import pq_adc

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SET = dict(max_examples=12, deadline=None)


# ------------------------------------------------------------- page_scan
def _random_page_record(rng, p, cap, d, rp, m):
    """Random page arrays + their packed (P, rows, 128) record."""
    vecs = rng.standard_normal((p, cap, d)).astype(np.float32)
    codes = rng.integers(0, 256, (p, rp, m)).astype(np.uint8)
    recs = pack_page_records(vecs, codes)
    return vecs, codes, recs


@pytest.mark.parametrize(
    "p,cap,d,rp,m,b",
    [
        (7, 4, 16, 12, 4, 3),
        (23, 28, 32, 48, 8, 5),    # the serve-benchmark geometry
        (11, 5, 128, 48, 16, 8),   # d == full lane width
        (3, 1, 8, 1, 4, 1),
        (5, 3, 200, 12, 4, 4),     # d > 128: vectors span 2 record rows
        (4, 6, 384, 16, 8, 2),     # sentence-transformer-sized embeddings
    ],
)
def test_page_scan_matches_ref_and_semantics(p, cap, d, rp, m, b):
    """Pallas fused kernel (interpret) == jnp oracle == the unfused pair of
    semantic ground truths it replaced (member L2 + neighbor ADC)."""
    rng = np.random.default_rng(p * 100 + cap)
    vecs, codes, recs = _random_page_record(rng, p, cap, d, rp, m)
    ids = jnp.asarray(rng.integers(0, p, (b,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    lut = jnp.asarray(rng.standard_normal((m, 256)), jnp.float32)
    recs_j = jnp.asarray(recs)

    md_k, nd_k = page_scan(
        recs_j, ids, q, lut, capacity=cap, dim=d, rp=rp, interpret=True
    )
    md_r, nd_r = ref.page_scan_ref(
        recs_j, ids, q, lut, capacity=cap, dim=d, rp=rp
    )
    np.testing.assert_allclose(np.asarray(md_k), np.asarray(md_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nd_k), np.asarray(nd_r), rtol=1e-4, atol=1e-4)

    # ground truth from the unfused seed path
    md_t = ref.page_gather_l2_ref(jnp.asarray(vecs), ids, q)
    flat = jnp.asarray(codes)[ids].reshape(-1, m)
    nd_t = ref.pq_adc_ref(flat, lut).reshape(b, rp)
    np.testing.assert_allclose(np.asarray(md_k), np.asarray(md_t), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(nd_k), np.asarray(nd_t), rtol=1e-4, atol=1e-4)


def test_page_scan_members_only_skips_adc():
    """compute_adc=False (MEM_ALL: codes live in the memory tier) returns
    member distances only, identical to the full kernel's member output."""
    rng = np.random.default_rng(5)
    _, _, recs = _random_page_record(rng, 9, 6, 24, 10, 8)
    recs = jnp.asarray(recs)
    ids = jnp.asarray(rng.integers(0, 9, (4,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((24,)), jnp.float32)
    lut = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    md_full, _ = page_scan(
        recs, ids, q, lut, capacity=6, dim=24, rp=10, interpret=True
    )
    md_only, nd = page_scan(
        recs, ids, q, lut, capacity=6, dim=24, rp=10,
        compute_adc=False, interpret=True,
    )
    assert nd is None
    np.testing.assert_allclose(np.asarray(md_only), np.asarray(md_full), rtol=1e-5)
    md_ref, nd_ref = ref.page_scan_ref(
        recs, ids, q, lut, capacity=6, dim=24, rp=10, compute_adc=False
    )
    assert nd_ref is None
    np.testing.assert_allclose(np.asarray(md_only), np.asarray(md_ref), rtol=1e-5)


# ---------------------------------------------------- seeded kernel sweeps
def test_l2_distance_matches_ref_seeded():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((37, 32)), jnp.float32)
    out = l2_distance(q, x, interpret=True)
    want = ref.l2_distance_ref(q, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-2, rtol=2e-2)


def test_pq_adc_matches_ref_seeded():
    rng = np.random.default_rng(2)
    codes = jnp.asarray(rng.integers(0, 256, (130, 8)), jnp.uint8)
    lut = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    out = pq_adc(codes, lut, interpret=True)
    want = ref.pq_adc_ref(codes, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_hamming_zero_distance_to_self():
    codes = jnp.asarray(np.arange(8, dtype=np.uint32).reshape(4, 2))
    out = hamming(codes, codes[2], interpret=True)
    assert int(np.asarray(out)[2]) == 0


def test_page_gather_l2_matches_ref_seeded():
    rng = np.random.default_rng(3)
    pages = jnp.asarray(rng.standard_normal((13, 8, 16)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 13, (6,)), jnp.int32)
    q = jnp.asarray(rng.standard_normal((16,)), jnp.float32)
    out = page_gather_l2(pages, ids, q, interpret=True)
    want = ref.page_gather_l2_ref(pages, ids, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_dispatch_to_ref_on_cpu():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((9, 16)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.l2_distance(q, x)),
        np.asarray(ref.l2_distance_ref(q, x)),
        rtol=1e-5,
    )


def test_delta_scan_kernel_path_parity_and_masking():
    """ops.delta_scan (the mutable index's fresh-tier scan) agrees between
    the pallas l2 kernel path (interpret) and the jnp oracle path, and
    never returns a dead row while a live one remains."""
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((3, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    live = jnp.asarray(rng.random(64) > 0.4)
    d_ref, s_ref = ops.delta_scan(q, v, live, 6, impl="ref")
    d_pal, s_pal = ops.delta_scan(q, v, live, 6, impl="pallas", interpret=True)
    np.testing.assert_allclose(
        np.asarray(d_ref), np.asarray(d_pal), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))
    live_np = np.asarray(live)
    finite = np.isfinite(np.asarray(d_ref))
    assert finite.sum(1).min() == min(6, live_np.sum())
    assert live_np[np.asarray(s_ref)[finite]].all()


# -------------------------------------------------- hypothesis properties
if HAVE_HYPOTHESIS:

    @settings(**SET)
    @given(
        bq=st.integers(1, 70),
        nx=st.integers(1, 300),
        d=st.sampled_from([8, 32, 96, 128]),
        dtype=st.sampled_from([np.float32, np.float16]),
    )
    def test_l2_distance_matches_ref(bq, nx, d, dtype):
        rng = np.random.default_rng(bq * 1000 + nx)
        q = jnp.asarray(rng.standard_normal((bq, d)).astype(dtype))
        x = jnp.asarray(rng.standard_normal((nx, d)).astype(dtype))
        out = l2_distance(q, x, interpret=True)
        want = ref.l2_distance_ref(q, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), atol=2e-2, rtol=2e-2
        )

    @settings(**SET)
    @given(
        n=st.integers(1, 600),
        m=st.sampled_from([4, 8, 16]),
        k=st.sampled_from([16, 256]),
    )
    def test_pq_adc_matches_ref(n, m, k):
        rng = np.random.default_rng(n)
        codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
        lut = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        out = pq_adc(codes, lut, interpret=True)
        want = ref.pq_adc_ref(codes, lut)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    @settings(**SET)
    @given(s=st.integers(1, 700), w=st.sampled_from([1, 2, 4]))
    def test_hamming_matches_ref(s, w):
        rng = np.random.default_rng(s)
        codes = jnp.asarray(
            rng.integers(0, 2**32, (s, w), dtype=np.uint64).astype(np.uint32)
        )
        qc = jnp.asarray(
            rng.integers(0, 2**32, (w,), dtype=np.uint64).astype(np.uint32)
        )
        out = hamming(codes, qc, interpret=True)
        want = ref.hamming_ref(codes, qc)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    @settings(**SET)
    @given(
        p=st.integers(2, 40),
        cap=st.sampled_from([4, 8, 16]),
        d=st.sampled_from([16, 64]),
        b=st.integers(1, 12),
    )
    def test_page_gather_l2_matches_ref(p, cap, d, b):
        rng = np.random.default_rng(p * 7 + b)
        pages = jnp.asarray(rng.standard_normal((p, cap, d)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, p, (b,)), jnp.int32)
        q = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
        out = page_gather_l2(pages, ids, q, interpret=True)
        want = ref.page_gather_l2_ref(pages, ids, q)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    @settings(**SET)
    @given(
        p=st.integers(2, 30),
        cap=st.sampled_from([1, 5, 28]),
        d=st.sampled_from([16, 32, 128]),
        rp=st.sampled_from([4, 48]),
        m=st.sampled_from([4, 8, 16]),
        b=st.integers(1, 10),
    )
    def test_page_scan_matches_ref_property(p, cap, d, rp, m, b):
        rng = np.random.default_rng(p * 31 + cap * 7 + b)
        _, _, recs = _random_page_record(rng, p, cap, d, rp, m)
        ids = jnp.asarray(rng.integers(0, p, (b,)), jnp.int32)
        q = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
        lut = jnp.asarray(rng.standard_normal((m, 256)), jnp.float32)
        recs = jnp.asarray(recs)
        md_k, nd_k = page_scan(
            recs, ids, q, lut, capacity=cap, dim=d, rp=rp, interpret=True
        )
        md_r, nd_r = ref.page_scan_ref(
            recs, ids, q, lut, capacity=cap, dim=d, rp=rp
        )
        np.testing.assert_allclose(
            np.asarray(md_k), np.asarray(md_r), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(nd_k), np.asarray(nd_r), rtol=1e-4, atol=1e-4
        )
