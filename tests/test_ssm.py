"""SSD chunked scan vs naive sequential recurrence; decode-step consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_arch
from repro.models import ssm as ssm_mod


def naive_ssd(x, dt, A, B, C):
    """Sequential reference: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    b, T, h, p = x.shape
    n = B.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros_like(np.asarray(x), dtype=np.float64)
    x, dt, A, B, C = map(np.asarray, (x, dt, A, B, C))
    for t in range(T):
        decay = np.exp(dt[:, t] * A[None, :])           # (b, h)
        contrib = np.einsum("bh,bn,bhp->bhpn", dt[:, t], B[:, t], x[:, t])
        state = state * decay[:, :, None, None] + contrib
        ys[:, t] = np.einsum("bn,bhpn->bhp", C[:, t], state)
    return ys, state


@settings(max_examples=8, deadline=None)
@given(
    T=st.sampled_from([7, 16, 33]),
    chunk=st.sampled_from([4, 8]),
    h=st.sampled_from([1, 2]),
)
def test_ssd_chunked_matches_sequential(T, chunk, h):
    rng = np.random.default_rng(T * 10 + chunk)
    b, p, n = 2, 4, 8
    x = jnp.asarray(rng.standard_normal((b, T, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, T, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, T, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, T, n)), jnp.float32)
    y, state = ssm_mod.ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, state_ref = naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), state_ref, atol=1e-3, rtol=1e-3)


def test_ssm_decode_matches_forward():
    """Prefill T tokens via chunked scan == T single decode steps."""
    cfg = get_arch("mamba2-370m", smoke=True)
    key = jax.random.PRNGKey(0)
    params = ssm_mod.init_ssm(key, cfg, jnp.float32)
    rng = np.random.default_rng(0)
    B, T = 2, 12
    u = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)), jnp.float32)

    full, _ = ssm_mod.ssm_forward(params, u, cfg)

    state = {
        "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state)),
        "ssd": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)),
    }
    outs = []
    for t in range(T):
        o, state = ssm_mod.ssm_decode_step(params, u[:, t], cfg, state)
        outs.append(o)
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=2e-3, rtol=2e-3)
