"""Vamana + page-graph construction invariants (Algorithm 1)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import page_graph as pg
from repro.core import vamana
from repro.core.layout import reassign_ids


@pytest.fixture(scope="module")
def small_graph():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((400, 16)).astype(np.float32)
    nbrs = vamana.build_vamana(x, degree=12, beam=24, seed=0)
    return x, nbrs


def test_vamana_degree_and_no_self_loops(small_graph):
    x, nbrs = small_graph
    assert nbrs.shape == (400, 12)
    for i in range(len(nbrs)):
        row = nbrs[i][nbrs[i] != pg.PAD]
        assert i not in row
        assert len(np.unique(row)) == len(row)


def test_vamana_greedy_search_recall(small_graph):
    x, nbrs = small_graph
    rng = np.random.default_rng(1)
    q = x[rng.integers(0, 400, 20)] + 0.01 * rng.standard_normal((20, 16)).astype(np.float32)
    import jax.numpy as jnp

    ids, d = vamana._greedy_search_batch(
        jnp.asarray(x), jnp.asarray(nbrs), jnp.asarray(q),
        vamana.medoid(x), beam=32, iters=24,
    )
    truth = vamana.brute_force_knn(x, q, 10)
    hits = 0
    for i in range(20):
        found = set(np.asarray(ids[i]).tolist())
        hits += len(found & set(truth[i].tolist()))
    assert hits / (20 * 10) > 0.8


def test_grouping_partitions_all_vectors(small_graph):
    x, nbrs = small_graph
    g = pg.group_pages(x, nbrs, capacity=8, h=2)
    flat = g.pages[g.pages != pg.PAD]
    assert len(flat) == 400
    assert len(np.unique(flat)) == 400          # exactly-once cover
    assert (g.page_of >= 0).all()
    for v in range(400):
        assert g.pages[g.page_of[v], g.slot_of[v]] == v


def test_page_edges_external_and_deduped(small_graph):
    x, nbrs = small_graph
    g = pg.group_pages(x, nbrs, capacity=8, h=2)
    edges = pg.derive_page_edges(x, nbrs, g, page_degree=16)
    for pid in range(len(edges)):
        row = edges[pid][edges[pid] != pg.PAD]
        assert len(np.unique(row)) == len(row)   # merged duplicates
        assert (g.page_of[row] != pid).all()     # intra-page edges removed


def test_reassignment_bijective(small_graph):
    x, nbrs = small_graph
    g = pg.group_pages(x, nbrs, capacity=8, h=2)
    new_to_old, old_to_new = reassign_ids(g)
    valid = new_to_old != pg.PAD
    assert valid.sum() == 400
    assert (old_to_new[new_to_old[valid]] == np.nonzero(valid)[0]).all()
    # page id arithmetic: new_id // capacity == page_of[old_id]
    new_ids = old_to_new[np.arange(400)]
    assert (new_ids // 8 == g.page_of).all()


@settings(max_examples=6, deadline=None)
@given(capacity=st.sampled_from([4, 8, 16]), h=st.sampled_from([1, 2, 3]))
def test_grouping_capacity_respected(capacity, h):
    rng = np.random.default_rng(capacity * h)
    x = rng.standard_normal((100, 8)).astype(np.float32)
    nbrs = vamana.build_vamana(x, degree=8, beam=16, rounds=1, seed=0)
    g = pg.group_pages(x, nbrs, capacity=capacity, h=h)
    assert ((g.pages != pg.PAD).sum(1) <= capacity).all()
    assert g.pages.shape[0] == -(-100 // capacity) or g.pages.shape[0] >= 100 // capacity
