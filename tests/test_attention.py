"""Blockwise (flash-style) attention vs naive reference, GQA/causal/window,
plus decode-attention consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.models.layers import blockwise_attention, decode_attention


def naive_attention(q, k, v, causal, window=0, q_offset=0):
    B, Tq, H, hd = q.shape
    Tk, KvH = k.shape[1], k.shape[2]
    G = H // KvH
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    qpos = q_offset + np.arange(Tq)[:, None]
    kpos = np.arange(Tk)[None, :]
    mask = np.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@settings(max_examples=10, deadline=None)
@given(
    tq=st.sampled_from([8, 33, 64]),
    h=st.sampled_from([2, 4]),
    kvh=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 7]),
)
def test_blockwise_matches_naive(tq, h, kvh, causal, window):
    if h % kvh:
        kvh = 1
    rng = np.random.default_rng(tq + h)
    B, hd = 2, 8
    q = jnp.asarray(rng.standard_normal((B, tq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, tq, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, tq, kvh, hd)), jnp.float32)
    if window and not causal:
        causal = True  # window is only used with causal in our archs
    out = blockwise_attention(
        q, k, v, causal=causal, window=window, q_chunk=16, kv_chunk=16
    )
    want = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_blockwise_fwd_only_skipping_matches():
    rng = np.random.default_rng(0)
    B, T, H, hd = 1, 64, 4, 8
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, 2, hd)), jnp.float32)
    a = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    b = blockwise_attention(
        q, k, v, causal=True, q_chunk=16, kv_chunk=16, fwd_only=True
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_decode_matches_naive_last_row():
    rng = np.random.default_rng(1)
    B, S, H, KvH, hd = 2, 32, 4, 2, 8
    cache_len = 20
    k = jnp.asarray(rng.standard_normal((B, S, KvH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KvH, hd)), jnp.float32)
    q1 = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    out = decode_attention(q1, k, v, cache_len)
    want = naive_attention(
        q1[:, None], k[:, :cache_len], v[:, :cache_len], causal=False
    )[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_blockwise_grad_finite():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)

    def f(q):
        return blockwise_attention(
            q, k, v, causal=True, q_chunk=8, kv_chunk=8
        ).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()


def test_pairscan_matches_naive():
    from repro.models.layers import pairscan_attention

    rng = np.random.default_rng(3)
    B, T, H, KvH, hd = 2, 48, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KvH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KvH, hd)), jnp.float32)
    out = pairscan_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-4)
    # window variant
    out_w = pairscan_attention(
        q, k, v, causal=True, window=9, q_chunk=16, kv_chunk=16
    )
    want_w = naive_attention(q, k, v, causal=True, window=9)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(want_w), atol=2e-5, rtol=2e-4)


def test_pairscan_grad_finite():
    from repro.models.layers import pairscan_attention

    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)

    def f(q):
        return pairscan_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8).sum()

    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()
