"""Observability subsystem: span tracer, metrics exposition, engine
quantile/window accounting vs numpy oracles, per-hop search profiling.

Clock-sensitive tests inject a fake clock object (no sleeps): the engine
stamps ``t_submit`` at submit and ``t_done`` after the backend call, so a
backend that advances the fake clock by a chosen delta makes each
request's latency exactly that delta.
"""
import json
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MemoryMode, PageANNConfig, PageANNIndex, SearchParams
from repro.core import search as search_mod
from repro.core.search import PAD, SearchResult
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.obs import (
    MetricsServer,
    Tracer,
    parse_prometheus_text,
    sample_value,
    serve_registry,
)
from repro.obs import report as report_mod
from repro.obs.metrics import MetricsRegistry
from repro.serve import BatchingEngine

N, D = 800, 32


# ------------------------------------------------------------------ tracer
def test_tracer_records_spans_in_order():
    t = {"v": 0.0}
    tr = Tracer(clock=lambda: t["v"])
    t["v"] = 1.0
    with tr.span("phase_a", cat="x", track="eng", n=3):
        t["v"] = 1.5
    tr.add("phase_b", 2.0, 2.25, track="req-1", args={"k": 10})
    tr.instant("marker")
    spans = tr.spans()
    assert [s.name for s in spans] == ["phase_a", "phase_b", "marker"]
    a, b, m = spans
    assert (a.ts, a.dur, a.track, a.args) == (1.0, 0.5, "eng", {"n": 3})
    assert (b.ts, b.dur) == (2.0, 0.25)
    assert m.dur == 0.0
    assert len(tr) == 3 and tr.dropped == 0


def test_tracer_disabled_is_noop_and_shares_null_span():
    tr = Tracer(enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b")
    assert s1 is s2  # one shared no-op CM, no per-call allocation
    with s1:
        pass
    tr.add("c", 0.0, 1.0)
    tr.instant("d")
    assert len(tr) == 0 and tr.spans() == []


def test_tracer_ring_buffer_drops_oldest_and_counts():
    tr = Tracer(capacity=4, clock=lambda: 0.0)
    for i in range(7):
        tr.add(f"s{i}", float(i), float(i))
    assert len(tr) == 4
    assert tr.dropped == 3
    assert [s.name for s in tr.spans()] == ["s3", "s4", "s5", "s6"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_negative_duration_clamps_to_zero():
    tr = Tracer()
    tr.add("backwards", 5.0, 4.0)
    assert tr.spans()[0].dur == 0.0


def test_chrome_export_structure(tmp_path):
    tr = Tracer(clock=lambda: 0.0)
    tr.add("first", 10.0, 10.002, cat="engine", track="engine")
    tr.add("second", 10.001, 10.004, track="req-1", args={"k": 5})
    doc = json.loads(tr.to_chrome_json())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    body = [e for e in events if e["ph"] == "X"]
    # one process_name + one thread_name per distinct track
    assert {e["args"]["name"] for e in meta} == {
        "repro-serve", "engine", "req-1"
    }
    # timestamps are microseconds relative to the EARLIEST span
    first = next(e for e in body if e["name"] == "first")
    second = next(e for e in body if e["name"] == "second")
    assert first["ts"] == 0.0 and first["dur"] == pytest.approx(2000.0)
    assert second["ts"] == pytest.approx(1000.0)
    assert second["args"] == {"k": 5}
    # distinct tracks get distinct tids
    assert first["tid"] != second["tid"]
    out = tmp_path / "trace.json"
    tr.save(str(out))
    assert json.loads(out.read_text()) == doc


# ---------------------------------------------------------------- registry
def test_registry_counter_gauge_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("t_requests_total", "req")
    c.inc()
    c.inc(4.0)
    reg.gauge("t_qps", "qps").set(123.5)
    parsed = parse_prometheus_text(reg.render())
    assert sample_value(parsed, "t_requests_total") == 5.0
    assert sample_value(parsed, "t_qps") == 123.5
    # create-or-get returns the same family; kind mismatch raises
    assert reg.counter("t_requests_total", "req") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("t_requests_total", "req")


def test_registry_histogram_buckets_sum_count():
    reg = MetricsRegistry()
    h = reg.histogram("t_lat_ms", "lat", buckets=(1.0, 5.0, 10.0))
    for v in (0.5, 0.7, 3.0, 7.0, 50.0):
        h.observe(v)
    parsed = parse_prometheus_text(reg.render())
    assert sample_value(parsed, "t_lat_ms_bucket", le="1") == 2
    assert sample_value(parsed, "t_lat_ms_bucket", le="5") == 3
    assert sample_value(parsed, "t_lat_ms_bucket", le="10") == 4
    assert sample_value(parsed, "t_lat_ms_bucket", le="+Inf") == 5
    assert sample_value(parsed, "t_lat_ms_sum") == pytest.approx(61.2)
    assert sample_value(parsed, "t_lat_ms_count") == 5
    # observe_window REPLACES the distribution rather than accumulating
    h.observe_window([2.0, 2.0])
    parsed = parse_prometheus_text(reg.render())
    assert sample_value(parsed, "t_lat_ms_count") == 2
    assert sample_value(parsed, "t_lat_ms_bucket", le="5") == 2


def test_registry_labels_and_validation():
    reg = MetricsRegistry()
    g = reg.gauge("t_pages", "pages")
    g.set(7, labels={"collection": 'we"ird'})
    g.set(9, labels={"collection": "other"})
    parsed = parse_prometheus_text(reg.render())
    assert sample_value(parsed, "t_pages", collection='we"ird') == 7
    assert sample_value(parsed, "t_pages", collection="other") == 9
    with pytest.raises(KeyError):
        sample_value(parsed, "t_pages", collection="absent")
    with pytest.raises(ValueError):
        reg.counter("bad name", "x")
    with pytest.raises(ValueError):
        reg.histogram("t_h", "x", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        parse_prometheus_text("t_ok 1\nthis is not a sample line !!\n")


# --------------------------------------- engine accounting vs numpy oracles
class _FakeClock:
    """Deterministic monotonic clock; tests advance ``.t`` explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _clocked_backend(clock, latencies_s, hops_list, ios=3):
    """Per-dispatch backend: advances the fake clock by the next latency
    (so request latency == that delta at batch_size=1) and reports the
    next scripted hop count."""
    lat_it = iter(latencies_s)
    hop_it = iter(hops_list)

    def fn(q, k, params):
        clock.t += next(lat_it)
        b = q.shape[0]
        return SearchResult(
            ids=jnp.zeros((b, k), jnp.int32),
            dists=jnp.zeros((b, k), jnp.float32),
            ios=jnp.full((b,), ios, jnp.int32),
            hops=jnp.full((b,), next(hop_it), jnp.int32),
            cache_hits=jnp.zeros((b,), jnp.int32),
        )

    return fn


def test_latency_and_hops_quantiles_match_numpy_oracle():
    rng = np.random.default_rng(7)
    lat_s = rng.uniform(0.001, 0.2, size=100)
    hops = rng.integers(1, 40, size=100)
    clock = _FakeClock()
    eng = BatchingEngine(
        _clocked_backend(clock, lat_s, hops), dim=4, batch_size=1,
        clock=clock,
    )
    for _ in range(100):
        eng.submit(np.zeros(4, np.float32)).result(timeout=30)
    m = eng.metrics()
    lat_ms = lat_s * 1e3
    assert m.requests == 100 and m.batches == 100
    assert m.latency_ms_mean == pytest.approx(lat_ms.mean())
    assert m.latency_ms_p50 == pytest.approx(np.percentile(lat_ms, 50))
    assert m.latency_ms_p99 == pytest.approx(np.percentile(lat_ms, 99))
    assert m.mean_hops == pytest.approx(hops.mean())
    assert m.p99_hops == pytest.approx(np.percentile(hops, 99))
    assert m.mean_ios == 3.0 and m.p99_ios == 3.0
    # windows snapshot must agree with the gauges' source data
    win = eng.metrics_windows()
    np.testing.assert_allclose(win["latency_ms"], lat_ms)
    np.testing.assert_array_equal(win["hops"], hops)
    eng.close()


def test_latency_window_evicts_oldest_at_overflow():
    window = 16
    total = 50
    lat_s = np.linspace(0.001, 0.05, total)
    hops = np.arange(1, total + 1)
    clock = _FakeClock()
    eng = BatchingEngine(
        _clocked_backend(clock, lat_s, hops), dim=4, batch_size=1,
        clock=clock, latency_window=window,
    )
    for _ in range(total):
        eng.submit(np.zeros(4, np.float32)).result(timeout=30)
    m = eng.metrics()
    # cumulative counters keep the full history ...
    assert m.requests == total
    # ... while the quantile gauges see exactly the trailing window
    tail_ms = lat_s[-window:] * 1e3
    assert m.latency_ms_mean == pytest.approx(tail_ms.mean())
    assert m.latency_ms_p50 == pytest.approx(np.percentile(tail_ms, 50))
    assert m.latency_ms_p99 == pytest.approx(np.percentile(tail_ms, 99))
    assert m.mean_hops == pytest.approx(hops[-window:].mean())
    win = eng.metrics_windows()
    assert len(win["latency_ms"]) == window
    np.testing.assert_allclose(win["latency_ms"], tail_ms)
    eng.close()


def test_early_exit_accounting_against_resolved_max_hops():
    hops = [3, 10, 10, 7, 10, 1]  # 3 requests exit before max_hops=10
    clock = _FakeClock()
    eng = BatchingEngine(batch_size=1, clock=clock)
    eng.add_collection(
        "c",
        _clocked_backend(clock, [0.001] * len(hops), hops),
        dim=4,
        default_k=5,
        resolve_fn=lambda k, p: SearchParams(k=k, max_hops=10),
    )
    for _ in range(len(hops)):
        eng.submit(np.zeros(4, np.float32), collection="c").result(timeout=30)
    assert eng.metrics().early_exits == 3
    eng.close()


# ---------------------------------------------------- exposition over engine
def test_serve_registry_reconciles_with_engine_metrics():
    rng = np.random.default_rng(3)
    n = 40
    lat_s = rng.uniform(0.001, 0.05, size=n)
    hops = rng.integers(1, 30, size=n)
    clock = _FakeClock()
    eng = BatchingEngine(
        _clocked_backend(clock, lat_s, hops), dim=4, batch_size=1,
        clock=clock,
    )
    for _ in range(n):
        eng.submit(np.zeros(4, np.float32)).result(timeout=30)
    reg = serve_registry(eng)
    parsed = parse_prometheus_text(reg.render())
    m = eng.metrics()
    assert sample_value(parsed, "pageann_requests_total") == m.requests
    assert sample_value(parsed, "pageann_batches_total") == m.batches
    assert sample_value(parsed, "pageann_early_exits_total") == m.early_exits
    assert sample_value(parsed, "pageann_compile_misses_total") == (
        m.compile_misses
    )
    assert sample_value(parsed, "pageann_latency_ms_p99") == pytest.approx(
        m.latency_ms_p99
    )
    assert sample_value(parsed, "pageann_mean_hops") == pytest.approx(
        m.mean_hops
    )
    assert sample_value(parsed, "pageann_collections") == 1
    # the latency histogram is the engine's trailing window verbatim
    assert sample_value(
        parsed, "pageann_request_latency_ms_count"
    ) == n
    assert sample_value(
        parsed, "pageann_request_latency_ms_sum"
    ) == pytest.approx((lat_s * 1e3).sum())
    assert sample_value(
        parsed, "pageann_request_hops_bucket", le="+Inf"
    ) == n
    eng.close()


def test_metrics_server_scrape_endpoints():
    clock = _FakeClock()
    eng = BatchingEngine(
        _clocked_backend(clock, [0.002] * 5, [4] * 5), dim=4, batch_size=1,
        clock=clock,
    )
    for _ in range(5):
        eng.submit(np.zeros(4, np.float32)).result(timeout=30)
    reg = serve_registry(eng)
    with MetricsServer(reg, source=eng) as srv:
        assert srv.port > 0
        with urllib.request.urlopen(f"{srv.url}/healthz", timeout=10) as r:
            assert r.status == 200 and r.read() == b"ok\n"
        with urllib.request.urlopen(f"{srv.url}/metrics", timeout=10) as r:
            assert r.status == 200
            assert r.headers["Content-Type"].startswith("text/plain")
            parsed = parse_prometheus_text(r.read().decode())
        assert sample_value(parsed, "pageann_requests_total") == 5
        with urllib.request.urlopen(f"{srv.url}/stats", timeout=10) as r:
            doc = json.loads(r.read().decode())
        assert doc["metrics"]["requests"] == 5
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{srv.url}/nope", timeout=10)
    eng.close()


# ------------------------------------------------- engine tracing integration
def test_engine_emits_expected_span_phases():
    clock = _FakeClock()
    tr = Tracer(clock=clock)
    eng = BatchingEngine(
        _clocked_backend(clock, [0.004] * 4, [5] * 4), dim=4, batch_size=2,
        clock=clock, tracer=tr,
    )
    futs = [eng.submit(np.zeros(4, np.float32)) for _ in range(4)]
    for f in futs:
        f.result(timeout=30)
    names = {s.name for s in tr.spans()}
    assert {
        "submit", "queue_wait", "batch_assemble", "device_dispatch",
        "demux", "request",
    } <= names
    # per-request spans live on per-request tracks; the first dispatch is
    # cold, so it carries an overlaid compile span
    reqs = [s for s in tr.spans() if s.name == "request"]
    assert sorted(s.track for s in reqs) == [
        "req-1", "req-2", "req-3", "req-4"
    ]
    dispatches = [s for s in tr.spans() if s.name == "device_dispatch"]
    assert [d.args["compiled"] for d in dispatches] == [True, False]
    assert sum(s.name == "compile" for s in tr.spans()) == 1
    # request span duration equals the engine-reported latency
    for s in reqs:
        assert s.dur * 1e3 == pytest.approx(s.args["latency_ms"])
    eng.close()


# ------------------------------------------------------- per-hop profiling
@pytest.fixture(scope="module")
def small_index():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    cfg = PageANNConfig(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    return PageANNIndex.build(x, cfg)


@pytest.mark.parametrize("mode", list(MemoryMode))
def test_profile_search_matches_batch_search(mode):
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    cfg = PageANNConfig(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=mode,
    )
    index = PageANNIndex.build(x, cfg)
    q = jnp.asarray(query_vectors(x, 8, seed=5), jnp.float32)
    params = index.resolve_params(10, None)
    want = search_mod.batch_search(
        q, index.data, params, capacity=index.store.capacity,
        mode=mode.value,
    )
    got, trail = search_mod.profile_search(
        q, index.data, params, capacity=index.store.capacity,
        mode=mode.value,
    )
    # the profiled program reuses the same pure hop transitions: results
    # are identical, distances to the bit
    np.testing.assert_array_equal(np.asarray(want.ids), np.asarray(got.ids))
    assert np.array_equal(
        np.asarray(want.dists).view(np.uint32),
        np.asarray(got.dists).view(np.uint32),
    )
    np.testing.assert_array_equal(np.asarray(want.ios), np.asarray(got.ios))
    np.testing.assert_array_equal(np.asarray(want.hops), np.asarray(got.hops))
    np.testing.assert_array_equal(
        np.asarray(want.cache_hits), np.asarray(got.cache_hits)
    )
    # trail invariants: per-hop deltas sum to the totals, inactive hops
    # are fully frozen (no pages scheduled, no I/O)
    active = np.asarray(trail.active)
    np.testing.assert_array_equal(active.sum(1), np.asarray(got.hops))
    np.testing.assert_array_equal(
        np.asarray(trail.ios).sum(1), np.asarray(got.ios)
    )
    np.testing.assert_array_equal(
        np.asarray(trail.cache_hits).sum(1), np.asarray(got.cache_hits)
    )
    pages = np.asarray(trail.pages)
    assert (pages[~active] == PAD).all()
    assert (np.asarray(trail.ios)[~active] == 0).all()


def test_index_profile_api(tmp_path, small_index):
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, 4, seed=9)
    want = small_index.search(q, k=10)
    out = tmp_path / "profile.json"
    res, trail = small_index.profile(q, k=10, save=str(out))
    # translated ids match the fast path exactly
    np.testing.assert_array_equal(want.ids, res.ids)
    assert trail.pages.shape[0] == 4
    doc = json.loads(out.read_text())
    assert doc["kind"] == "pageann_profile"
    assert len(doc["ids"]) == 4
    # the report CLI renders it
    assert report_mod.main([str(out), "--queries", "2"]) == 0


def test_profile_rejects_streamed_index(small_index):
    class _Streamed(PageANNIndex):
        pass

    streamed = object.__new__(_Streamed)
    streamed.__dict__.update(small_index.__dict__)
    streamed.fetcher = object()
    with pytest.raises(ValueError, match="streamed"):
        streamed.profile(np.zeros((1, D), np.float32))


def test_report_cli_renders_chrome_trace(tmp_path, capsys):
    tr = Tracer(clock=lambda: 0.0)
    tr.add("device_dispatch", 0.0, 0.010, cat="engine", track="engine")
    tr.add("queue_wait", 0.0, 0.002, track="req-1")
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert report_mod.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "device_dispatch" in out and "queue_wait" in out
