"""Per-arch smoke tests (reduced configs, CPU): one train forward + one
decode step, shape and finiteness assertions, and prefill/decode logit
consistency for one arch per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_arch
from repro.models import transformer as tf
from repro.models.frontend import make_train_batch

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_shapes(arch_id):
    cfg = get_arch(arch_id, smoke=True)
    params = tf.init_params(cfg, KEY)
    batch = make_train_batch(cfg, 2, 32, KEY)
    logits, aux = tf.forward_train(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[..., : cfg.vocab_size])).all()
    loss, (nll, _) = tf.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # random init -> loss near ln(V)
    assert abs(float(nll) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS if get_arch(a).is_decoder])
def test_smoke_decode_step(arch_id):
    cfg = get_arch(arch_id, smoke=True)
    params = tf.init_params(cfg, KEY)
    cache = tf.init_cache(cfg, 2, 64)
    p3 = jnp.zeros((3, 2, 1), jnp.int32) if cfg.mrope else None
    logits, new_cache = tf.decode_step(
        params, cache, jnp.zeros((2,), jnp.int32), jnp.int32(3), cfg, p3
    )
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab_size])).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch_id", ["granite-3-2b", "mamba2-370m", "recurrentgemma-9b"])
def test_prefill_decode_consistency(arch_id):
    """Teacher-forced decode reproduces the training-forward logits."""
    cfg = get_arch(arch_id, smoke=True)
    # plain attention chunks that divide T; no remat noise
    cfg = dataclasses.replace(cfg, q_chunk=8, kv_chunk=8)
    params = tf.init_params(cfg, KEY)
    B, T = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0, cfg.vocab_size)
    batch = {
        "tokens": toks,
        "labels": toks,
        "positions": jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32),
    }
    full_logits, _ = tf.forward_train(params, batch, cfg)

    cache = tf.init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        logits, cache = tf.decode_step(
            params, cache, toks[:, t], jnp.int32(t), cfg
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits[..., : cfg.vocab_size]),
        np.asarray(dec[..., : cfg.vocab_size]),
        atol=2e-2, rtol=2e-2,
    )


def test_shape_applicability_matrix():
    runnable = 0
    skips = []
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(arch, shape)
            if ok:
                runnable += 1
            else:
                skips.append((aid, sname, why))
    assert runnable == 31  # 40 - 7 full-attn long_500k - 2 hubert decode
    assert ("hubert-xlarge", "decode_32k", "encoder-only arch has no decode step") in skips


def test_vocab_padding_masks_logits():
    cfg = get_arch("granite-3-2b", smoke=True)
    cfg = dataclasses.replace(cfg, vocab_size=100)  # padded to 256
    params = tf.init_params(cfg, KEY)
    batch = make_train_batch(cfg, 1, 8, KEY)
    batch["tokens"] = jnp.clip(batch["tokens"], 0, 99)
    batch["labels"] = jnp.clip(batch["labels"], 0, 99)
    logits, _ = tf.forward_train(params, batch, cfg)
    assert logits.shape[-1] == 256
    assert (np.asarray(logits[..., 100:]) <= -1e29).all()
