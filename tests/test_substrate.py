"""Optimizers, gradient compression, checkpointing, fault tolerance, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpointing as ckpt
from repro.configs.base import SHAPES
from repro.configs.registry import get_arch
from repro.data.pipeline import TokenPipeline, clustered_vectors
from repro.ft.failures import (
    PreemptionGuard,
    RestartManager,
    StragglerMonitor,
    elastic_remesh,
)
from repro.optim import Adafactor, AdamW, global_norm
from repro.train import compress


# ------------------------------------------------------------- optimizers ---
@pytest.mark.parametrize("opt", [AdamW(lr=0.1), Adafactor(lr=0.5)])
def test_optimizer_decreases_quadratic(opt):
    params = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 4)), jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(30):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}
    st_ = Adafactor().init(params)
    assert st_.vr["w"].shape == (16,)
    assert st_.vc["w"].shape == (8,)
    assert st_.vr["b"].shape == (8,)     # rank-1: unfactored


def test_adafactor_scanned_update_matches_unscanned():
    """Stacked (L, r, c) leaves update layer-by-layer — results identical."""
    rng = np.random.default_rng(0)
    opt = Adafactor(lr=0.1)
    stacked = {"w": jnp.asarray(rng.standard_normal((3, 8, 4)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal((3, 8, 4)), jnp.float32)}
    s = opt.init(stacked)
    new_stacked, _, _ = opt.update(grads, s, stacked)
    for i in range(3):
        one = {"w": stacked["w"][i]}
        g1 = {"w": grads["w"][i]}
        s1 = opt.init(one)
        got, _, _ = opt.update(g1, s1, one)
        np.testing.assert_allclose(
            np.asarray(new_stacked["w"][i]), np.asarray(got["w"]), rtol=2e-4, atol=1e-5
        )


def test_global_norm():
    t = {"a": jnp.ones((2, 2)), "b": jnp.ones((5,))}
    assert abs(float(global_norm(t)) - 3.0) < 1e-6


# ------------------------------------------------------ grad compression ----
@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-3, 1e3))
def test_int8_compression_error_bounded(scale):
    rng = np.random.default_rng(int(scale * 7) % 100)
    g = jnp.asarray(scale * rng.standard_normal((64,)), jnp.float32)
    q, s = compress.compress(g)
    back = compress.decompress(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-9


def test_error_feedback_accumulates_truth():
    """Sum of EF-compressed grads converges to the true sum."""
    rng = np.random.default_rng(0)
    grads = [
        {"w": jnp.asarray(rng.standard_normal((32,)) * 0.01, jnp.float32)}
        for _ in range(50)
    ]
    ef = compress.init_ef(grads[0])
    applied = jnp.zeros((32,))
    for g in grads:
        codes, scales, ef = compress.ef_compress_tree(g, ef)
        applied = applied + compress.ef_decompress_tree(codes, scales)["w"]
    true = sum(g["w"] for g in grads)
    resid = float(jnp.abs(applied + ef.residual["w"] - true).max())
    assert resid < 1e-4


# ----------------------------------------------------------- checkpoints ----
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "n": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(str(tmp_path), 7, jax.tree.map(lambda x: x, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_commit(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    d = ckpt.save(str(tmp_path), 1, tree)
    assert not d.endswith(".tmp")
    assert not os.path.exists(d + ".tmp")


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path))
    for s in (1, 2):
        w.submit(s, {"a": jnp.full((3,), s)})
    w.close()
    assert ckpt.latest_step(str(tmp_path)) == 2
    out = ckpt.restore(str(tmp_path), 2, {"a": jnp.zeros((3,))})
    np.testing.assert_array_equal(np.asarray(out["a"]), 2.0)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((3,))})


# --------------------------------------------------------- fault tolerance --
def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(threshold=2.0, rebalance_after=2)
    for s in range(10):
        m.observe(s, 1.0)
    assert not m.slow_steps
    assert m.observe(10, 5.0)
    assert not m.should_rebalance()
    m.observe(11, 5.0)
    assert m.should_rebalance()
    assert [s for s, _ in m.slow_steps] == [10, 11]


def test_restart_manager_recovers():
    calls = {"n": 0}

    def step(s):
        calls["n"] += 1
        if s == 3 and calls["n"] < 6:
            raise RuntimeError("chip failure")

    def restore():
        return 2  # resume from checkpointed step

    rm = RestartManager(max_restarts=3)
    done = rm.run(6, step, restore)
    assert done == 6
    assert rm.restarts >= 1


def test_restart_manager_gives_up():
    rm = RestartManager(max_restarts=1)

    def step(s):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        rm.run(3, step, lambda: 0)


def test_preemption_guard_flag():
    g = PreemptionGuard()
    assert not g.preempted
    g.request()
    assert g.preempted


def test_elastic_remesh_shapes():
    assert elastic_remesh(256) == (16, 16)
    assert elastic_remesh(240) == (15, 16)   # one host of 16 chips lost
    assert elastic_remesh(512, multi_pod=True) == (2, 16, 16)
    assert elastic_remesh(8) == (1, 8)


# ------------------------------------------------------------------ data ----
def test_token_pipeline_determinism_and_host_sharding():
    arch = get_arch("granite-3-2b", smoke=True)
    shape = SHAPES["train_4k"]
    import dataclasses

    shape = dataclasses.replace(shape, seq_len=16, global_batch=8)
    p0 = TokenPipeline(arch, shape, num_hosts=2, host_id=0)
    p0b = TokenPipeline(arch, shape, num_hosts=2, host_id=0)
    p1 = TokenPipeline(arch, shape, num_hosts=2, host_id=1)
    b0, b0b, b1 = p0.batch(3), p0b.batch(3), p1.batch(3)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    assert b0["tokens"].shape == (4, 16)
    # next-token labels
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_clustered_vectors_shape_and_structure():
    x = clustered_vectors(256, 16, num_clusters=4, seed=0)
    assert x.shape == (256, 16)
    # clustered: mean pairwise distance within dataset < random gaussian data
    rng = np.random.default_rng(0)
    rand = rng.standard_normal((256, 16)).astype(np.float32)

    def spread(a):
        return np.var(a, axis=0).sum()

    assert spread(x) < spread(rand) * 3
