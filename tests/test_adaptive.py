"""Adaptive query engine (PR 7): query-sensitive entry selection,
per-query early termination, and recall/latency autotuning.

The load-bearing guarantees:
  * disabled adaptive features are a no-op at the BIT level — ids, dists,
    ios, hops, and cache_hits all equal the pre-adaptive loop, on every
    memory mode and on the streamed (memory-budgeted) path;
  * early termination trades nothing it should not: hops(enabled) <=
    hops(disabled) pointwise, recall stays within a tight parity bound,
    and easy (duplicate-of-base) queries exit well before ``max_hops``;
  * combined validation reports EVERY violated field in one error;
  * ``autotune`` meets its recall floor and the operating point
    round-trips through the manifest into ``load_index`` /
    ``VectorService.attach(recall_target=...)`` defaults.
"""
import tempfile

import numpy as np
import pytest

from repro.core import (
    AdaptiveParams,
    MemoryBudget,
    MemoryMode,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    load_index,
    recall_at_k,
)
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

N, D, Q = 2500, 32, 25


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=32, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=16, build_beam=32, pq_subspaces=8,
        lsh_sample=512, lsh_entries=8, beam_width=64, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def hybrid_index(dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg())


# ------------------------------------------------------------- validation
def test_searchparams_reports_every_violation_in_one_error():
    with pytest.raises(ValueError) as e:
        SearchParams(k=0, beam_width=-1, io_batch=0)
    msg = str(e.value)
    assert "k must be positive (got 0)" in msg
    assert "beam_width must be positive (got -1)" in msg
    assert "io_batch must be positive (got 0)" in msg


def test_searchparams_rejects_non_adaptive_adaptive():
    with pytest.raises(ValueError, match="adaptive must be an AdaptiveParams"):
        SearchParams(adaptive="patience=2")


def test_adaptiveparams_reports_every_violation_in_one_error():
    with pytest.raises(ValueError) as e:
        AdaptiveParams(patience=0, epsilon=-1.0, entry_slack_bits=-3,
                       min_entries=0)
    msg = str(e.value)
    assert "patience must be >= 1 (got 0)" in msg
    assert "epsilon must be >= 0 (got -1.0)" in msg
    assert "entry_slack_bits must be >= 0 (got -3)" in msg
    assert "min_entries must be >= 1 (got 0)" in msg


def test_pageann_path_reports_cross_field_violations_together(hybrid_index):
    """The beam>=entries invariant and the adaptive entry-floor invariant
    are both PageANN-path checks; a params value violating both must name
    both in one search-time error."""
    p = SearchParams(
        beam_width=4, lsh_entries=8,
        adaptive=AdaptiveParams(entry_slack_bits=2, min_entries=9),
    )
    with pytest.raises(ValueError) as e:
        hybrid_index.search(np.zeros((1, D), np.float32), params=p)
    msg = str(e.value)
    assert "beam_width >= lsh_entries" in msg
    assert "min_entries <= lsh_entries" in msg


# ----------------------------------------------------- disabled bit-identity
@pytest.fixture(scope="module", params=list(MemoryMode), ids=lambda m: m.value)
def mode_index(request, dataset):
    x, _, _ = dataset
    return PageANNIndex.build(x, _cfg(memory_mode=request.param))


def _assert_results_equal(want, got, context=""):
    for field in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, field)),
            np.asarray(getattr(got, field)),
            err_msg=f"{context}SearchResult.{field}",
        )


def test_disabled_adaptive_bit_identical_all_modes(dataset, mode_index):
    """adaptive=None and an all-default AdaptiveParams() must compile to
    the exact pre-adaptive program: every SearchResult field bit-equal,
    on every memory-disk coordination mode."""
    _, q, _ = dataset
    base = SearchParams.from_config(mode_index.cfg)
    want = mode_index.search(q, params=base)
    got = mode_index.search(q, params=base.replace(adaptive=AdaptiveParams()))
    _assert_results_equal(want, got, f"{mode_index.cfg.memory_mode.value}: ")


def test_disabled_adaptive_bit_identical_streamed(dataset, hybrid_index):
    """Same guarantee on the memory-budgeted streaming path (0.25x
    residency): the adaptive no-op composes with the PR-6 bit-identity."""
    _, q, _ = dataset
    base = SearchParams.from_config(hybrid_index.cfg)
    with tempfile.TemporaryDirectory() as d:
        hybrid_index.save(d)
        streamed = load_index(d, memory_budget=MemoryBudget(fraction=0.25))
        assert streamed.fetcher is not None
        want = streamed.search(q, params=base)
        got = streamed.search(
            q, params=base.replace(adaptive=AdaptiveParams())
        )
    _assert_results_equal(want, got, "streamed: ")
    # and the streamed adaptive run matches the resident adaptive run
    resident = hybrid_index.search(
        q, params=base.replace(adaptive=AdaptiveParams(patience=2))
    )
    with tempfile.TemporaryDirectory() as d:
        hybrid_index.save(d)
        streamed = load_index(d, memory_budget=MemoryBudget(fraction=0.25))
        got = streamed.search(
            q, params=base.replace(adaptive=AdaptiveParams(patience=2))
        )
    _assert_results_equal(resident, got, "streamed adaptive: ")


# -------------------------------------------------------- early termination
def test_early_termination_hops_monotone_and_recall_parity(dataset,
                                                           hybrid_index):
    x, q, truth = dataset
    base = SearchParams.from_config(hybrid_index.cfg)
    off = hybrid_index.search(q, params=base)
    on = hybrid_index.search(
        q, params=base.replace(adaptive=AdaptiveParams(patience=2))
    )
    # a lane can only exit EARLIER: the cond gained a conjunct
    assert (np.asarray(on.hops) <= np.asarray(off.hops)).all()
    assert (np.asarray(on.ios) <= np.asarray(off.ios)).all()
    r_off = recall_at_k(off.ids, truth)
    r_on = recall_at_k(on.ids, truth)
    assert r_on >= r_off - 0.02, (r_on, r_off)


def test_easy_queries_terminate_before_max_hops(dataset, hybrid_index):
    """Duplicate-of-base queries converge immediately; with patience set
    they must exit strictly before the max_hops safety bound — and spend
    strictly fewer hops than the non-adaptive run on average."""
    x, _, _ = dataset
    rng = np.random.default_rng(7)
    easy = x[rng.choice(len(x), 16, replace=False)]
    base = SearchParams.from_config(hybrid_index.cfg)
    off = hybrid_index.search(easy, params=base)
    on = hybrid_index.search(
        easy, params=base.replace(adaptive=AdaptiveParams(patience=1))
    )
    hops = np.asarray(on.hops)
    assert (hops < hybrid_index.cfg.max_hops).all()
    assert hops.mean() < np.asarray(off.hops).mean()
    # each duplicate still finds itself at distance ~0
    assert np.allclose(np.asarray(on.dists)[:, 0], 0.0, atol=1e-4)


def test_entry_selection_recall_parity(dataset, hybrid_index):
    _, q, truth = dataset
    base = SearchParams.from_config(hybrid_index.cfg)
    res = hybrid_index.search(
        q,
        params=base.replace(
            adaptive=AdaptiveParams(entry_slack_bits=4, min_entries=4)
        ),
    )
    assert recall_at_k(res.ids, truth) >= 0.8


# ---------------------------------------------------------------- autotune
def test_autotune_meets_recall_floor_and_roundtrips(dataset):
    x, q, truth = dataset
    idx = PageANNIndex.build(x, _cfg())
    win = idx.autotune(q, recall_target=0.9, truth=truth,
                       beam_grid=(16, 32, 64))
    assert win["recall"] >= 0.9
    assert idx.default_params == win["params"]
    with tempfile.TemporaryDirectory() as d:
        idx.save(d)
        loaded = load_index(d)
        # the tuned operating point IS the loaded default
        assert loaded.default_params == win["params"]
        assert loaded.params_for_target(recall_target=0.9) == win["params"]
        with pytest.raises(LookupError, match="no tuned operating point"):
            loaded.params_for_target(recall_target=0.9999999)
        # and searching with no explicit params runs it
        res = loaded.search(q, k=10)
        assert recall_at_k(res.ids, truth) >= 0.85


def test_autotune_rejects_ambiguous_target(hybrid_index):
    with pytest.raises(ValueError, match="exactly one of"):
        hybrid_index.autotune(np.zeros((4, D), np.float32))
    with pytest.raises(ValueError, match="exactly one of"):
        hybrid_index.params_for_target()


def test_autotune_latency_target(dataset):
    x, q, truth = dataset
    idx = PageANNIndex.build(x, _cfg())
    win = idx.autotune(q, p99_target_us=10_000_000.0, truth=truth,
                       beam_grid=(16, 32), patience_grid=(None, 2))
    # an absurdly generous budget: every point qualifies, the best-recall
    # one wins and is stored
    assert win["p99_us"] <= 10_000_000.0
    assert idx.params_for_target(p99_target_us=10_000_000.0) == win["params"]


def test_service_attach_recall_target(dataset):
    from repro.serve import VectorService

    x, q, truth = dataset
    idx = PageANNIndex.build(x, _cfg())
    idx.autotune(q, recall_target=0.9, truth=truth, beam_grid=(32, 64))
    tuned = idx.tuned_default
    with tempfile.TemporaryDirectory() as d:
        idx.save(d)
        with VectorService(batch_size=4) as svc:
            h = svc.attach("tunedcol", d, recall_target=0.9)
            assert (
                svc._engine._collections["tunedcol"].default_params == tuned
            )
            rows = h.search(np.asarray(q)[:4], k=10)
            assert len(rows) == 4
            # strict: an unreachable target refuses to attach
            with pytest.raises(LookupError, match="no tuned operating point"):
                svc.attach("strict", d, recall_target=0.9999999)
            with pytest.raises(ValueError, match="not both"):
                svc.attach("both", d, recall_target=0.9,
                           params=SearchParams())


# ----------------------------------------------------------- engine metrics
def test_engine_metrics_surface_hops_and_early_exits(dataset):
    from repro.serve import BatchingEngine

    x, q, _ = dataset
    idx = PageANNIndex.build(x, _cfg())
    params = SearchParams.from_config(idx.cfg).replace(
        adaptive=AdaptiveParams(patience=2)
    )
    with BatchingEngine.from_index(
        idx, k=10, batch_size=8, params=params
    ) as eng:
        eng.search(np.asarray(q)[:8])
        m = eng.metrics()
    assert m.mean_hops > 0
    assert m.p99_hops >= m.mean_hops
    assert m.p99_ios > 0
    # every lane converged before the max_hops safety bound here
    assert m.early_exits == 8
